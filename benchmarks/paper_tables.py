"""One benchmark per paper table.

Hardware columns (slices, MHz) need synthesis; the architecture-level
columns — schedule, cycle counts, latency bounds, min set sizes, adder
utilization, exactness — are measured on the cycle-accurate simulators,
and the production JAX layer is timed for throughput.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.core.circuit import INTAC, JugglePAC, jugglepac_min_set_size
from repro.core.segmented import segment_sum_ref, segments_from_lengths


def _time(fn, *args, reps=5, warmup=2, **kw):
    """Median wall time of ``reps`` fully-blocked calls, in us.

    Every timed call blocks until its result is ready: with JAX's async
    dispatch, timing a loop of unblocked calls and blocking once at the
    end measures queue depth, not per-call latency.  The median (not the
    mean) is reported because a single straggler — first-touch
    allocation, a GC pause, the OS descheduling this 1-core box —
    poisons a mean arbitrarily; that is exactly how the fast tier once
    reported 6421us on a workload whose median call took 45us.  Two
    warmup calls absorb compilation *and* the first post-compile
    dispatch (which pays one-time buffer setup).
    """
    for _ in range(max(1, warmup)):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(reps):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    return float(np.median(times)) * 1e6


def table1_schedule(rows):
    """Table I: the JugglePAC schedule for 3 sets (5,4,9 elems), L=2."""
    pac = JugglePAC(adder_latency=2, num_registers=4)
    sets = [[1, 2, 3, 4, 5], [10, 20, 30, 40],
            [100, 200, 300, 400, 500, 600, 700, 800, 900]]
    res = pac.run(sets)
    total_cycles = max(r.cycle for r in res)
    in_order = [r.set_index for r in res] == [0, 1, 2]
    correct = all(abs(r.value - sum(s)) < 1e-9 for r, s in zip(res, sets))
    issues = len(pac.adder_issue_log)
    rows.append(("table1_schedule_cycles", total_cycles,
                 f"in_order={in_order} correct={correct} "
                 f"adder_issues={issues} (paper: result@16,17)"))


def table2_pis_registers(rows):
    """Table II: min set size + latency constant vs #PIS registers, L=14."""
    paper = {2: 94, 4: 29, 8: 18}
    for regs in (2, 4, 8):
        t0 = time.perf_counter()
        m = jugglepac_min_set_size(14, regs)
        us = (time.perf_counter() - t0) * 1e6
        # worst latency constant at n=128 (the paper's test length)
        pac = JugglePAC(14, regs)
        res = pac.run([[1.0] * 128 for _ in range(6)])
        c = max(r.latency - 128 for r in res)
        rows.append((f"table2_minset_regs{regs}", us,
                     f"min_set={m} paper={paper[regs]} latency<=DS+{c} "
                     f"(paper: DS+110..113)"))


def table3_accumulator_comparison(rows):
    """Table III: design comparison.  Cycle-level: JugglePAC (1 adder) vs a
    serial accumulator (1 adder, stalls) on back-to-back sets; plus wall
    time of the production segmented-sum paths."""
    sets = [[float(j) for j in range(128)] for _ in range(8)]
    n_inputs = sum(len(s) for s in sets)

    pac = JugglePAC(14, 4)
    res = pac.run(sets)
    pac_cycles = max(r.cycle for r in res)

    # serial pipelined accumulator: one in-flight addition per set; inputs
    # stall whenever the adder is busy -> n * L cycles per set
    serial_cycles = sum(len(s) for s in sets) * 14

    rows.append(("table3_jugglepac_cycles", pac_cycles,
                 f"{n_inputs} inputs back-to-back, 1 adder, L=14; "
                 f"throughput={n_inputs / pac_cycles:.2f} inputs/cycle"))
    rows.append(("table3_serial_cycles", serial_cycles,
                 f"stalling serial accumulator "
                 f"({serial_cycles / pac_cycles:.1f}x slower)"))

    # production layer: variable-length segmented sum through the
    # repro.reduce front door, scatter oracle vs registered backends
    rng = np.random.RandomState(0)
    lens = rng.randint(64, 256, size=64)
    total = int(lens.sum())
    vals = jnp.asarray(rng.randn(total, 128).astype(np.float32))
    ids = segments_from_lengths(jnp.asarray(lens), total)

    ref = jax.jit(lambda v, i: segment_sum_ref(v, i, 64))
    us_ref = _time(ref, vals, ids)
    us_blocked = _time(lambda v, i: repro.reduce(
        v, segment_ids=i, num_segments=64, backend="blocked"), vals, ids)
    us_kernel = _time(lambda v, i: repro.reduce(
        v, segment_ids=i, num_segments=64, backend="pallas"), vals, ids)
    rows.append(("table3_segsum_scatter_ref_us", us_ref,
                 f"{total} rows x 128, 64 segments"))
    rows.append(("table3_segsum_blocked_us", us_blocked,
                 "repro.reduce backend=blocked (lax.scan schedule)"))
    rows.append(("table3_segsum_jugglepac_kernel_us", us_kernel,
                 "repro.reduce backend=pallas, interpret on CPU (TPU "
                 "schedule validation, not a wall-clock claim)"))


def table5_intac(rows):
    """Table V: INTAC latency/parameters + exactness of the fixed-point
    accumulation vs float summation."""
    for n_in, fas in ((1, 1), (1, 2), (1, 16), (2, 16)):
        it = INTAC(64, 128, n_in, fas)
        res = it.accumulate(list(range(1000)))
        lat = res.cycle
        eq1 = INTAC.latency_eq1(1000, n_in, 128, fas)
        rows.append((f"table5_intac_in{n_in}_fa{fas}_cycles", lat,
                     f"eq1={eq1} min_set={it.min_set_size()} "
                     f"(paper latency N/{n_in}+{-(-128 // fas)})"))

    # exactness + determinism: integer-domain accumulation (bounded-range
    # data, the paper's fixed-point assumption) vs a true serial fp32 sum
    # and numpy's pairwise sum (a reduction tree, like our Fig.2 schedule).
    rng = np.random.RandomState(1)
    x = rng.randn(1 << 14).astype(np.float32)
    exact = float(np.sum(x.astype(np.float64)))
    acc = np.float32(0.0)
    for v in x:                                   # genuinely serial
        acc = np.float32(acc + v)
    err_serial = abs(float(acc) - exact)
    err_pairwise = abs(float(x.sum(dtype=np.float32)) - exact)

    from repro.core.intac import LimbState, limb_finalize
    from repro.kernels.ref import intac_accum_ref, limbs_to_float
    scale = np.float32(2.0 ** 24)
    limbs = intac_accum_ref(jnp.asarray(x)[:, None], scale)
    err_intac = abs(float(limbs_to_float(limbs, scale)[0]) - exact)
    rows.append(("table5_intac_abs_err", err_intac,
                 f"serial_fp32_err={err_serial:.3e} "
                 f"pairwise_tree_err={err_pairwise:.3e} "
                 "(integer accumulation: exact, one final rounding)"))

    # determinism under permutation (the non-associativity problem),
    # via the front door's exact policy
    perm = rng.permutation(len(x))
    det = float(repro.reduce(jnp.asarray(x), policy="exact")) == \
        float(repro.reduce(jnp.asarray(x[perm]), policy="exact"))
    acc2 = np.float32(0.0)
    for v in x[perm]:
        acc2 = np.float32(acc2 + v)
    rows.append(("table5_intac_permutation_invariant", int(det),
                 f"fp32_serial_changes_by={abs(float(acc2 - acc)):.3e}"))


def table6_reduce_policies(rows, *, smoke: bool = False):
    """repro.reduce accuracy/latency sweep: the policy knob quantified.

    One ill-conditioned segmented stream, every registered accuracy
    policy on the jit-friendly blocked backend: abs error vs f64 and host
    wall time.  ``smoke`` shrinks the stream so CI can assert the whole
    five-tier sweep stays runnable in seconds.
    """
    rng = np.random.RandomState(7)
    n, d, s = (1 << 10, 16, 8) if smoke else (1 << 14, 64, 32)
    x = (rng.randn(n, d) * 10 ** rng.uniform(-3, 3, (n, 1))) \
        .astype(np.float32)
    ids = np.sort(rng.randint(0, s, n))
    exact64 = np.zeros((s, d))
    np.add.at(exact64, ids, x.astype(np.float64))
    vals, jids = jnp.asarray(x), jnp.asarray(ids)
    for pol in ("fast", "compensated", "exact", "exact2", "procrastinate"):
        fn = jax.jit(lambda v, i, p=pol: repro.reduce(
            v, segment_ids=i, num_segments=s, policy=p, backend="blocked"))
        us = _time(fn, vals, jids)
        err = float(np.abs(np.asarray(fn(vals, jids)) - exact64).max())
        rows.append((f"table6_reduce_{pol}_us", us,
                     f"max_abs_err_vs_f64={err:.3e} "
                     f"({n}x{d} rows, {s} segments, blocked backend)"))
        # machine-independent accuracy rows, for the integer tiers only:
        # their error is bit-deterministic by the repo's own contract, so
        # the baseline gate can hold it to 20% exactly.  The float tiers'
        # error depends on XLA's internal f32 dot reduction order — it
        # would move with a jax upgrade, so it stays informational (in
        # the derived column above).
        if pol in ("exact", "exact2", "procrastinate"):
            rows.append((f"table6_reduce_{pol}_err", err,
                         f"max_abs_err_vs_f64, deterministic fixture "
                         f"({n}x{d} rows, {s} segments)"))


def table6c_algebra_ops(rows, *, smoke: bool = False):
    """The reduction algebra benchmarked: ``weighted_sum`` and
    ``moments`` on the table6 stream, every policy.

    The ops transform rows *above* the policy layer, so each cell should
    cost roughly its plain-sum sibling (moments ~2x: the [v | v*v]
    stream doubles the domain width).  ``_err`` rows pin the integer
    tiers to the f64 oracle — like ``table6_reduce_*_err`` they are
    bit-deterministic on the fixed fixture, so the baseline gate holds
    them exactly.
    """
    rng = np.random.RandomState(13)
    n, d, s = (1 << 10, 16, 8) if smoke else (1 << 14, 64, 32)
    x = (rng.randn(n, d) * 10 ** rng.uniform(-3, 3, (n, 1))) \
        .astype(np.float32)
    w = rng.uniform(-2.0, 2.0, n).astype(np.float32)
    ids = np.sort(rng.randint(0, s, n))
    x64, w64 = x.astype(np.float64), w.astype(np.float64)
    wref = np.zeros((s, d))
    np.add.at(wref, ids, x64 * w64[:, None])
    mref = np.zeros((s, 2, d))
    for seg in range(s):
        seg_rows = x64[ids == seg]
        if len(seg_rows):
            mref[seg, 0] = seg_rows.mean(0)
            mref[seg, 1] = seg_rows.var(0)
    vals, jids, jw = jnp.asarray(x), jnp.asarray(ids), jnp.asarray(w)
    for op, ref in (("weighted_sum", wref), ("moments", mref)):
        for pol in ("fast", "compensated", "exact", "exact2",
                    "procrastinate"):
            if op == "weighted_sum":
                fn = jax.jit(lambda v, i, ww, p=pol: repro.reduce(
                    v, segment_ids=i, num_segments=s, op="weighted_sum",
                    weights=ww, policy=p, backend="blocked"))
                args = (vals, jids, jw)
            else:
                fn = jax.jit(lambda v, i, p=pol: repro.reduce(
                    v, segment_ids=i, num_segments=s, op="moments",
                    policy=p, backend="blocked"))
                args = (vals, jids)
            us = _time(fn, *args)
            err = float(np.abs(np.asarray(fn(*args)) - ref).max())
            rows.append((f"table6_{op}_{pol}_us", us,
                         f"max_abs_err_vs_f64={err:.3e} "
                         f"({n}x{d} rows, {s} segments, blocked backend)"))
            if pol in ("exact", "exact2", "procrastinate"):
                rows.append((f"table6_{op}_{pol}_err", err,
                             f"max_abs_err_vs_f64, deterministic fixture "
                             f"({n}x{d} rows, {s} segments)"))


def table6b_large_n_resolution(rows, *, smoke: bool = False):
    """The shrinking-scale defect quantified: error vs f64 at growing N.

    Single-limb ``exact`` loses resolution as 1/N; ``exact2`` and
    ``procrastinate`` hold a flat error floor (the tentpole claim of the
    two-limb / exponent-bin tiers).
    """
    rng = np.random.RandomState(11)
    sizes = (1 << 12,) if smoke else (1 << 12, 1 << 16, 1 << 20)
    for n in sizes:
        x = rng.randn(n).astype(np.float32)
        ref = float(np.sum(x.astype(np.float64)))
        ulp = float(np.spacing(np.abs(np.float32(ref)), dtype=np.float32))
        xj = jnp.asarray(x)
        for pol in ("exact", "exact2", "procrastinate"):
            out = float(repro.reduce(xj, policy=pol, backend="blocked"))
            err = abs(out - ref)
            rows.append((f"table6b_resolution_n{n}_{pol}", err,
                         f"abs_err_vs_f64 ({err / ulp:.2f} ulp of the "
                         f"sum; standard-normal stream)"))


def table7_shard_scaling(rows, *, smoke: bool = False):
    """Multi-device scaling of the shard_map backend.

    Shards the same segmented stream across 1 / 2 / ... / all visible
    devices (CPU: simulate a fleet with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``), times each
    shard count against the single-device ``blocked`` schedule, and
    asserts the invariants inline: ``exact2`` and ``procrastinate``
    results (and ``exact2``'s canonical integer limbs) are bitwise
    identical at every shard count.  Inputs are **pre-sharded** onto each
    mesh before timing (``jax.device_put`` with the row sharding the
    backend would request) — otherwise every timed call re-lays-out
    device-0-resident arrays across the fleet, and the benchmark reports
    that host copy instead of the reduction; on this simulated-CPU box
    that once made 8 shards look 9x slower than 1.  Host wall-clock here
    still measures dispatch overhead more than speedup — the columns to
    read are ``bitwise`` and the *trend* (shardN should no longer grow
    with N now that staged prep runs in-shard and carry merges are one
    fused collective).
    """
    from jax.sharding import Mesh, NamedSharding, PartitionSpec

    from repro.core import intac
    from repro.reduce import get_backend, get_policy, mask_out_of_range

    devs = jax.devices()
    n, d, s = (1 << 15, 16, 8) if smoke else (1 << 16, 64, 32)
    rng = np.random.RandomState(23)
    vals = jnp.asarray(rng.randn(n, d).astype(np.float32))
    ids = jnp.asarray(rng.randint(0, s, n))
    counts = sorted({c for c in (1, 2, 4, 8, len(devs))
                     if c <= len(devs)})
    for pol in ("fast", "exact2", "procrastinate"):
        base_fn = jax.jit(lambda v, i, p=pol: repro.reduce(
            v, segment_ids=i, num_segments=s, policy=p, backend="blocked"))
        base = np.asarray(base_fn(vals, ids))
        us0 = _time(base_fn, vals, ids)
        rows.append((f"table7_{pol}_blocked_us", us0,
                     f"single-device baseline ({n}x{d} rows, {s} segments)"))
        for c in counts:
            mesh = Mesh(np.asarray(devs[:c]), ("shards",))
            sv = jax.device_put(
                vals, NamedSharding(mesh, PartitionSpec("shards", None)))
            si = jax.device_put(
                ids, NamedSharding(mesh, PartitionSpec("shards")))
            fn = jax.jit(lambda v, i, p=pol, m=mesh: repro.reduce(
                v, segment_ids=i, num_segments=s, policy=p,
                backend="shard_map", mesh=m))
            out = np.asarray(fn(sv, si))
            bitwise = bool(np.array_equal(base, out))
            if pol in ("exact2", "procrastinate"):
                # the tentpole invariant: all-integer carries make the
                # finalized float topology-independent, bit for bit
                assert bitwise, (pol, c)
            us = _time(fn, sv, si)
            rows.append((f"table7_{pol}_shard{c}_us", us,
                         f"bitwise_vs_blocked={bitwise} "
                         f"speedup_vs_1dev={us0 / us:.2f}x"))

    # ... and exact2's canonical int32 limbs bitwise at every shard count
    pol2 = get_policy("exact2")
    mids = mask_out_of_range(ids, s)
    domain, _ = pol2.prepare(jnp.where((mids >= 0)[:, None], vals, 0.0), n)
    cb = get_backend("blocked").run(domain, mids, s, policy=pol2)
    lb = [np.asarray(v) for v in intac.limbs_canonical(cb[0], cb[1])]
    for c in counts:
        mesh = Mesh(np.asarray(devs[:c]), ("shards",))
        csh = get_backend("shard_map").run(domain, mids, s, policy=pol2,
                                           mesh=mesh)
        lsh = intac.limbs_canonical(csh[0], csh[1])
        assert all(np.array_equal(a, np.asarray(b))
                   for a, b in zip(lb, lsh)), c
    rows.append(("table7_exact2_limbs_bitwise", 1.0,
                 f"canonical hi/lo limbs == blocked at shard counts "
                 f"{counts}"))


def table8_serving(rows, *, smoke: bool = False):
    """Sustained serving throughput and latency under a Poisson arrival
    trace through the continuous-batching engine (docs/serving.md).

    Requests (random prompts, staggered max_new_tokens) arrive with
    exponential inter-arrival gaps measured in engine steps; the engine
    juggles them through its fixed decode slots with chunked prefill and
    paged-KV admission.  One full warmup drain compiles the three model
    programs, then an identical trace is timed end to end.

    Rows (all ``_us`` so the baseline gate host-speed-normalizes them):
      table8_tok_us   wall-clock per *generated* token, the inverse of
                      sustained throughput (derived column shows tok/s);
      table8_p{50,95,99}_us   request latency percentiles, submission to
                      retirement (queue wait included).
    Inline asserts pin the serving contract while we time it: results
    deliver in submission order and echo their prompts.
    """
    from repro.configs import get_smoke_config
    from repro.models import init_params
    from repro.serve import Engine, Request

    cfg = get_smoke_config("stablelm-1.6b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, max_len=96, seed=0, max_batch=8)
    n = 16 if smoke else 64
    rng = np.random.RandomState(17)
    trace, t = [], 0.0
    for _ in range(n):
        t += float(rng.exponential(2.0))          # Poisson arrivals
        plen = int(rng.randint(1, 17))
        trace.append((Request(
            prompt=[int(x) for x in rng.randint(0, cfg.vocab, plen)],
            max_new_tokens=int(rng.randint(2, 9))), t))

    def drain():
        rids = [eng.submit(r, arrival=a) for r, a in trace]
        results = eng.run()
        assert [r.rid for r in results] == rids       # in-order delivery
        for (req, _), res in zip(trace, results):
            assert res.tokens[:res.prompt_len] == list(req.prompt)
        return results

    drain()                                       # warmup: compile + cache
    t0 = time.perf_counter()
    results = drain()
    elapsed = time.perf_counter() - t0
    new_tokens = sum(len(r.tokens) - r.prompt_len for r in results)
    tok_us = elapsed * 1e6 / max(new_tokens, 1)
    lat_us = np.asarray([r.latency_s for r in results]) * 1e6
    rows.append(("table8_tok_us", tok_us,
                 f"sustained {1e6 / tok_us:.0f} tok/s over {n} Poisson "
                 f"arrivals ({new_tokens} new tokens, max_batch=8)"))
    for pct in (50, 95, 99):
        rows.append((f"table8_p{pct}_us", float(np.percentile(lat_us, pct)),
                     f"request latency p{pct} (submission→retirement, "
                     f"queue wait included)"))


def table9_fault_overhead(rows, *, smoke: bool = False):
    """Cost of the robustness guard rails (docs/robustness.md).

    The same segmented exact2 reduction with and without
    ``with_status=True``.  ``with_status`` is a *static* jit argument, so
    the plain path traces none of the flag bookkeeping — the guarded
    timing bounds what the NaN scan + saturation pooling actually cost.
    Also asserts inline that the guarded result is bitwise the plain one
    and that a clean stream trips no flag (the machine-independent
    ``table9_clean_run_flags`` row pins that at 0.0).
    """
    rng = np.random.RandomState(31)
    n, d, s = (1 << 10, 16, 8) if smoke else (1 << 14, 64, 32)
    x = rng.randn(n, d).astype(np.float32)
    ids = np.sort(rng.randint(0, s, n))
    vals, jids = jnp.asarray(x), jnp.asarray(ids)
    plain = jax.jit(lambda v, i: repro.reduce(
        v, segment_ids=i, num_segments=s, policy="exact2",
        backend="blocked"))
    guarded = jax.jit(lambda v, i: repro.reduce(
        v, segment_ids=i, num_segments=s, policy="exact2",
        backend="blocked", with_status=True))
    out, st = guarded(vals, jids)
    assert np.array_equal(np.asarray(plain(vals, jids)), np.asarray(out))
    # guarded returns a (result, ReduceStatus) tuple, which _time's
    # trailing block_until_ready would skip — block the pytree explicitly
    # so both timings measure completed work
    us_plain = _time(lambda v, i: jax.block_until_ready(plain(v, i)),
                     vals, jids)
    us_guard = _time(lambda v, i: jax.block_until_ready(guarded(v, i)),
                     vals, jids)
    flags = float(bool(st.nonfinite) or bool(st.saturated)
                  or bool(st.degraded))
    rows.append(("table9_fault_overhead_us", us_guard,
                 f"with_status=True; plain={us_plain:.0f}us "
                 f"overhead={us_guard / max(us_plain, 1e-9):.2f}x "
                 f"({n}x{d} rows, {s} segments, exact2 blocked)"))
    rows.append(("table9_clean_run_flags", flags,
                 "nonfinite|saturated|degraded after a clean stream — "
                 "any guard-rail false positive fails the 0.0 baseline"))
