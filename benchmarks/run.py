"""Benchmark harness: one function per paper table + the roofline summary.

Prints ``name,value,derived`` CSV.  Cycle-level numbers come from the
cycle-accurate simulators (the paper's own metrics); wall-clock numbers are
CPU-host timings of the production JAX layer (relative comparisons only —
TPU roofline projections live in benchmarks/roofline.py).

    PYTHONPATH=src python -m benchmarks.run [--with-roofline] [--smoke]

The multi-device scaling table (table7) shards over however many devices
are visible; on CPU simulate a fleet first:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.run --smoke

Regression tracking (the ROADMAP "tracked regression table"): the smoke
numbers are pinned in ``benchmarks/baseline.json``.  CI runs

    ... python -m benchmarks.run --smoke --check-baseline

and fails on a >20% regression in any machine-independent row (schedule
cycle counts, the ``*_err`` accuracy rows, invariant flags — these are
bit-deterministic, so 20% is pure slack).  Wall-clock ``*_us`` rows are
first normalized by the host-speed factor (the median current/baseline
ratio across all ``*_us`` rows) and then held to a deliberately wide
noise band (``TIME_NOISE_FACTOR``): at smoke sizes, sharded dispatch on
simulated CPU devices jitters several-fold run to run, so the time gate
catches order-of-magnitude hot-path regressions, not 20% ones.  After an
intentional change, refresh the file with ``--write-baseline`` and
commit it.

``--check-baseline`` additionally enforces host-speed-independent
*ordering* invariants (``sanity_checks``): the fast tier strictly
cheaper than exact2 in table6, and table7 shard scaling not inverse
(shardN <= shard1 x ``SHARD_MONOTONE_TOL``).  Every ``--smoke`` run also
emits the staged block-program's analytic roofline to
``experiments/roofline/reduce_smoke.json`` (see
``roofline.reduce_program_table``).
"""

from __future__ import annotations

import argparse
import json
import statistics
import sys
from pathlib import Path

from benchmarks import paper_tables

BASELINE_PATH = Path(__file__).resolve().parent / "baseline.json"
#: fail threshold for machine-independent rows: >20% worse than baseline
REGRESSION_FACTOR = 1.2
#: absolute slack for near-zero deterministic rows (exact-tier errors)
REGRESSION_ATOL = 1e-12
#: wall-clock noise band (after host-speed normalization): smoke-size
#: timings on simulated devices jitter several-fold, so the time gate is
#: an order-of-magnitude tripwire, not a 20% one
TIME_NOISE_FACTOR = 4.0
#: table7 shard-scaling ratchet: time at N shards may exceed the 1-shard
#: time by at most this factor.  The real claim is "adding shards must
#: not make the reduction slower" — before inputs were pre-sharded and
#: carry merges fused, shard8 ran ~9x shard1; what remains at smoke
#: sizes is the per-device dispatch floor of simulating 8 devices on one
#: CPU core (~1.7x on the fast tier, whose whole reduction is sub-ms),
#: so the gate sits above that floor but far below the old pathology
SHARD_MONOTONE_TOL = 2.5


def sanity_checks(rows) -> list:
    """Relative-ordering invariants the baseline's per-row gates cannot
    see; return failure strings.

    These are *shape* claims about the current run, independent of host
    speed: the fast tier must actually be the cheap one (a fast tier
    slower than the all-int32 exact2 carry means the timing harness or
    the fast path itself regressed — the old async-dispatch mean once
    reported exactly that, 6421us vs 224us), and shard scaling must not
    be inverse (shardN beyond ``SHARD_MONOTONE_TOL`` x shard1 means
    per-call resharding or per-component collective overhead crept back
    into the distributed path).
    """
    current = {name: val for name, val, _ in rows}
    failures = []
    # every table6 family — the plain sum and the algebra ops riding the
    # same stream — must keep the fast tier cheaper than exact2
    for family in ("reduce", "weighted_sum", "moments"):
        fast = current.get(f"table6_{family}_fast_us")
        ex2 = current.get(f"table6_{family}_exact2_us")
        if fast is not None and ex2 is not None and fast >= ex2:
            failures.append(
                f"table6_{family}_fast_us ({fast:.1f}us) >= "
                f"table6_{family}_exact2_us ({ex2:.1f}us): the fast tier "
                f"must be cheaper than the 4-component integer carry")
    for pol in ("fast", "exact2"):
        s1 = current.get(f"table7_{pol}_shard1_us")
        if s1 is None:
            continue
        prefix = f"table7_{pol}_shard"
        for name, val in current.items():
            if (name.startswith(prefix) and name.endswith("_us")
                    and name != f"{prefix}1_us"
                    and val > s1 * SHARD_MONOTONE_TOL):
                failures.append(
                    f"{name}: {val:.1f}us > shard1 {s1:.1f}us x "
                    f"{SHARD_MONOTONE_TOL} (inverse shard scaling)")
    return failures


def check_baseline(rows, baseline: dict) -> list:
    """Compare ``rows`` against a baseline mapping; return failure strings.

    ``*_us`` rows are host-speed-normalized before the 20% gate; every
    other row (cycle counts, ``*_err`` accuracy rows, invariant flags) is
    machine-independent and gated directly.  Rows missing on either side
    are reported as failures too — the baseline must be refreshed
    (``--write-baseline``) in the same change that renames a benchmark.
    """
    current = {name: val for name, val, _ in rows}
    failures = [f"row {name!r} missing from baseline; refresh with "
                f"--write-baseline" for name in current
                if name not in baseline]
    failures += [f"baseline row {name!r} no longer produced; refresh "
                 f"with --write-baseline" for name in baseline
                 if name not in current]

    shared = [n for n in current if n in baseline]
    time_rows = [n for n in shared if n.endswith("_us")]
    ratios = [current[n] / baseline[n] for n in time_rows
              if baseline[n] > 0]
    speed = statistics.median(ratios) if ratios else 1.0

    for name in shared:
        cur, base = current[name], baseline[name]
        if name.endswith("_us"):
            limit = base * speed * TIME_NOISE_FACTOR
            if cur > limit:
                failures.append(
                    f"{name}: {cur:.1f}us > {limit:.1f}us "
                    f"(baseline {base:.1f}us x host-speed {speed:.2f} "
                    f"x {TIME_NOISE_FACTOR})")
        elif cur > base * REGRESSION_FACTOR + REGRESSION_ATOL:
            failures.append(f"{name}: {cur:.6g} > {base:.6g} "
                            f"x {REGRESSION_FACTOR}")
    return failures


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--with-roofline", action="store_true",
                    help="also rebuild the roofline table from "
                         "experiments/dryrun")
    ap.add_argument("--smoke", action="store_true",
                    help="quick CI subset: the schedule table and the "
                         "full five-policy sweep at reduced sizes")
    ap.add_argument("--write-baseline", action="store_true",
                    help="write the smoke numbers to benchmarks/"
                         "baseline.json (commit it)")
    ap.add_argument("--check-baseline", action="store_true",
                    help="fail (exit 1) on a >20% regression vs the "
                         "tracked benchmarks/baseline.json")
    args = ap.parse_args(argv)
    if (args.write_baseline or args.check_baseline) and not args.smoke:
        ap.error("--write-baseline/--check-baseline track the --smoke "
                 "subset; pass --smoke too")

    rows = []
    if args.smoke:
        paper_tables.table1_schedule(rows)
        paper_tables.table6_reduce_policies(rows, smoke=True)
        paper_tables.table6c_algebra_ops(rows, smoke=True)
        paper_tables.table6b_large_n_resolution(rows, smoke=True)
        paper_tables.table7_shard_scaling(rows, smoke=True)
        paper_tables.table8_serving(rows, smoke=True)
        paper_tables.table9_fault_overhead(rows, smoke=True)
    else:
        paper_tables.table1_schedule(rows)
        paper_tables.table2_pis_registers(rows)
        paper_tables.table3_accumulator_comparison(rows)
        paper_tables.table5_intac(rows)
        paper_tables.table6_reduce_policies(rows)
        paper_tables.table6c_algebra_ops(rows)
        paper_tables.table6b_large_n_resolution(rows)
        paper_tables.table7_shard_scaling(rows)
        paper_tables.table8_serving(rows)
        paper_tables.table9_fault_overhead(rows)

    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.6g},{derived}")

    if args.smoke:
        # the staged block-program's analytic roofline rides along with
        # every smoke run as a JSON artifact (pure analysis, no arrays)
        from benchmarks import roofline
        art_dir = Path("experiments/roofline")
        art_dir.mkdir(parents=True, exist_ok=True)
        rrows = roofline.reduce_program_table()
        art = art_dir / "reduce_smoke.json"
        art.write_text(json.dumps(rrows, indent=2) + "\n")
        print(f"roofline: wrote {len(rrows)} reduce-program rows to {art}")

    if args.write_baseline:
        BASELINE_PATH.write_text(json.dumps(
            {name: val for name, val, _ in rows}, indent=2,
            sort_keys=True) + "\n")
        print(f"baseline: wrote {len(rows)} rows to {BASELINE_PATH}")
    if args.check_baseline:
        if not BASELINE_PATH.exists():
            print(f"baseline: {BASELINE_PATH} missing; run with "
                  f"--write-baseline and commit it")
            sys.exit(1)
        baseline = json.loads(BASELINE_PATH.read_text())
        failures = check_baseline(rows, baseline) + sanity_checks(rows)
        if failures:
            print(f"baseline: {len(failures)} regression(s) vs "
                  f"{BASELINE_PATH.name}:")
            for f in failures:
                print(f"  {f}")
            sys.exit(1)
        print(f"baseline: {len(baseline)} rows within "
              f"{REGRESSION_FACTOR}x of {BASELINE_PATH.name}")

    if args.with_roofline and Path("experiments/dryrun").exists():
        from benchmarks import roofline
        rl = roofline.build_table("experiments/dryrun")
        print()
        print(roofline.to_markdown(rl))


if __name__ == "__main__":
    main()
