"""Benchmark harness: one function per paper table + the roofline summary.

Prints ``name,value,derived`` CSV.  Cycle-level numbers come from the
cycle-accurate simulators (the paper's own metrics); wall-clock numbers are
CPU-host timings of the production JAX layer (relative comparisons only —
TPU roofline projections live in benchmarks/roofline.py).

    PYTHONPATH=src python -m benchmarks.run [--with-roofline] [--smoke]

The multi-device scaling table (table7) shards over however many devices
are visible; on CPU simulate a fleet first:

    XLA_FLAGS=--xla_force_host_platform_device_count=8 \
        PYTHONPATH=src python -m benchmarks.run --smoke
"""

from __future__ import annotations

import argparse
from pathlib import Path

from benchmarks import paper_tables


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--with-roofline", action="store_true",
                    help="also rebuild the roofline table from "
                         "experiments/dryrun")
    ap.add_argument("--smoke", action="store_true",
                    help="quick CI subset: the schedule table and the "
                         "full five-policy sweep at reduced sizes")
    args = ap.parse_args(argv)

    rows = []
    if args.smoke:
        paper_tables.table1_schedule(rows)
        paper_tables.table6_reduce_policies(rows, smoke=True)
        paper_tables.table6b_large_n_resolution(rows, smoke=True)
        paper_tables.table7_shard_scaling(rows, smoke=True)
    else:
        paper_tables.table1_schedule(rows)
        paper_tables.table2_pis_registers(rows)
        paper_tables.table3_accumulator_comparison(rows)
        paper_tables.table5_intac(rows)
        paper_tables.table6_reduce_policies(rows)
        paper_tables.table6b_large_n_resolution(rows)
        paper_tables.table7_shard_scaling(rows)

    print("name,value,derived")
    for name, val, derived in rows:
        print(f"{name},{val:.6g},{derived}")

    if args.with_roofline and Path("experiments/dryrun").exists():
        from benchmarks import roofline
        rl = roofline.build_table("experiments/dryrun")
        print()
        print(roofline.to_markdown(rl))


if __name__ == "__main__":
    main()
