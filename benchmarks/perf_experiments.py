"""§Perf hillclimbing harness: compile a cell under named variants and
report the roofline-term deltas.

Each variant is a hypothesis about the dominant roofline term; the harness
re-lowers the cell with the change applied and prints before/after terms.
Results are logged to EXPERIMENTS.md §Perf by hand with the hypothesis and
verdict.

    PYTHONPATH=src python -m benchmarks.perf_experiments \
        --arch deepseek-7b --shape train_4k --variants baseline,no_sp
"""

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")

import argparse
import json
from pathlib import Path

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

import repro.launch.dryrun as dr
from repro.configs import get_config
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh
from repro.models.config import SHAPES_BY_NAME

from benchmarks import roofline as rl


def apply_variant(name: str):
    """Monkeypatch the distribution plan for one named variant.
    Returns a restore() callable."""
    orig_plan = shd.mesh_plan
    orig_param_specs = shd.param_specs
    orig_cache_specs = shd.cache_specs

    if name == "baseline":
        pass
    elif name == "no_sp":
        def plan(cfg, shape, mesh):
            p = orig_plan(cfg, shape, mesh)
            p["act_sp_axis"] = None
            return p
        shd.mesh_plan = plan
    elif name == "no_fsdp":
        def pspecs(cfg, params, *, fsdp_axis="data", replicate_all=False):
            return orig_param_specs(cfg, params, fsdp_axis=None,
                                    replicate_all=replicate_all)
        shd.param_specs = pspecs
    elif name == "no_sp_no_fsdp":
        def plan(cfg, shape, mesh):
            p = orig_plan(cfg, shape, mesh)
            p["act_sp_axis"] = None
            return p
        def pspecs(cfg, params, *, fsdp_axis="data", replicate_all=False):
            return orig_param_specs(cfg, params, fsdp_axis=None,
                                    replicate_all=replicate_all)
        shd.mesh_plan = plan
        shd.param_specs = pspecs
    elif name.startswith("no_sp_mb"):
        m = int(name.split("mb")[1])
        def plan(cfg, shape, mesh):
            p = orig_plan(cfg, shape, mesh)
            p["act_sp_axis"] = None
            return p
        shd.mesh_plan = plan
        dr.TRAIN_MICROBATCHES = m
    elif name == "cache_hd_sharded":
        # prefill/decode caches: shard head_dim on 'model' instead of the
        # sequence axis (avoids the batch->seq reshard of the cache output)
        def cspecs(cfg, caches, dp, *, seq_axes=("model",)):
            from repro.models import attention as attn
            base = orig_cache_specs(cfg, caches, dp, seq_axes=(None,))
            def fix(c):
                core = c["core"]
                if isinstance(core, attn.KVCache):
                    return {"core": attn.KVCache(
                        k=P(None, dp, None, None, "model"),
                        v=P(None, dp, None, None, "model"),
                        length=P(None, dp))}
                return c
            return [fix(c) for c in base]
        shd.cache_specs = cspecs
    elif name == "mixtral_best":
        # combined: (no-SP default) + mb8 + cf1.0 + bf16 expert combine
        import dataclasses
        import repro.configs as cfgs
        orig_get = cfgs.get_config
        def getc(arch):
            c = orig_get(arch)
            if c.moe:
                c = c.scaled(moe=dataclasses.replace(c.moe,
                                                     capacity_factor=1.0),
                             moe_bf16_combine=True)
            return c
        cfgs.get_config = getc
        dr.get_config = getc
        dr.TRAIN_MICROBATCHES = 8
    elif name == "mixtral_best4":
        import dataclasses
        import repro.configs as cfgs
        orig_get = cfgs.get_config
        def getc(arch):
            c = orig_get(arch)
            if c.moe:
                c = c.scaled(moe=dataclasses.replace(c.moe,
                                                     capacity_factor=1.0),
                             moe_bf16_combine=True)
            return c
        cfgs.get_config = getc
        dr.get_config = getc
        # mb stays at the plan default (4)
    elif name == "mixtral_vexp":
        # virtual experts: 8 experts x2 column shards = exact EP-16;
        # the expert-TP f32 partial AR disappears into the combine gather
        import dataclasses
        import repro.configs as cfgs
        orig_get = cfgs.get_config
        def getc(arch):
            c = orig_get(arch)
            if c.moe:
                c = c.scaled(moe=dataclasses.replace(c.moe,
                                                     capacity_factor=1.0),
                             moe_virtual_split=2)
            return c
        cfgs.get_config = getc
        dr.get_config = getc
    elif name == "ep_capacity_2x":
        import repro.models.moe as moe_mod
        moe_mod.MOE_GROUP_SAVED = moe_mod.MOE_GROUP
        # tighter capacity: cf 1.0 instead of 1.25 (fewer padded slots)
        import dataclasses
        import repro.configs as cfgs
        orig_get = cfgs.get_config
        def getc(arch):
            c = orig_get(arch)
            if c.moe:
                c = c.scaled(moe=dataclasses.replace(c.moe,
                                                     capacity_factor=1.0))
            return c
        cfgs.get_config = getc
        dr.get_config = getc
    else:
        raise ValueError(name)

    def restore():
        shd.mesh_plan = orig_plan
        shd.param_specs = orig_param_specs
        shd.cache_specs = orig_cache_specs
        dr.TRAIN_MICROBATCHES = 1

    return restore


def run(arch: str, shape_name: str, variants, out_dir: str):
    mesh = make_production_mesh()
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    out = Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    print(f"{'variant':18s} {'compute_s':>9s} {'mem_floor':>9s} "
          f"{'collect_s':>9s} {'temp_GB':>8s} {'AG_GB':>7s} {'AR_GB':>7s}")
    for name in variants:
        restore = apply_variant(name)
        try:
            rec = dr.run_cell(arch, shape_name, mesh,
                              f"hc_{name}", with_cost_variants=True)
            row = rl.analyze_cell(rec, cfg, shape)
            coll = rec["cost_extrapolated"]["collective_bytes"]
            print(f"{name:18s} {row['compute_s']:9.3f} "
                  f"{row['memory_s']:9.4f} {row['collective_s']:9.3f} "
                  f"{row['temp_gb']:8.1f} {coll['all-gather'] / 1e9:7.1f} "
                  f"{coll['all-reduce'] / 1e9:7.1f}")
            (out / f"{arch}__{shape_name}__{name}.json").write_text(
                json.dumps(rec, indent=1))
        except Exception as e:
            print(f"{name:18s} FAILED: {type(e).__name__}: {str(e)[:160]}")
        finally:
            restore()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--variants", default="baseline")
    ap.add_argument("--out", default="experiments/hillclimb")
    args = ap.parse_args()
    run(args.arch, args.shape, args.variants.split(","), args.out)


if __name__ == "__main__":
    main()
