"""Roofline analysis from the dry-run's compiled artifacts.

Per (arch × shape) on the single-pod mesh (256 × TPU v5e):

  compute term    = HLO_FLOPs / peak_FLOPs          (per device, 197 TF bf16)
  memory term     = HLO_bytes / HBM_bw              (per device, 819 GB/s)
  collective term = collective_bytes / link_bw      (per device, ~50 GB/s)

HLO numbers come from ``cost_extrapolated`` (depth-1/2 unrolled variants,
linearly extrapolated to full depth — XLA counts while bodies once, see
launch/dryrun.py).  The sLSTM per-timestep scan cannot be unrolled; its
missing flops/bytes are added analytically (documented below).

MODEL_FLOPS (the "useful" flop count):
  train:   6 * N_active * tokens   (fwd 2ND + bwd 4ND)
  prefill: 2 * N_active * tokens
  decode:  2 * N_active * batch    (+ attention cache read, in bytes)
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
LINK_BW = 50e9               # bytes/s / link (ICI)
CHIPS = 256


def _slstm_correction(cfg, shape, devices: int) -> dict:
    """Analytic correction for the sequential sLSTM scan (counted once by
    XLA): per step the cell does the recurrent matmul (B, d) @ (d, 4d)
    => 8*B*d^2 flops; (S-1) steps are missing; backward ~2x forward."""
    n_slstm = sum(1 for b in cfg.period if b.kind == "slstm") \
        * cfg.n_periods
    if n_slstm == 0:
        return {"flops": 0.0, "bytes": 0.0}
    d = cfg.d_model
    if shape.kind == "decode":
        return {"flops": 0.0, "bytes": 0.0}      # decode is one step anyway
    # xlstm trains pure-DP over the whole mesh (mesh_plan): batch/256 per
    # device; prefill keeps batch on the 16-way dp axis.
    b_loc = shape.global_batch / (256 if shape.kind == "train" else 16)
    mult = 3.0 if shape.kind == "train" else 1.0  # bwd ~ 2x fwd
    flops = n_slstm * b_loc * (shape.seq_len - 1) * 8 * d * d * mult
    # bytes: optimistic — recurrent weights stay VMEM-resident across steps
    return {"flops": flops, "bytes": 0.0}


def cache_bytes_total(cfg, shape) -> float:
    """Global KV/state cache bytes for a decode/prefill shape."""
    b, s = shape.global_batch, shape.seq_len
    per_layer = 0.0
    n_attn = sum(1 for sp in cfg.period if sp.kind == "attn") * cfg.n_periods
    if cfg.attn_type == "mla":
        per_tok = (cfg.kv_lora_rank + cfg.qk_rope_dim) * 2
    else:
        s_eff = min(cfg.window, s) if cfg.window else s
        per_tok = 2 * cfg.n_kv_heads * cfg.hdim * 2
        return n_attn * b * s_eff * per_tok
    return n_attn * b * s * per_tok


def analytic_bytes_floor(cfg, shape, devices: int = CHIPS,
                         model: int = 16) -> float:
    """Per-device HBM-traffic floor (perfect fusion): weights + optimizer +
    saved activations + caches + logits.  The HLO 'bytes accessed' number is
    the no-fusion *upper* bound; real TPU traffic lies between."""
    pc = cfg.param_counts()
    n_tot = pc["total"]
    d = cfg.d_model
    dp = devices // model
    if shape.kind == "train":
        b_loc = shape.global_batch / dp
        s_sp = shape.seq_len / model          # SP residual stream
        w = 3 * n_tot * 2 / model             # fwd + remat + bwd reads
        opt = 20 * n_tot / devices            # f32 m,v,p rw + grad
        act = 2 * cfg.n_layers * b_loc * s_sp * d * 2 * 2
        loss = b_loc * shape.seq_len * cfg.padded_vocab / model * 4 * 2
        return w + opt + act + loss
    if shape.kind == "prefill":
        b_loc = shape.global_batch / dp
        w = n_tot * 2 / model
        cache = cache_bytes_total(cfg, shape) / devices
        act = 2 * cfg.n_layers * b_loc * shape.seq_len * d * 2
        return w + cache + act
    # decode: weights once + full cache read
    w = n_tot * 2 / model
    cache = cache_bytes_total(cfg, shape) / devices
    return w + cache


def model_flops_per_device(cfg, shape, devices: int = CHIPS) -> float:
    n_active = cfg.param_counts()["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / devices
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / devices
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch / devices


def analyze_cell(rec: dict, cfg, shape) -> Optional[dict]:
    if rec.get("status") != "ok":
        return None
    cost = rec.get("cost_extrapolated") or {}
    flops = cost.get("flops", rec["cost_raw"].get("flops", 0.0))
    byts = cost.get("bytes", rec["cost_raw"].get("bytes accessed", 0.0))
    coll = (cost.get("collective_bytes") or
            rec.get("collectives_raw", {})).get("total", 0.0)
    corr = _slstm_correction(cfg, shape, rec.get("devices", CHIPS))
    flops += corr["flops"]
    byts += corr["bytes"]
    # microbatched train steps scan over microbatches: the body is counted
    # once by XLA, so per-step costs scale by the microbatch count
    # (optimizer/overhead slightly overcounted; <1% at these sizes).
    mb = rec.get("microbatches", 1)
    if mb > 1:
        flops *= mb
        byts *= mb
        coll = coll * mb

    t_c = flops / PEAK_FLOPS
    t_m_hlo = byts / HBM_BW               # no-fusion upper bound
    bytes_floor = analytic_bytes_floor(cfg, shape,
                                       rec.get("devices", CHIPS))
    t_m = bytes_floor / HBM_BW            # perfect-fusion floor
    t_x = coll / LINK_BW
    dominant = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))
    mf = model_flops_per_device(cfg, shape)
    total_t = max(t_c, t_m, t_x)
    return {
        "arch": rec["arch"], "shape": rec["shape"], "kind": shape.kind,
        "flops": flops, "bytes_hlo": byts, "bytes_floor": bytes_floor,
        "collective_bytes": coll,
        "compute_s": t_c, "memory_s": t_m, "memory_hlo_s": t_m_hlo,
        "collective_s": t_x,
        "dominant": dominant[1],
        "model_flops": mf,
        "useful_ratio": mf / flops if flops else 0.0,
        "roofline_fraction": (mf / PEAK_FLOPS) / total_t if total_t else 0.0,
        "temp_gb": rec.get("memory", {}).get("temp_size_in_bytes", 0) / 1e9,
        "slstm_corr_flops": corr["flops"],
    }


def build_table(dryrun_dir: str, mesh_tag: str = "single_pod_16x16"):
    from repro.configs import get_config
    from repro.models.config import SHAPES_BY_NAME

    rows = []
    for f in sorted(Path(dryrun_dir).glob(f"*__{mesh_tag}.json")):
        rec = json.loads(f.read_text())
        if rec.get("status") == "skipped":
            rows.append({"arch": rec["arch"], "shape": rec["shape"],
                         "dominant": "skipped",
                         "note": rec.get("reason", "")})
            continue
        cfg = get_config(rec["arch"])
        shape = SHAPES_BY_NAME[rec["shape"]]
        row = analyze_cell(rec, cfg, shape)
        if row:
            rows.append(row)
    return rows


def to_markdown(rows) -> str:
    hdr = ("| arch | shape | compute s | memory s (floor..hlo) | "
           "collective s | bottleneck | useful/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|\n")
    out = [hdr]
    for r in rows:
        if r.get("dominant") == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | — | — | — | "
                       f"skipped | — | — |\n")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} | "
            f"{r['memory_s']:.4f}..{r['memory_hlo_s']:.3f} | "
            f"{r['collective_s']:.4f} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_fraction']:.3f} |\n")
    return "".join(out)


def reduce_program_table(shapes=((512, 128, 64), (512, 128, 1024))):
    """Analytic roofline of the staged reduce block-program.

    For each (block_size, d, num_segments) shape and every registered
    accuracy policy, plan the staged program (``repro.reduce
    .plan_program``) and turn its declared per-block stage costs into
    roofline times: a stage takes ``max(bytes / HBM_BW, flops /
    PEAK_FLOPS)``.  Two derived columns quantify the two pipeline
    decisions this repo makes:

      * ``overlap_speedup`` — serial stage sum over max stage time: what
        double-buffering the gather against the carry update is worth
        when the stages are balanced (the JugglePAC overlap, at block
        granularity);
      * ``contrib`` — the planned gather form; at large ``num_segments``
        the integer tiers switch to the lane-parallel scatter because
        the one-hot dot's B*S*W flops would make the *memory-bound*
        stage compute-bound.

    Pure analysis — no arrays move; safe in any CI job.  The smoke
    harness (benchmarks/run.py --smoke) writes this table to
    ``experiments/roofline/reduce_smoke.json``.
    """
    from repro.reduce import get_policy, plan_program
    from repro.reduce.policy import POLICIES

    rows = []
    for block_size, d, s in shapes:
        for name in sorted(POLICIES):
            pol = get_policy(name)
            w = pol.domain_width(d)
            prog = plan_program(pol, num_segments=s, domain_width=w,
                                block_size=block_size)
            stages = {}
            for st in prog.stages:
                stages[st.name] = {
                    "bytes": st.bytes, "flops": st.flops,
                    "bound": st.bound,
                    "s": max(st.bytes / HBM_BW, st.flops / PEAK_FLOPS)}
            serial = sum(v["s"] for v in stages.values())
            pipelined = max(v["s"] for v in stages.values())
            rows.append({
                "policy": name, "contrib": prog.contrib,
                "block_size": block_size, "d": d, "num_segments": s,
                "domain_width": w, "stages": stages,
                "serial_s": serial, "pipelined_s": pipelined,
                "overlap_speedup": serial / pipelined if pipelined else 1.0,
            })
    return rows


def to_csv(rows) -> str:
    cols = ("arch", "shape", "kind", "flops", "bytes_floor", "bytes_hlo",
            "collective_bytes", "compute_s", "memory_s", "memory_hlo_s",
            "collective_s", "dominant", "model_flops", "useful_ratio",
            "roofline_fraction", "temp_gb")
    lines = [",".join(cols)]
    for r in rows:
        if r.get("dominant") == "skipped":
            continue
        lines.append(",".join(str(r.get(c, "")) for c in cols))
    return "\n".join(lines) + "\n"


def main():
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun-dir", default="experiments/dryrun")
    ap.add_argument("--out", default="experiments/roofline")
    args = ap.parse_args()
    rows = build_table(args.dryrun_dir)
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    (out / "roofline.md").write_text(to_markdown(rows))
    (out / "roofline.csv").write_text(to_csv(rows))
    print(to_markdown(rows))


if __name__ == "__main__":
    main()
