"""Continuous-batching tour: a Poisson arrival trace through the engine.

Shows the pieces docs/serving.md describes, end to end on CPU:

  1. requests arrive mid-stream (Poisson gaps) and are admitted into
     freed decode slots while earlier requests are still generating;
  2. one request is cancelled mid-decode — its KV pages return to the
     pool immediately, its batchmates don't notice;
  3. results are delivered strictly in submission order (reorder buffer)
     with per-request latency and finish reason;
  4. with ``logprob_policy="exact2"`` a request's mean_logprob is
     bitwise identical whether it runs alone or inside the trace.

Run:  PYTHONPATH=src python examples/serve_trace.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serve import Engine, Request


def main():
    cfg = get_smoke_config("stablelm-1.6b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params, max_len=96, seed=0, max_batch=4,
                    logprob_policy="exact2")
    print(f"engine: {engine.max_batch} slots, {engine.pool}")

    # --- 1. a Poisson arrival trace ---------------------------------------
    rng = np.random.default_rng(7)
    trace, t = [], 0.0
    for _ in range(10):
        t += float(rng.exponential(2.0))
        trace.append((Request(
            prompt=[int(x) for x in rng.integers(1, cfg.vocab,
                                                 rng.integers(2, 14))],
            max_new_tokens=int(rng.integers(3, 10))), t))
    rids = [engine.submit(r, arrival=a) for r, a in trace]

    # --- 2. kill whatever is mid-decode at step 12 ------------------------
    killed = {}

    def chaos(eng, step):
        if step == 12 and not killed:
            decoding = eng.scheduler.in_state("decode")
            if decoding:
                victim = decoding[-1].rid
                before = eng.pool.free_pages
                eng.cancel(victim)
                killed["rid"] = victim
                print(f"  step {step}: cancelled rid {victim} mid-decode — "
                      f"{eng.pool.free_pages - before} pages back in the "
                      f"pool")

    # --- 3. drain; results arrive in submission order ---------------------
    results = engine.run(on_step=chaos)
    assert [r.rid for r in results] == rids
    for (req, a), res in zip(trace, results):
        lp = "None" if res.mean_logprob is None else f"{res.mean_logprob:+.4f}"
        print(f"  rid {res.rid} (arrival {a:5.1f}): "
              f"+{len(res.tokens) - res.prompt_len:2d} tokens  "
              f"finish={res.finish_reason:<9s} mean_logprob={lp}  "
              f"latency={res.latency_s * 1e3:.0f}ms")

    # --- 4. exact2: composition-invariant to the bit ----------------------
    probe = Request(prompt=[5, 6, 7, 8], max_new_tokens=6)
    alone = engine.generate([probe])[0].mean_logprob
    in_traffic = engine.generate([trace[0][0], probe, trace[1][0]])[1]
    same = np.float32(alone).tobytes() == \
        np.float32(in_traffic.mean_logprob).tobytes()
    print(f"exact2 mean_logprob alone vs in-traffic: {alone:+.7f} vs "
          f"{in_traffic.mean_logprob:+.7f}  bitwise_equal={same}")
    assert same


if __name__ == "__main__":
    main()
