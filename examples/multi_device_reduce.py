"""Multi-device reduction through the front door: the shard_map backend.

Simulates an 8-device fleet on CPU (the XLA host-platform trick — the
env var must be set before jax initializes), streams one segmented
reduction through ``backend="shard_map"`` at 1/2/8 shards, and asserts
the tentpole invariant: the integer tiers (here ``exact2``) reproduce
the single-device ``blocked`` schedule **bit for bit** at every shard
count, even with uneven shards.  The float tiers keep tolerance, not
bits — the demo prints both.

    PYTHONPATH=src python examples/multi_device_reduce.py
"""

import os
import sys
from pathlib import Path

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402
from jax.sharding import Mesh                                 # noqa: E402

import repro                                                  # noqa: E402


def main():
    devs = jax.devices()
    print(f"devices: {len(devs)} x {devs[0].platform}")

    # uneven on purpose: 10_007 rows never divide evenly into 8 shards of
    # 512-row blocks — the backend pads with OUT_OF_RANGE_LABEL rows,
    # which drop out of every sum and count
    rng = np.random.RandomState(0)
    n, d, s = 10_007, 32, 5
    vals = jnp.asarray(rng.randn(n, d).astype(np.float32))
    ids = jnp.asarray(rng.randint(0, s, n))

    base = {p: np.asarray(repro.reduce(vals, segment_ids=ids,
                                       num_segments=s, policy=p,
                                       backend="blocked"))
            for p in ("fast", "exact2")}

    print(f"\n{n} rows x {d} features -> {s} segments; "
          f"single-device 'blocked' schedule is the reference")
    for nshards in (1, 2, 8):
        mesh = Mesh(np.asarray(devs[:nshards]), ("shards",))
        for pol in ("fast", "exact2"):
            out = np.asarray(repro.reduce(vals, segment_ids=ids,
                                          num_segments=s, policy=pol,
                                          backend="shard_map", mesh=mesh))
            bitwise = np.array_equal(base[pol], out)
            maxdiff = float(np.abs(base[pol] - out).max())
            print(f"  shards={nshards}  policy={pol:7s}  "
                  f"bitwise={str(bitwise):5s}  max|diff|={maxdiff:.2e}")
            if pol == "exact2":
                assert bitwise, "exact2 must reproduce single-device bits"
            else:
                assert maxdiff <= 1e-5 * float(np.abs(base[pol]).max())

    # auto-selection: an active multi-device mesh is enough — no backend
    # argument, no mesh argument
    with Mesh(np.asarray(devs), ("shards",)):
        auto = np.asarray(repro.reduce(vals, segment_ids=ids,
                                       num_segments=s, policy="exact2"))
    assert np.array_equal(auto, base["exact2"])
    print("\nauto-selection under `with mesh:` picked shard_map and "
          "reproduced the single-device bits — scaling out is a context "
          "manager, not a rewrite")


if __name__ == "__main__":
    main()
