"""Multi-device reduction through the front door: the shard_map backend.

Simulates an 8-device fleet on CPU (the XLA host-platform trick — the
env var must be set before jax initializes), streams one segmented
reduction through ``backend="shard_map"`` at 1/2/8 shards, and asserts
the invariants: ``procrastinate`` reproduces the single-device
``blocked`` schedule **bit for bit** at every shard count, even with
uneven shards; ``exact2`` reproduces the *canonical int32 limbs* bit for
bit while its finalized float — which folds the exactly-captured
quantization-residual limb in device order — stays at ulp-level
agreement.  The float tiers keep tolerance, not bits — the demo prints
all of it.

    PYTHONPATH=src python examples/multi_device_reduce.py
"""

import os
import sys
from pathlib import Path

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax                                                    # noqa: E402
import jax.numpy as jnp                                       # noqa: E402
import numpy as np                                            # noqa: E402
from jax.sharding import Mesh                                 # noqa: E402

import repro                                                  # noqa: E402
from repro import reduce as R                                 # noqa: E402
from repro.core import intac                                  # noqa: E402


def main():
    devs = jax.devices()
    print(f"devices: {len(devs)} x {devs[0].platform}")

    # uneven on purpose: 10_007 rows never divide evenly into 8 shards of
    # 512-row blocks — the backend pads with OUT_OF_RANGE_LABEL rows,
    # which drop out of every sum and count
    rng = np.random.RandomState(0)
    n, d, s = 10_007, 32, 5
    vals = jnp.asarray(rng.randn(n, d).astype(np.float32))
    ids = jnp.asarray(rng.randint(0, s, n))

    base = {p: np.asarray(repro.reduce(vals, segment_ids=ids,
                                       num_segments=s, policy=p,
                                       backend="blocked"))
            for p in ("fast", "exact2", "procrastinate")}

    # exact2's limb-level reference: the canonical int32 hi/lo pair out
    # of the single-device schedule
    pol2 = R.get_policy("exact2")
    mids = R.mask_out_of_range(ids, s)
    domain, _ = pol2.prepare(jnp.where((mids >= 0)[:, None], vals, 0.0), n)
    cb = R.get_backend("blocked").run(domain, mids, s, policy=pol2)
    limbs_base = [np.asarray(v)
                  for v in intac.limbs_canonical(cb[0], cb[1])]

    print(f"\n{n} rows x {d} features -> {s} segments; "
          f"single-device 'blocked' schedule is the reference")
    for nshards in (1, 2, 8):
        mesh = Mesh(np.asarray(devs[:nshards]), ("shards",))
        for pol in ("fast", "exact2", "procrastinate"):
            out = np.asarray(repro.reduce(vals, segment_ids=ids,
                                          num_segments=s, policy=pol,
                                          backend="shard_map", mesh=mesh))
            bitwise = np.array_equal(base[pol], out)
            maxdiff = float(np.abs(base[pol] - out).max())
            line = (f"  shards={nshards}  policy={pol:13s}  "
                    f"bitwise={str(bitwise):5s}  max|diff|={maxdiff:.2e}")
            if pol == "procrastinate":
                assert bitwise, "procrastinate must reproduce the bits"
            elif pol == "exact2":
                csh = R.get_backend("shard_map").run(
                    domain, mids, s, policy=pol2, mesh=mesh)
                limbs_ok = all(
                    np.array_equal(a, np.asarray(b)) for a, b in
                    zip(limbs_base, intac.limbs_canonical(csh[0], csh[1])))
                assert limbs_ok, "exact2 limbs must reproduce the bits"
                assert maxdiff <= 1e-6 * float(np.abs(base[pol]).max())
                line += f"  limbs_bitwise={limbs_ok}"
            else:
                assert maxdiff <= 1e-5 * float(np.abs(base[pol]).max())
            print(line)

    # auto-selection: an active multi-device mesh is enough — no backend
    # argument, no mesh argument
    with Mesh(np.asarray(devs), ("shards",)):
        auto = np.asarray(repro.reduce(vals, segment_ids=ids,
                                       num_segments=s,
                                       policy="procrastinate"))
    assert np.array_equal(auto, base["procrastinate"])
    print("\nauto-selection under `with mesh:` picked shard_map and "
          "reproduced the single-device bits — scaling out is a context "
          "manager, not a rewrite")


if __name__ == "__main__":
    main()
