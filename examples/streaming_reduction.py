"""The paper's technique, end to end.

  1. cycle-accurate JugglePAC: variable-length back-to-back sets through a
     single L=14 pipelined adder, in-order results (prints the schedule);
  2. INTAC: integer carry-save accumulation, exact, Eq.1 latency;
  3. the production mirror: JugglePAC segmented-sum Pallas kernel for MoE
     combine / variable-resolution pooling, INTAC deterministic gradient
     reduction with error feedback.

    PYTHONPATH=src python examples/streaming_reduction.py
"""

import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.core.circuit import INTAC, JugglePAC
from repro.core.segmented import segments_from_lengths


def main():
    # --- 1: the circuit -----------------------------------------------------
    print("=== JugglePAC (L=14, 4 PIS registers) ===")
    pac = JugglePAC(adder_latency=14, num_registers=4)
    sizes = [40, 29, 64, 33]
    sets = [[float(i * 1000 + j) for j in range(n)]
            for i, n in enumerate(sizes)]
    res = pac.run(sets)
    for r in res:
        print(f"  set {r.set_index} (n={sizes[r.set_index]}): "
              f"sum={r.value:.0f} emitted@cycle {r.cycle} "
              f"(latency {r.latency} = n+{r.latency - sizes[r.set_index]})")
    print(f"  adder issues: {len(pac.adder_issue_log)} over "
          f"{pac.cycle} cycles; FIFO overflows: {pac.fifo_overflows}")

    # --- 2: INTAC ------------------------------------------------------------
    print("=== INTAC (64b in, 128b out) ===")
    vals = [int(v) for v in
            np.random.default_rng(0).integers(0, 2 ** 62, 200)]
    for fas in (1, 16):
        it = INTAC(64, 128, 1, fas)
        r = it.accumulate(vals)
        ok = r.value == sum(int(v) for v in vals) % (1 << 128)
        print(f"  FAs={fas:2d}: exact={ok} latency={r.cycle} "
              f"(Eq.1: {INTAC.latency_eq1(len(vals), 1, 128, fas)})")

    # --- 3: production mirror, via the repro.reduce front door ---------------
    print("=== production: repro.reduce — one call, policy x backend ===")
    lens = jnp.asarray([100, 1, 399, 250, 274])   # variable-length sets
    total = int(lens.sum())
    vals = jnp.asarray(np.random.default_rng(1)
                       .normal(size=(total, 128)).astype(np.float32))
    ids = segments_from_lengths(lens, total)
    ref = jnp.zeros((5, 128)).at[ids].add(vals)
    outs = {b: repro.reduce(vals, segment_ids=ids, num_segments=5, backend=b)
            for b in ("ref", "blocked", "pallas")}
    bitwise = all(bool(jnp.array_equal(outs["ref"], o))
                  for o in outs.values())
    print(f"  segmented sum, 3 backends bitwise-identical: {bitwise}; "
          f"vs scatter oracle max|diff| = "
          f"{float(jnp.abs(outs['blocked'] - ref).max()):.2e}")

    x = jnp.asarray(np.random.default_rng(2)
                    .normal(size=100000).astype(np.float32))
    s64 = float(np.sum(np.asarray(x, np.float64)))
    for pol in ("fast", "compensated", "exact", "exact2", "procrastinate"):
        a = float(repro.reduce(x, policy=pol))
        b = float(repro.reduce(x[::-1], policy=pol))
        print(f"  policy={pol:13s} sum={a:.6f} reversed={b:.6f} "
              f"bitwise equal: {a == b}  |err vs f64|={abs(a - s64):.2e}")
    s1 = float(jnp.sum(x))
    print(f"  jnp.sum for reference: {s1} (order-dependent in general);")
    print("  note exact's 1/N scale visibly drifts at N=1e5 — exact2 and")
    print("  procrastinate hold <=1 ulp at any length (exact2's residual")
    print("  limb re-folds under reversal: ulp tolerance, bitwise limbs)")


if __name__ == "__main__":
    main()
