"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps.

The model is a llama-family config at ~97M params (12L, d=768, 12 heads,
d_ff=2048, 8k vocab).  On a TPU slice this is minutes; on this CPU
container a full 300-step run is hours, so ``--steps`` defaults low and the
checkpoint/restart machinery means the run can be resumed incrementally:

    PYTHONPATH=src python examples/train_100m.py --steps 25
    PYTHONPATH=src python examples/train_100m.py --steps 50   # resumes @25

EXPERIMENTS.md records the verification runs (loss curve, restart drill).
"""

import argparse
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import repro.configs as configs
from repro.models.config import BlockSpec, ModelConfig

CONFIG_100M = ModelConfig(
    name="repro-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=12,
    d_ff=2048,
    vocab=8192,
    period=(BlockSpec("attn", "swiglu"),),
    dtype="float32",
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=25)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_100m_ckpt")
    ap.add_argument("--lr", type=float, default=6e-4)
    args = ap.parse_args()

    print(f"repro-100m: {CONFIG_100M.param_counts()['total'] / 1e6:.1f}M "
          f"params")
    # register it so the standard launcher drives everything
    configs._MODULES["repro-100m"] = None
    configs.get_config = _wrap(configs.get_config)
    configs.get_smoke_config = _wrap(configs.get_smoke_config)

    from repro.launch import train as train_mod
    train_mod.get_config = configs.get_config
    train_mod.get_smoke_config = configs.get_smoke_config
    train_mod.main([
        "--arch", "repro-100m", "--steps", str(args.steps),
        "--batch", str(args.batch), "--seq", str(args.seq),
        "--lr", str(args.lr), "--warmup", "20",
        "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "10",
        "--log-every", "5",
    ])


def _wrap(fn):
    def inner(arch):
        if arch == "repro-100m":
            return CONFIG_100M
        return fn(arch)
    return inner


if __name__ == "__main__":
    main()
