"""Quickstart: the whole stack in one minute on CPU.

  1. instantiate a reduced llama-family config;
  2. train it for 20 steps on the synthetic stream (loss drops);
  3. generate from it with the batched serving engine;
  4. demo the paper's primitives through the ``repro.reduce`` front door:
     JugglePAC cycle-accurate schedule, segmented reduction across
     backends, INTAC-exact deterministic summation as a policy.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.configs import get_smoke_config
from repro.core.circuit import JugglePAC
from repro.data.pipeline import DataCfg, SyntheticLM
from repro.models import init_params
from repro.optim import adamw
from repro.serve.engine import Engine, Request
from repro.train.steps import make_train_step


def main():
    # --- 1-2: train a tiny LM ---------------------------------------------
    cfg = get_smoke_config("stablelm-1.6b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    src = SyntheticLM(DataCfg(vocab=cfg.vocab, seq_len=64, global_batch=4,
                              seed=0))
    step = jax.jit(make_train_step(
        cfg, lr_fn=adamw.cosine_schedule(3e-3, 5, 20), remat=False,
        moe_impl="dense"))
    for i in range(20):
        batch = {k: jnp.asarray(v) for k, v in src.batch(i).items()}
        params, opt, m = step(params, opt, batch)
        if i % 5 == 0 or i == 19:
            print(f"train step {i:3d}  loss {float(m['loss']):.4f}")

    # --- 3: serve it --------------------------------------------------------
    engine = Engine(cfg, params, max_len=96)
    res = engine.generate([Request(prompt=[5, 6, 7], max_new_tokens=8),
                           Request(prompt=[42, 1], max_new_tokens=8,
                                   temperature=0.7)])
    for i, r in enumerate(res):
        print(f"generated[{i}]: {r.tokens[r.prompt_len:]} "
              f"mean_logprob={r.mean_logprob:.3f}")

    # --- 4: the paper's primitives, via the repro.reduce front door --------
    pac = JugglePAC(adder_latency=14, num_registers=4)
    sets = [[float(j) for j in range(n)] for n in (40, 35, 50)]
    results = pac.run(sets)
    print("JugglePAC:",
          [(r.set_index, r.value, f"latency={r.latency}") for r in results])

    vals = jnp.asarray(np.random.randn(512, 64).astype(np.float32))
    ids = jnp.sort(jnp.asarray(np.random.randint(0, 9, 512)))
    seg = repro.reduce(vals, segment_ids=ids, num_segments=9)
    seg_k = repro.reduce(vals, segment_ids=ids, num_segments=9,
                         backend="pallas")
    print("segmented sum (9 variable-length sets):", seg.shape,
          "| auto == pallas kernel bitwise:",
          bool(jnp.array_equal(seg, seg_k)))

    x = jnp.asarray(np.random.randn(1000).astype(np.float32))
    fwd = float(repro.reduce(x, policy="exact"))
    rev = float(repro.reduce(x[::-1], policy="exact"))
    print("exact-policy deterministic sum:", fwd, "== reversed:", rev)


if __name__ == "__main__":
    main()
