"""Batched serving example: continuous-batch generation with mixed prompt
lengths, greedy + sampled requests, eos stopping.

    PYTHONPATH=src python examples/serve_batched.py [--arch stablelm-1.6b]
"""

import argparse
import sys
import time
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parent.parent / "src"))

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--batch", type=int, default=6)
    ap.add_argument("--new-tokens", type=int, default=24)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params, max_len=256)

    rng = np.random.default_rng(0)
    reqs = []
    for i in range(args.batch):
        plen = int(rng.integers(3, 48))
        reqs.append(Request(
            prompt=list(rng.integers(1, cfg.vocab, plen)),
            max_new_tokens=args.new_tokens,
            temperature=0.0 if i % 2 == 0 else 0.8,
            eos_id=int(rng.integers(1, cfg.vocab)) if i % 3 == 0 else None))

    t0 = time.time()
    results = engine.generate(reqs)
    dt = time.time() - t0
    new = sum(len(r.tokens) - r.prompt_len for r in results)
    for i, r in enumerate(results):
        mode = "greedy" if reqs[i].temperature == 0 else "t=0.8"
        print(f"req{i} ({mode}, prompt={r.prompt_len:2d}): "
              f"+{len(r.tokens) - r.prompt_len} -> "
              f"{r.tokens[r.prompt_len:r.prompt_len + 10]}")
    print(f"\n{new} tokens in {dt:.2f}s = {new / dt:.1f} tok/s "
          f"(batched, CPU smoke config)")


if __name__ == "__main__":
    main()
