import sys
import types

import pytest


# ---------------------------------------------------------------------------
# Optional-dependency guard: the suite must collect and run everywhere.
#
# Property tests use hypothesis; when it is absent we install a minimal
# stub so `from hypothesis import given, settings, strategies as st` still
# imports, and every @given-decorated test is collected as *skipped*
# (plain tests in the same modules run normally).
# ---------------------------------------------------------------------------

HYPOTHESIS_SKIP_REASON = ("hypothesis not installed; property test "
                          "skipped — install the [dev] extra "
                          "(pip install -e '.[dev]') to run it")

try:
    import hypothesis  # noqa: F401
except ImportError:
    def _given_stub(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(reason=HYPOTHESIS_SKIP_REASON)(fn)
        return deco

    def _settings_stub(*_args, **_kwargs):
        if _args and callable(_args[0]) and len(_args) == 1 and not _kwargs:
            return _args[0]              # bare @settings usage
        return lambda fn: fn

    class _StubStrategy:
        # real strategies support chained combinators (.map/.filter/...)
        # called at module scope while building @given arguments — the
        # stub must absorb any such chain, or every property module
        # using them would crash at collection and its plain tests
        # would silently vanish with it
        def map(self, *_args, **_kwargs):
            return self

        filter = flatmap = map

        def example(self, *_args, **_kwargs):
            return None

    def _strategy_stub(*_args, **_kwargs):
        return _StubStrategy()

    def _composite_stub(fn):
        # real @st.composite wraps a function that is then *called* at
        # module scope to build strategies — same survival requirement
        return lambda *_args, **_kwargs: _StubStrategy()

    def _decorator_stub(*_args, **_kwargs):
        return lambda fn: fn             # @example(...) / @seed(...)

    def _noop(*_args, **_kwargs):
        return None                      # assume(...) / note(...)

    _st = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "booleans", "lists", "tuples",
                  "sampled_from", "text", "just", "one_of", "none",
                  "builds", "dictionaries", "sets", "permutations",
                  "data"):
        setattr(_st, _name, _strategy_stub)
    _st.composite = _composite_stub

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given_stub
    _hyp.settings = _settings_stub
    _hyp.strategies = _st
    _hyp.assume = _noop
    _hyp.note = _noop
    _hyp.example = _decorator_stub
    _hyp.seed = _decorator_stub
    _hyp.HealthCheck = types.SimpleNamespace(too_slow=None,
                                             data_too_large=None,
                                             function_scoped_fixture=None)
    _hyp.__stub__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


def pytest_report_header(config):
    if getattr(sys.modules.get("hypothesis"), "__stub__", False):
        return ("hypothesis: NOT INSTALLED — @given property tests (e.g. "
                "tests/test_algebra_props.py) are collected as skipped; "
                "their fixed-example twins still run")
    return None


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="run slow (subprocess / multi-device) tests")


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: subprocess / multi-device")
    config.addinivalue_line(
        "markers",
        "faults: fault-injection robustness suite (tests/test_faults.py)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip = pytest.mark.skip(reason="needs --runslow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
