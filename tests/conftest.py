import sys
import types

import pytest


# ---------------------------------------------------------------------------
# Optional-dependency guard: the suite must collect and run everywhere.
#
# Property tests use hypothesis; when it is absent we install a minimal
# stub so `from hypothesis import given, settings, strategies as st` still
# imports, and every @given-decorated test is collected as *skipped*
# (plain tests in the same modules run normally).
# ---------------------------------------------------------------------------

try:
    import hypothesis  # noqa: F401
except ImportError:
    def _given_stub(*_args, **_kwargs):
        def deco(fn):
            return pytest.mark.skip(
                reason="hypothesis not installed; property test skipped"
            )(fn)
        return deco

    def _settings_stub(*_args, **_kwargs):
        if _args and callable(_args[0]) and len(_args) == 1 and not _kwargs:
            return _args[0]              # bare @settings usage
        return lambda fn: fn

    def _strategy_stub(*_args, **_kwargs):
        return None

    _st = types.ModuleType("hypothesis.strategies")
    for _name in ("integers", "floats", "booleans", "lists", "tuples",
                  "sampled_from", "text", "composite", "just", "one_of"):
        setattr(_st, _name, _strategy_stub)

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = _given_stub
    _hyp.settings = _settings_stub
    _hyp.strategies = _st
    _hyp.HealthCheck = types.SimpleNamespace(too_slow=None,
                                             data_too_large=None)
    _hyp.__stub__ = True
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


def pytest_addoption(parser):
    parser.addoption("--runslow", action="store_true", default=False,
                     help="run slow (subprocess / multi-device) tests")


def pytest_configure(config):
    config.addinivalue_line("markers", "slow: subprocess / multi-device")
    config.addinivalue_line(
        "markers",
        "faults: fault-injection robustness suite (tests/test_faults.py)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip = pytest.mark.skip(reason="needs --runslow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip)
