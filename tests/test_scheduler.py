"""Units for the serving control plane: PagedKVPool + Scheduler.

Host-side only (no jax): the pool's alloc/extend/free bookkeeping and the
scheduler's arrival queue, FIFO admission gated on pages, and reorder
buffer (in-order delivery regardless of finish order).
"""

import pytest

from repro.serve import (FREE_PAGE, PagedKVPool, PoolExhausted, Scheduler)


# ---------------------------------------------------------------------------
# PagedKVPool
# ---------------------------------------------------------------------------


def test_pool_pages_for_rounds_up():
    pool = PagedKVPool(num_pages=8, page_size=16)
    assert pool.pages_for(1) == 1
    assert pool.pages_for(16) == 1
    assert pool.pages_for(17) == 2
    assert pool.pages_for(0) == 1          # every request owns >= 1 page


def test_pool_alloc_free_roundtrip():
    pool = PagedKVPool(num_pages=8, page_size=16)
    pages = pool.alloc(0, 40)              # 3 pages
    assert pages == [0, 1, 2]              # deterministic low-first ids
    assert pool.free_pages == 5
    assert pool.live_requests == 1
    assert pool.owns(0) and not pool.owns(1)
    assert pool.free(0) == 3
    assert pool.free_pages == 8
    assert pool.free(0) == 0               # double-free is a no-op


def test_pool_extend_grows_reservation():
    pool = PagedKVPool(num_pages=4, page_size=16)
    pool.alloc(7, 16)                      # 1 page
    assert pool.extend(7, 20) == [1]       # grows to 2 total
    assert pool.extend(7, 20) == []        # already covered
    assert pool.pages_of(7) == [0, 1]
    with pytest.raises(KeyError):
        pool.extend(99, 16)


def test_pool_exhaustion_and_double_alloc():
    pool = PagedKVPool(num_pages=2, page_size=16)
    pool.alloc(0, 32)
    assert not pool.can_alloc(1)
    with pytest.raises(PoolExhausted, match="needs 1 pages"):
        pool.alloc(1, 1)
    with pytest.raises(ValueError, match="already holds"):
        pool.alloc(0, 1)


def test_pool_page_table_padding():
    pool = PagedKVPool(num_pages=8, page_size=16)
    pool.alloc(3, 33)                      # 3 pages
    table = pool.page_table(3, max_pages=6)
    assert table.tolist() == [0, 1, 2, FREE_PAGE, FREE_PAGE, FREE_PAGE]
    assert table.dtype.name == "int32"
    with pytest.raises(ValueError, match="max_pages"):
        pool.page_table(3, max_pages=2)
    assert pool.page_table(99).tolist() == []   # unknown rid: empty table


def test_pool_recycles_pages():
    pool = PagedKVPool(num_pages=4, page_size=16)
    a = pool.alloc(0, 32)
    pool.free(0)
    b = pool.alloc(1, 32)
    assert a == b                          # LIFO recycling, hot pages reused


def test_pool_rejects_bad_sizes():
    with pytest.raises(ValueError, match="positive"):
        PagedKVPool(num_pages=0, page_size=16)
    with pytest.raises(ValueError, match="positive"):
        PagedKVPool(num_pages=4, page_size=0)


# ---------------------------------------------------------------------------
# Scheduler
# ---------------------------------------------------------------------------


def _sched(max_slots=2, num_pages=8, page_size=16):
    return Scheduler(max_slots, PagedKVPool(num_pages, page_size))


def test_scheduler_arrival_order_admission():
    s = _sched(max_slots=2)
    r0 = s.submit("a", arrival=5.0, need_tokens=16)
    r1 = s.submit("b", arrival=1.0, need_tokens=16)
    r2 = s.submit("c", arrival=3.0, need_tokens=16)
    assert (r0, r1, r2) == (0, 1, 2)
    assert s.advance(0.0) == []            # nothing has arrived yet
    assert s.next_arrival() == 1.0
    s.advance(4.0)                         # b then c arrive; a still pending
    admitted = s.admit()
    assert [t.rid for t in admitted] == [1, 2]   # arrival order, not rid
    assert [t.slot for t in admitted] == [0, 1]
    assert s.admit() == []                 # slots full
    s.advance(10.0)
    assert s.admit() == []                 # a arrived but no slot


def test_scheduler_pool_gates_admission_fifo():
    """Head-of-line blocking: a big request at the queue head must not be
    overtaken by a small one behind it (admission order == arrival order)."""
    s = _sched(max_slots=4, num_pages=4, page_size=16)
    s.submit("big", arrival=0.0, need_tokens=64)     # 4 pages
    s.submit("small", arrival=1.0, need_tokens=16)   # 1 page
    s.advance(2.0)
    first = s.admit()
    assert [t.rid for t in first] == [0]             # big takes whole pool
    assert s.admit() == []                           # small blocked behind
    tr = s.tracked(0)
    s.finish(tr, "done-big")
    assert [t.rid for t in s.admit()] == [1]


def test_scheduler_reorder_buffer_delivers_in_order():
    s = _sched(max_slots=3)
    for name in ("a", "b", "c"):
        s.submit(name, arrival=0.0, need_tokens=16)
    s.advance(0.0)
    s.admit()
    # finish out of order: c, a, then b
    s.finish(s.tracked(2), "rc")
    assert s.pop_ready() == []             # 0 and 1 still running
    s.finish(s.tracked(0), "ra")
    assert s.pop_ready() == ["ra"]         # 1 still blocks 2
    s.finish(s.tracked(1), "rb")
    assert s.pop_ready() == ["rb", "rc"]
    assert not s.has_work()
    assert s.undelivered == 0


def test_scheduler_finish_releases_slot_and_pages():
    s = _sched(max_slots=1, num_pages=2, page_size=16)
    s.submit("a", arrival=0.0, need_tokens=32)
    s.advance(0.0)
    s.admit()
    assert s.pool.free_pages == 0
    tr = s.tracked(0)
    s.finish(tr, "ra", reason="stop")
    assert tr.state == "done"
    assert tr.slot is None
    assert s.slots == [None]
    assert s.pool.free_pages == 2


def test_scheduler_rejects_request_larger_than_pool():
    s = _sched(max_slots=2, num_pages=2, page_size=16)
    with pytest.raises(ValueError, match="raise num_pages"):
        s.submit("huge", need_tokens=100)


def test_scheduler_in_state_slot_order():
    s = _sched(max_slots=3)
    for name in ("a", "b"):
        s.submit(name, arrival=0.0, need_tokens=16)
    s.advance(0.0)
    s.admit()
    assert [t.rid for t in s.in_state("prefill")] == [0, 1]
    s.tracked(1).state = "decode"
    assert [t.rid for t in s.in_state("prefill")] == [0]
    assert [t.rid for t in s.in_state("decode")] == [1]
