"""repro.reduce front-door tests.

The core contract under test: one call, and the (policy x backend) grid is
*consistent* — every backend executes the identical block schedule, so for
a given policy all backends agree bitwise; the exact policy additionally
agrees bitwise under input permutation.
"""

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

import repro
from repro import reduce as R
from repro.core import intac, segmented
from repro.kernels import ops

BACKENDS = ("ref", "blocked", "pallas")
POLICIES = ("fast", "compensated", "exact", "exact2", "procrastinate")
#: the tiers with integer accumulation domains (exact2 carries its
#: residual as exponent-indexed int32 digits, so its finalized float —
#: like its canonical limbs — is a pure function of the integer carry;
#: see test_exact2_limbs_invariant_result_1ulp)
INT_POLICIES = ("exact", "exact2", "procrastinate")
#: the tiers whose *finalized result* is bitwise order-independent
BITWISE_POLICIES = ("exact", "exact2", "procrastinate")


def _data(n, d, s, dtype, seed=0):
    rng = np.random.RandomState(seed)
    vals = jnp.asarray(rng.randn(n, d).astype(np.float32)).astype(dtype)
    ids = jnp.asarray(rng.randint(0, s, n))
    return vals, ids


def _scatter64(vals, ids, s):
    out = np.zeros((s,) + np.asarray(vals).shape[1:])
    np.add.at(out, np.asarray(ids), np.asarray(vals, np.float64))
    return out


# ---------------------------------------------------------------------------
# cross-backend equivalence: segmented/unsegmented x dtype x policy
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("policy", POLICIES)
def test_segmented_backends_bitwise_equal(policy, dtype):
    vals, ids = _data(700, 32, 9, dtype)
    outs = [np.asarray(R.reduce(vals, segment_ids=ids, num_segments=9,
                                policy=policy, backend=b, block_size=128))
            for b in BACKENDS]
    for o in outs[1:]:
        assert np.array_equal(outs[0], o)          # bitwise, not allclose
    tol = 1e-3 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        outs[0], _scatter64(vals.astype(jnp.float32), ids, 9),
        atol=tol, rtol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("policy", POLICIES)
def test_unsegmented_backends_bitwise_equal(policy, dtype):
    vals, _ = _data(500, 16, 1, dtype, seed=3)
    outs = [np.asarray(R.reduce(vals, policy=policy, backend=b,
                                block_size=128)) for b in BACKENDS]
    assert outs[0].shape == (16,)
    for o in outs[1:]:
        assert np.array_equal(outs[0], o)


@pytest.mark.parametrize("policy", POLICIES)
def test_mean_op_matches_oracle(policy):
    vals, ids = _data(400, 8, 5, jnp.float32, seed=4)
    out = R.reduce(vals, segment_ids=ids, num_segments=5, op="mean",
                   policy=policy)
    s64 = _scatter64(vals, ids, 5)
    c64 = _scatter64(jnp.ones((400,)), ids, 5)[:, None]
    np.testing.assert_allclose(np.asarray(out), s64 / np.maximum(c64, 1),
                               atol=1e-3, rtol=1e-3)


@pytest.mark.parametrize("policy", BITWISE_POLICIES)
def test_integer_policies_permutation_and_blocksize_invariant(policy):
    x = jnp.asarray(np.random.RandomState(5).randn(4096).astype(np.float32))
    perm = np.random.RandomState(6).permutation(4096)
    a = float(R.reduce(x, policy=policy))
    b = float(R.reduce(x[perm], policy=policy))
    c = float(R.reduce(x, policy=policy, block_size=64))
    d = float(R.reduce(x[perm], policy=policy, backend="pallas",
                       block_size=256))
    assert a == b == c == d                        # bitwise


def test_exact2_limbs_invariant_result_1ulp():
    """exact2's split guarantee: the *canonical* int32 hi/lo limbs are
    bitwise identical under permutation, block size, and backend, while
    the finalized float (which folds the compensated residual limb, whose
    fold order follows the schedule) stays within 1 ulp of the f64
    reference in every configuration."""
    x = np.random.RandomState(5).randn(4096).astype(np.float32)
    perm = np.random.RandomState(6).permutation(4096)
    ref = float(np.sum(x.astype(np.float64)))
    pol = R.get_policy("exact2")
    ids = jnp.zeros(4096, jnp.int32)

    def canon_limbs(xv, backend, block_size):
        domain, ctx = pol.prepare(jnp.asarray(xv)[:, None], 4096)
        carry = R.get_backend(backend).run(domain, ids, 1, policy=pol,
                                           block_size=block_size)
        hi, lo = intac.limbs_canonical(carry[0], carry[1])
        return np.asarray(hi), np.asarray(lo)

    base = canon_limbs(x, "blocked", 512)
    for xv, bk, bs in ((x, "blocked", 64), (x[perm], "blocked", 512),
                       (x, "ref", 128), (x[perm], "pallas", 256)):
        hi, lo = canon_limbs(xv, bk, bs)
        assert np.array_equal(base[0], hi) and np.array_equal(base[1], lo)

    for xv, kw in ((x, {}), (x[perm], {}), (x, {"block_size": 64}),
                   (x[perm], {"backend": "pallas", "block_size": 256})):
        out = float(R.reduce(jnp.asarray(xv), policy="exact2", **kw))
        assert abs(out - ref) <= _ulp(ref)


def test_exact_policy_tiny_magnitude_stream():
    """Near-clamp scales (max|x| ~ 1e-38) must not collapse to zero: the
    scale clamps to 2^127 and the descale must avoid subnormal
    intermediates (reciprocal or single-step 2^-127 both flush on CPU)."""
    v = jnp.asarray([[2e-38], [2e-38]])
    for b in BACKENDS:
        out = float(R.reduce(v, policy="exact", backend=b)[0])
        assert abs(out - 4e-38) < 6e-39      # within one quantum of 2^-127


def _ulp(x: float) -> float:
    return float(np.spacing(np.abs(np.float32(x)), dtype=np.float32))


def test_large_n_exact2_and_procrastinate_keep_resolution():
    """The shrinking-scale defect, pinned: at N = 2^20 the single-limb
    ``exact`` scale has shrunk to ~2^-10 of max and visibly rounds, while
    ``exact2`` (fixed dyadic quantum) and ``procrastinate`` (per-exponent
    bins) stay within 1 ulp of the float64 oracle."""
    n = 1 << 20
    rng = np.random.RandomState(42)
    # dyadic-grid data (multiples of 2^-12): representable exactly by the
    # fixed ~2^-21-of-max quantum of exact2, far below the ~2^-10 quantum
    # the single-limb scale has shrunk to at this N
    x = (rng.randint(-4096, 4097, n) * 2.0 ** -12).astype(np.float32)
    ref = float(np.sum(x.astype(np.float64)))
    xj = jnp.asarray(x)
    errs = {p: abs(float(R.reduce(xj, policy=p, backend="blocked")) - ref)
            for p in INT_POLICIES}
    assert errs["exact"] > _ulp(ref)               # the defect
    assert errs["exact2"] <= _ulp(ref)
    assert errs["procrastinate"] <= _ulp(ref)

    # procrastinate — and, since the residual limb, exact2 — need no
    # grid: arbitrary f32 data, still <= 1 ulp
    y = rng.randn(n).astype(np.float32)
    refy = float(np.sum(y.astype(np.float64)))
    for p in ("procrastinate", "exact2"):
        erry = abs(float(R.reduce(jnp.asarray(y), policy=p,
                                  backend="blocked")) - refy)
        assert erry <= _ulp(refy), p
    assert abs(float(R.reduce(jnp.asarray(y), policy="exact",
                              backend="blocked")) - refy) > _ulp(refy)


def test_exact2_overflow_guards():
    """Stream length, block size, and block *count* beyond the two-limb
    headroom analysis are rejected eagerly rather than silently wrapping
    the int32 limbs."""
    with pytest.raises(ValueError, match="block"):
        R.reduce(jnp.ones(1024), policy="exact2", block_size=1024)
    # the lo limb accumulates one remainder per block: a small block size
    # shrinks the admissible row count proportionally
    with pytest.raises(ValueError, match="blocks"):
        R.reduce(jnp.ones((1 << 21) + 1), policy="exact2", block_size=64)
    assert float(R.reduce(jnp.ones(1 << 12), policy="exact2",
                          block_size=64)) == float(1 << 12)
    with pytest.raises(ValueError, match="headroom"):
        R.get_policy("exact2").prepare(jnp.ones(((1 << 24) + 1, 1)),
                                       (1 << 24) + 1)
    with pytest.raises(ValueError, match="headroom"):
        R.get_policy("procrastinate").prepare(jnp.ones(((1 << 22) + 1, 1)),
                                              (1 << 22) + 1)


@pytest.mark.parametrize("policy", POLICIES)
def test_all_zero_stream_is_benign(policy):
    """max_abs == 0 must yield a benign scale (``choose_scale`` pins the
    degenerate case to 1.0), not a near-2^127 one or NaN: an all-zero
    stream reduces to exact zeros on every backend, sums and means."""
    z = jnp.zeros((1024, 4))
    for b in BACKENDS:
        out = np.asarray(R.reduce(z, policy=policy, backend=b))
        assert np.array_equal(out, np.zeros(4)) and np.isfinite(out).all()
    m = np.asarray(R.reduce(jnp.zeros(512), policy=policy,
                            segment_ids=jnp.zeros(512, jnp.int32),
                            num_segments=2, op="mean"))
    assert np.array_equal(m, np.zeros(2))
    scale = float(intac.choose_scale(jnp.float32(0.0), 1024))
    assert scale == 1.0                      # pinned: benign, not 2^127


@pytest.mark.parametrize("policy", POLICIES)
def test_all_sentinel_block_is_benign(policy):
    """A stream that is 100% OUT_OF_RANGE_LABEL rows (every payload
    dropped and zeroed before ``prepare``) must reduce to finite zeros —
    the integer tiers' scale statistics see max_abs == 0."""
    vals = jnp.full((256, 3), 1e30)          # huge payloads, all dropped
    ids = jnp.full((256,), R.OUT_OF_RANGE_LABEL)
    for op in ("sum", "mean"):
        out = np.asarray(R.reduce(vals, segment_ids=ids, num_segments=2,
                                  policy=policy, op=op))
        assert np.array_equal(out, np.zeros((2, 3)))
        assert np.isfinite(out).all()


def test_compensated_beats_fast_on_ill_conditioned():
    rng = np.random.RandomState(7)
    x = (rng.randn(1 << 15) * 10 ** rng.uniform(-4, 4, 1 << 15)) \
        .astype(np.float32)
    exact = float(np.sum(x.astype(np.float64)))
    e_fast = abs(float(R.reduce(jnp.asarray(x))) - exact)
    e_comp = abs(float(R.reduce(jnp.asarray(x), policy="compensated"))
                 - exact)
    assert e_comp <= e_fast * 1.0 + 1e-12


def test_1d_values_and_scalar_result():
    x = jnp.arange(11, dtype=jnp.float32)
    assert float(R.reduce(x)) == 55.0
    seg = R.reduce(x, segment_ids=jnp.asarray([0] * 5 + [1] * 6),
                   num_segments=2)
    assert seg.shape == (2,)
    np.testing.assert_allclose(np.asarray(seg), [10.0, 45.0])


# ---------------------------------------------------------------------------
# sentinel + mean masking
# ---------------------------------------------------------------------------


def test_out_of_range_label_drops_rows_everywhere():
    vals = jnp.asarray([[1.0], [2.0], [4.0], [8.0]])
    ids = jnp.asarray([0, R.OUT_OF_RANGE_LABEL, 1, 99])   # 99 also invalid
    for b in BACKENDS:
        out = R.reduce(vals, segment_ids=ids, num_segments=2, backend=b)
        np.testing.assert_allclose(np.asarray(out)[:, 0], [1.0, 4.0])
    # the scatter oracle follows the same convention (negatives must not
    # wrap into the last segment)
    ref = segmented.segment_sum_ref(vals, ids, 2)
    np.testing.assert_allclose(np.asarray(ref)[:, 0], [1.0, 4.0])


@pytest.mark.parametrize("policy", INT_POLICIES)
def test_dropped_rows_cannot_poison_integer_scales(policy):
    """A sentinel-labeled row's payload must not influence the integer
    tiers' quantization scale / window anchor for the rows that are kept."""
    out = R.reduce(jnp.asarray([[1.0], [1e30]]),
                   segment_ids=jnp.asarray([0, R.OUT_OF_RANGE_LABEL]),
                   num_segments=1, policy=policy)
    assert float(out[0, 0]) == 1.0


def test_mean_counts_only_in_range_rows():
    vals = jnp.asarray([2.0, 4.0, 100.0])
    ids = jnp.asarray([0, 0, R.OUT_OF_RANGE_LABEL])
    out = R.reduce(vals, segment_ids=ids, num_segments=1, op="mean")
    assert float(out[0]) == 3.0


def test_segment_mean_honors_impl_and_valid():
    vals = jnp.asarray([[1.0], [3.0], [10.0], [50.0]])
    ids = jnp.asarray([0, 0, 1, 1])
    valid = jnp.asarray([True, True, True, False])
    calls = []

    def impl(v, i, n):
        calls.append(v.shape)
        return R.reduce(v, segment_ids=i, num_segments=n, backend="blocked")

    out = segmented.segment_mean(vals, ids, 2, impl=impl, valid=valid)
    np.testing.assert_allclose(np.asarray(out)[:, 0], [2.0, 10.0])
    assert len(calls) == 2                 # sum AND count went through impl


# ---------------------------------------------------------------------------
# spec, registries, errors
# ---------------------------------------------------------------------------


def test_reduce_module_is_callable_front_door():
    x = jnp.arange(4, dtype=jnp.float32)
    assert float(repro.reduce(x)) == 6.0


def test_spec_reuse_and_replace():
    spec = R.ReduceSpec(op="mean", policy="compensated", backend="blocked")
    vals, ids = _data(64, 4, 3, jnp.float32, seed=9)
    a = R.reduce(vals, segment_ids=ids, num_segments=3, spec=spec)
    b = R.reduce(vals, segment_ids=ids, num_segments=3, op="mean",
                 policy="compensated", backend="blocked")
    assert np.array_equal(np.asarray(a), np.asarray(b))
    assert spec.replace(op="sum").op == "sum"
    assert hash(spec) == hash(R.ReduceSpec(op="mean", policy="compensated",
                                           backend="blocked"))


def test_registries_and_errors():
    assert set(BACKENDS) <= set(R.BACKENDS)
    assert set(POLICIES) <= set(R.POLICIES)
    with pytest.raises(ValueError):
        R.ReduceSpec(op="median")
    with pytest.raises(ValueError):
        R.ReduceSpec(policy="psychic")
    with pytest.raises(ValueError):
        R.ReduceSpec(backend="abacus")
    with pytest.raises(ValueError):
        R.reduce(jnp.ones((4,)), segment_ids=jnp.zeros((4,), jnp.int32))
    with pytest.raises(ValueError):
        R.reduce(jnp.ones((4,)), num_segments=2)   # ids missing
    # every backend reports wildcard/explicit capabilities correctly
    assert all(R.get_backend(b).supports(R.get_policy(p))
               for b in BACKENDS for p in POLICIES)


def test_empty_stream_is_identity_on_all_backends():
    for b in BACKENDS:
        out = R.reduce(jnp.zeros((0, 4)), backend=b)
        assert np.array_equal(np.asarray(out), np.zeros(4))
        m = R.reduce(jnp.zeros((0,)), segment_ids=jnp.zeros((0,), jnp.int32),
                     num_segments=3, op="mean", backend=b)
        assert np.array_equal(np.asarray(m), np.zeros(3))


def test_register_backend_extension_point():
    @R.register_backend("test_double", policies=("fast",),
                        description="test-only")
    def _run(values, ids, n, *, policy, block_size=512, interpret=None):
        carry = R.get_backend("blocked").run(
            values, ids, n, policy=policy, block_size=block_size)
        return tuple(2 * c for c in carry)
    try:
        x = jnp.arange(4, dtype=jnp.float32)
        assert float(R.reduce(x, backend="test_double")) == 12.0
    finally:
        del R.BACKENDS["test_double"]


# ---------------------------------------------------------------------------
# deprecation shims stay removed (CI also errors on repro DeprecationWarnings)
# ---------------------------------------------------------------------------


def test_deprecation_shims_are_gone():
    from repro.core import juggler
    assert not hasattr(segmented, "segment_sum_blocked")
    assert not hasattr(ops, "intac_sum_exact")
    assert not hasattr(juggler, "accumulate_microbatch_grads")


# ---------------------------------------------------------------------------
# Accumulator protocol
# ---------------------------------------------------------------------------


def test_protocol_instances_are_accumulators():
    for acc in (R.TreeAccumulator(4), R.KahanAccumulator(),
                R.LimbAccumulator(2.0 ** 16), R.Limb3Accumulator(2.0 ** 16),
                R.BinAccumulator(8.0), R.FlashAccumulator()):
        assert isinstance(acc, R.Accumulator)


def test_limb3_accumulator_exact_off_the_grid():
    """The three-limb accumulator closes LimbAccumulator's dyadic-grid
    gap: off-grid values (1/3-ish) accumulate to within 1 ulp of the f64
    oracle, the split halves merge to the same integer limbs as a single
    pass, and the two-limb accumulator provably cannot match."""
    rng = np.random.RandomState(23)
    xs = (rng.randn(64, 8).astype(np.float32) / 3 + np.float32(1 / 3))
    scale = 2.0 ** 16
    acc3 = R.Limb3Accumulator(scale)
    a, b = acc3.init(xs[0]), acc3.init(xs[0])
    for x in xs[:32]:
        a = acc3.push(a, jnp.asarray(x))
    for x in xs[32:]:
        b = acc3.push(b, jnp.asarray(x))
    merged_state = acc3.merge(a, b)
    direct = acc3.init(xs[0])
    for x in xs:
        direct = acc3.push(direct, jnp.asarray(x))
    # integer limbs: canonical pairs bitwise equal, split vs direct
    for m, d in zip(intac.limbs_canonical(merged_state.hi, merged_state.lo),
                    intac.limbs_canonical(direct.hi, direct.lo)):
        assert np.array_equal(np.asarray(m), np.asarray(d))
    ref = np.sum(xs.astype(np.float64), axis=0)
    out3 = np.asarray(acc3.finalize(merged_state))
    assert (np.abs(out3 - ref)
            <= np.spacing(np.abs(ref.astype(np.float32)))).all()
    acc2 = R.LimbAccumulator(scale)
    st2 = acc2.init(xs[0])
    for x in xs:
        st2 = acc2.push(st2, jnp.asarray(x))
    out2 = np.asarray(acc2.finalize(st2))
    assert (np.abs(out2 - ref)
            > np.spacing(np.abs(ref.astype(np.float32)))).any()


def test_tree_accumulator_push_merge_finalize():
    rng = np.random.RandomState(13)
    gs = [jnp.asarray(rng.randn(6).astype(np.float32)) for _ in range(11)]
    acc = R.TreeAccumulator.for_count(11)
    st = acc.init(gs[0])
    for g in gs[:6]:
        st = acc.push(st, g)
    st2 = acc.init(gs[0])
    for g in gs[6:]:
        st2 = acc.push(st2, g)
    merged = acc.merge(st, st2)
    assert int(merged.count) == 11
    np.testing.assert_allclose(np.asarray(acc.finalize(merged)),
                               sum(np.asarray(g) for g in gs), atol=1e-5)


def test_kahan_accumulator_scan_and_merge():
    rng = np.random.RandomState(14)
    xs = jnp.asarray((rng.randn(512, 3) * 10 ** rng.uniform(-3, 3, (512, 1)))
                     .astype(np.float32))
    acc = R.KahanAccumulator()
    total = R.scan_accumulate(acc, xs)
    exact = np.sum(np.asarray(xs, np.float64), axis=0)
    assert np.abs(np.asarray(total) - exact).max() <= \
        np.abs(np.asarray(jnp.sum(xs, 0)) - exact).max() + 1e-6
    halves = [acc.init(xs[0]), acc.init(xs[0])]
    for i, x in enumerate(xs):
        halves[i % 2] = acc.push(halves[i % 2], x)
    merged = acc.finalize(R.merge_tree(acc, halves))
    np.testing.assert_allclose(np.asarray(merged), exact, atol=1e-3)


def test_limb_accumulator_matches_core_and_is_exact():
    rng = np.random.RandomState(15)
    xs = [jnp.asarray(rng.randn(8).astype(np.float32)) for _ in range(64)]
    acc = R.LimbAccumulator(2.0 ** 16)
    a = acc.init(xs[0])
    b = acc.init(xs[0])
    for x in xs[:32]:
        a = acc.push(a, x)
    for x in xs[32:]:
        b = acc.push(b, x)
    merged = np.asarray(acc.finalize(acc.merge(a, b)))
    direct = intac.limb_init((8,), 2.0 ** 16)
    for x in xs:
        direct = intac.limb_add(direct, x)
    assert np.array_equal(merged, np.asarray(intac.limb_finalize(direct)))


def test_bin_accumulator_exact_merge_and_finalize():
    """Push/merge are pure integer ops: split halves merge to the same
    bits as a single pass, and the deferred finalize lands within 1 ulp
    of the float64 oracle."""
    rng = np.random.RandomState(21)
    xs = [jnp.asarray(xr.astype(np.float32))
          for xr in rng.randn(96, 8) * 10 ** rng.uniform(-3, 3, (96, 1))]
    acc = R.BinAccumulator(float(max(np.abs(np.asarray(x)).max()
                                     for x in xs)))
    a = acc.init(xs[0])
    b = acc.init(xs[0])
    for x in xs[:48]:
        a = acc.push(a, x)
    for x in xs[48:]:
        b = acc.push(b, x)
    merged = np.asarray(acc.finalize(acc.merge(a, b)))
    direct = acc.init(xs[0])
    for x in xs:
        direct = acc.push(direct, x)
    assert np.array_equal(merged, np.asarray(acc.finalize(direct)))
    ref = np.sum([np.asarray(x, np.float64) for x in xs], axis=0)
    assert (np.abs(merged - ref)
            <= np.spacing(np.abs(ref.astype(np.float32)))).all()


def test_flash_accumulator_streams_softmax():
    rng = np.random.RandomState(16)
    nshards, g, d, s = 6, 4, 16, 32
    q = rng.randn(g, d).astype(np.float32)
    k = rng.randn(nshards, s, d).astype(np.float32)
    v = rng.randn(nshards, s, d).astype(np.float32)
    acc = R.FlashAccumulator()
    state = acc.init((jnp.zeros((g,)), jnp.zeros((g,)),
                      jnp.zeros((g, d))))
    for i in range(nshards):
        sc = q @ k[i].T
        m = sc.max(-1)
        p = np.exp(sc - m[:, None])
        state = acc.push(state, (jnp.asarray(m), jnp.asarray(p.sum(-1)),
                                 jnp.asarray(p @ v[i])))
    out = np.asarray(acc.finalize(state))
    kk, vv = k.reshape(-1, d), v.reshape(-1, d)
    sc = q @ kk.T
    p = np.exp(sc - sc.max(-1, keepdims=True))
    ref = (p / p.sum(-1, keepdims=True)) @ vv
    np.testing.assert_allclose(out, ref, atol=1e-4)


def test_accumulate_microbatch_grads_front_door():
    def grad_fn(p, mb):
        return jax.tree.map(lambda x: mb["x"].sum() * jnp.ones_like(x), p), \
            jnp.float32(0.0)
    params = {"w": jnp.zeros((3,))}
    mbs = {"x": jnp.arange(8, dtype=jnp.float32).reshape(4, 2)}
    g, _ = R.accumulate_microbatch_grads(
        grad_fn, params, mbs, num_microbatches=4, mean=True)
    np.testing.assert_allclose(np.asarray(g["w"]), np.full(3, 28.0 / 4))


# ---------------------------------------------------------------------------
# collective policies (single-device mesh: policy plumbing + math parity)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", R.COLLECTIVE_POLICIES)
def test_collective_mean_policies_single_device(policy):
    from jax.sharding import Mesh, PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    x = jnp.asarray(np.random.RandomState(17).randn(8).astype(np.float32))

    def f(v):
        m, r = R.collective_mean(v, ("data",), policy=policy, bits=8)
        return m, r

    m, r = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                     check_rep=False)(x)
    tol = 0.05 if policy == "compensated" else 1e-5   # 8-bit payload
    np.testing.assert_allclose(np.asarray(m), np.asarray(x),
                               atol=tol * max(1.0, float(jnp.abs(x).max())))
    if policy == "compensated":
        # error feedback: residual holds exactly what quantization dropped
        np.testing.assert_allclose(np.asarray(m + r), np.asarray(x),
                                   atol=1e-6)
