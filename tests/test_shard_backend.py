"""shard_map backend tests: the multi-device face of repro.reduce.

The tentpole contract: the shard_map backend runs the identical block
schedule, so the integer tiers (exact / exact2 / procrastinate) are
bitwise identical to the single-device ``blocked`` schedule at any shard
count, for uneven N, and under permutation of shards; the float tiers
hold documented tolerance.  Multi-device cases run in a subprocess with
8 simulated CPU devices (XLA_FLAGS must be set before jax initializes);
everything else runs in-process on whatever devices exist.
"""

import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import reduce as R
from repro.core import intac

REPO = Path(__file__).resolve().parent.parent
POLICIES = ("fast", "compensated", "exact", "exact2", "procrastinate")
INT_POLICIES = ("exact", "exact2", "procrastinate")
#: tiers whose *finalized float* is bitwise at any shard count — every
#: integer tier: all carry state (exact's int32 sum, exact2's limbs +
#: binned residual digits, procrastinate's bins) adds associatively and
#: finalizes canonically
BITWISE_POLICIES = ("exact", "exact2", "procrastinate")


def _data(n=700, d=8, s=5, seed=0):
    rng = np.random.RandomState(seed)
    return (jnp.asarray(rng.randn(n, d).astype(np.float32)),
            jnp.asarray(rng.randint(0, s, n)))


# ---------------------------------------------------------------------------
# in-process: registry, plumbing, and the 1-shard degenerate case
# ---------------------------------------------------------------------------


def test_backend_registered_with_capabilities():
    bk = R.get_backend("shard_map")
    assert bk.distributed
    assert all(bk.supports(R.get_policy(p)) for p in POLICIES)
    # single-device backends reject the mesh plumbing
    assert not R.get_backend("blocked").distributed


@pytest.mark.parametrize("policy", POLICIES)
def test_one_shard_is_bitwise_the_blocked_schedule(policy):
    """With one shard the carry merge is an identity, so even the float
    tiers must reproduce the blocked backend exactly."""
    vals, ids = _data()
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("shards",))
    a = R.reduce(vals, segment_ids=ids, num_segments=5, policy=policy,
                 backend="shard_map", mesh=mesh, block_size=128)
    b = R.reduce(vals, segment_ids=ids, num_segments=5, policy=policy,
                 backend="blocked", block_size=128)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_mean_and_sentinel_through_shard_map():
    vals = jnp.asarray([2.0, 4.0, 100.0])
    ids = jnp.asarray([0, 0, R.OUT_OF_RANGE_LABEL])
    out = R.reduce(vals, segment_ids=ids, num_segments=1, op="mean",
                   backend="shard_map")
    assert float(out[0]) == 3.0


def test_mesh_kwarg_validation():
    with pytest.raises(ValueError, match="single-device"):
        R.reduce(jnp.ones(4), backend="blocked", mesh=R.default_mesh())
    with pytest.raises(ValueError, match="axis_names"):
        R.reduce(jnp.ones(4), backend="shard_map", mesh=R.default_mesh(),
                 axis_names=("nonexistent",))
    # distributed intent stated via axis_names must never silently fall
    # back to a single-device reduction under auto-selection
    if len(jax.devices()) == 1:
        with pytest.raises(ValueError, match="axis_names"):
            R.reduce(jnp.ones(4), axis_names=("shards",))


def test_ambient_mesh_detection():
    assert R.ambient_mesh() is None
    with R.default_mesh() as m:
        amb = R.ambient_mesh()
        assert amb is not None and tuple(amb.axis_names) == ("shards",)
        del m
    assert R.ambient_mesh() is None


@pytest.mark.parametrize("policy", POLICIES)
def test_policy_merge_is_the_schedule_split(policy):
    """``merge(fold(blocks[:k]), fold(blocks[k:]))`` equals
    ``fold(blocks)`` — bitwise for the integer tiers (their carries add
    associatively), tolerance for the float tiers.  This is the local
    statement of the combiner contract the shard_map backend relies on."""
    pol = R.get_policy(policy)
    vals, ids = _data(n=512, d=4, s=3, seed=2)
    ids = R.mask_out_of_range(ids, 3)
    domain, ctx = pol.prepare(vals, 512)
    bk = R.get_backend("blocked")
    full = bk.run(domain, ids, 3, policy=pol, block_size=64)
    ca = bk.run(domain[:256], ids[:256], 3, policy=pol, block_size=64)
    cb = bk.run(domain[256:], ids[256:], 3, policy=pol, block_size=64)
    merged = pol.merge(ca, cb)
    out_full = np.asarray(pol.finalize(full, ctx))
    out_merged = np.asarray(pol.finalize(merged, ctx))
    if policy in BITWISE_POLICIES:
        assert np.array_equal(out_full, out_merged)
        if policy == "exact2":
            # the canonical integer limbs are bitwise equal too
            for a, b in zip(intac.limbs_canonical(full[0], full[1]),
                            intac.limbs_canonical(merged[0], merged[1])):
                assert np.array_equal(np.asarray(a), np.asarray(b))
    else:
        np.testing.assert_allclose(out_merged, out_full, rtol=1e-6,
                                   atol=1e-6)
    assert pol.merge_is_add == (policy != "compensated")


def test_merge_across_accumulator_single_device():
    mesh = jax.sharding.Mesh(np.asarray(jax.devices()[:1]), ("shards",))
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P
    acc = R.KahanAccumulator()
    x = jnp.asarray([1.5, 2.5])

    def f(v):
        st = acc.push(acc.init(v), v)
        return acc.finalize(R.merge_across(acc, st, mesh.axis_names))

    out = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
                    check_rep=False)(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x))


def test_train_step_grad_reduce_routes_through_front_door():
    """``make_train_step(..., grad_reduce="exact2")`` reduces the stacked
    microbatch gradients through repro.reduce: the step must be
    call-to-call deterministic and track the pairing-tree step closely."""
    from repro.configs import get_smoke_config
    from repro.models import init_params
    from repro.optim import adamw
    from repro.train.steps import make_train_step

    cfg = get_smoke_config("stablelm-1.6b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    lr_fn = adamw.cosine_schedule(1e-3, 2, 20)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32),
                                          0, cfg.vocab)}
    kw = dict(lr_fn=lr_fn, remat=False, moe_impl="dense",
              num_microbatches=2)
    s_tree = jax.jit(make_train_step(cfg, **kw))
    s_exact = jax.jit(make_train_step(cfg, grad_reduce="exact2", **kw))
    p1, _, m1 = s_tree(params, opt, batch)
    p2, _, m2 = s_exact(params, opt, batch)
    p2b, _, _ = s_exact(params, opt, batch)
    assert all(np.array_equal(a, b) for a, b in
               zip(jax.tree.leaves(p2), jax.tree.leaves(p2b)))
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    num = sum(float(jnp.sum((a - b) ** 2)) for a, b in
              zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    den = sum(float(jnp.sum((a - b) ** 2)) for a, b in
              zip(jax.tree.leaves(params), jax.tree.leaves(p1)))
    assert num / max(den, 1e-30) < 1e-3


# ---------------------------------------------------------------------------
# multi-device: 1/2/8 simulated devices in a subprocess
# ---------------------------------------------------------------------------

MULTIDEV_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro import reduce as R
from repro.core import intac

rng = np.random.RandomState(0)
n, d, s, bs = 1000, 16, 7, 128            # uneven: 1000 % (8*128) != 0
vals = jnp.asarray(rng.randn(n, d).astype(np.float32))
ids = jnp.asarray(rng.randint(0, s, n))

for pol in ("fast", "compensated", "exact", "exact2", "procrastinate"):
    base = np.asarray(R.reduce(vals, segment_ids=ids, num_segments=s,
                               policy=pol, backend="blocked",
                               block_size=bs))
    scale = float(np.abs(base).max())
    for ndev in (1, 2, 8):
        mesh = Mesh(np.asarray(jax.devices()[:ndev]), ("shards",))
        out = np.asarray(R.reduce(vals, segment_ids=ids, num_segments=s,
                                  policy=pol, backend="shard_map",
                                  mesh=mesh, block_size=bs))
        bit = int(np.array_equal(base, out))
        rel = float(np.abs(base - out).max()) / scale
        print(f"GRID {pol} {ndev} {bit} {rel:.3e}")

# exact2's integer-limb half of the split guarantee: the canonical hi/lo
# limbs out of the shard_map backend are bitwise identical to the blocked
# schedule at every shard count
pol2 = R.get_policy("exact2")
mids = R.mask_out_of_range(ids, s)
mvals = jnp.where((mids >= 0)[:, None], vals, 0.0)
domain, ctx = pol2.prepare(mvals, n)
cbase = R.get_backend("blocked").run(domain, mids, s, policy=pol2,
                                     block_size=bs)
lbase = [np.asarray(c) for c in intac.limbs_canonical(cbase[0], cbase[1])]
for ndev in (1, 2, 8):
    mesh = Mesh(np.asarray(jax.devices()[:ndev]), ("shards",))
    csh = R.get_backend("shard_map").run(domain, mids, s, policy=pol2,
                                         block_size=bs, mesh=mesh)
    lsh = intac.limbs_canonical(csh[0], csh[1])
    ok = all(np.array_equal(a, np.asarray(b)) for a, b in zip(lbase, lsh))
    print(f"LIMBS {ndev} {int(ok)}")

# BinAccumulator declares merge_is_add: merge_across must take the psum
# fast path and still match a single-device pass bit for bit
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
meshA = Mesh(np.asarray(jax.devices()), ("data",))
xa = jnp.asarray((np.arange(8 * 4).reshape(8, 4) % 7 - 3) * 0.25,
                 dtype=jnp.float32)
acc = R.BinAccumulator(8.0)
def accf(shard):
    st = acc.push(acc.init(shard[0]), shard[0])
    return acc.finalize(R.merge_across(acc, st, ("data",)))
got = np.asarray(shard_map(accf, mesh=meshA, in_specs=P("data", None),
                           out_specs=P(), check_rep=False)(xa))
direct = acc.init(xa[0])
for row in xa:
    direct = acc.push(direct, row)
print(f"BINACC {int(np.array_equal(got, np.asarray(acc.finalize(direct))))}")

# permutation of shards: swap whole shard-sized row chunks; the bitwise
# tiers must not notice (associative + commutative integer carries);
# exact2's finalized float re-folds its residual limb in the new order —
# ulp-level tolerance, with bitwise-equal canonical integer limbs
mesh8 = Mesh(np.asarray(jax.devices()), ("shards",))
npad = 1024                                # 8 shards x 1 block of 128
vp = jnp.asarray(rng.randn(npad, d).astype(np.float32))
ip = jnp.asarray(rng.randint(0, s, npad))
perm = rng.permutation(8)
chunks = np.arange(npad).reshape(8, -1)[perm].reshape(-1)
for pol in ("exact", "exact2", "procrastinate"):
    a = np.asarray(R.reduce(vp, segment_ids=ip, num_segments=s,
                            policy=pol, backend="shard_map", mesh=mesh8,
                            block_size=bs))
    b = np.asarray(R.reduce(vp[chunks], segment_ids=ip[chunks],
                            num_segments=s, policy=pol,
                            backend="shard_map", mesh=mesh8,
                            block_size=bs))
    rel = float(np.abs(a - b).max()) / max(float(np.abs(a).max()), 1e-30)
    print(f"PERM {pol} {int(np.array_equal(a, b))} {rel:.3e}")

# the staged program's lane-parallel contrib through shard_map: forcing
# contrib="lanes" swaps the gather form on every shard, and for the
# integer tiers that must not change a single bit vs the blocked dot
# schedule, at any shard count
for pol in ("exact", "exact2", "procrastinate"):
    base = np.asarray(R.reduce(vals, segment_ids=ids, num_segments=s,
                               policy=pol, backend="blocked",
                               block_size=bs))
    for ndev in (1, 2, 8):
        mesh = Mesh(np.asarray(jax.devices()[:ndev]), ("shards",))
        out = np.asarray(R.reduce(vals, segment_ids=ids, num_segments=s,
                                  policy=pol, backend="shard_map",
                                  mesh=mesh, block_size=bs,
                                  contrib="lanes"))
        print(f"LANES {pol} {ndev} {int(np.array_equal(base, out))}")

# block-size sweep at 8 shards: the bitwise tiers may not notice the
# schedule's block granularity either
for pol in ("exact", "exact2", "procrastinate"):
    outs = [np.asarray(R.reduce(vals, segment_ids=ids, num_segments=s,
                                policy=pol, backend="shard_map",
                                mesh=mesh8, block_size=b2))
            for b2 in (64, 128, 256)]
    ok = all(np.array_equal(outs[0], o) for o in outs[1:])
    print(f"BSWEEP {pol} {int(ok)}")

# auto-selection under an ambient multi-device mesh, bitwise vs blocked
with mesh8:
    auto = np.asarray(R.reduce(vals, segment_ids=ids, num_segments=s,
                               policy="exact", block_size=bs))
base = np.asarray(R.reduce(vals, segment_ids=ids, num_segments=s,
                           policy="exact", backend="blocked",
                           block_size=bs))
print(f"AUTO {int(np.array_equal(auto, base))}")

# a 2D mesh, sharding over both axes jointly
mesh2d = Mesh(np.asarray(jax.devices()).reshape(4, 2), ("dp", "mp"))
out2d = np.asarray(R.reduce(vals, segment_ids=ids, num_segments=s,
                            policy="procrastinate", backend="shard_map",
                            mesh=mesh2d, block_size=bs))
base2d = np.asarray(R.reduce(vals, segment_ids=ids, num_segments=s,
                             policy="procrastinate", backend="blocked",
                             block_size=bs))
print(f"MESH2D {int(np.array_equal(out2d, base2d))}")

# the training route: make_train_step(grad_reduce="exact2",
# grad_reduce_mesh=<8-dev mesh>) routes the microbatch-gradient mean
# through shard_map; the integer limbs are executor-invariant and the
# residual limb holds ulp-level tolerance, so the mesh-built step must
# track the local-executor build to float tolerance through a whole step
from repro.configs import get_smoke_config
from repro.models import init_params
from repro.optim import adamw
from repro.train.steps import make_train_step
cfg = get_smoke_config("stablelm-1.6b")
params = init_params(jax.random.PRNGKey(0), cfg)
opt = adamw.init(params)
kw = dict(lr_fn=adamw.cosine_schedule(1e-3, 2, 20), remat=False,
          moe_impl="dense", num_microbatches=2, grad_reduce="exact2")
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (4, 32),
                                      0, cfg.vocab)}
p1, _, _ = jax.jit(make_train_step(cfg, grad_reduce_mesh=mesh8,
                                   **kw))(params, opt, batch)
p0, _, _ = jax.jit(make_train_step(cfg, **kw))(params, opt, batch)
close = all(np.allclose(np.asarray(a, np.float32), np.asarray(b, np.float32),
                        rtol=1e-5, atol=1e-6)
            for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p0)))
print(f"TRAINSTEP {int(close)}")
"""


def test_multidevice_bitwise_invariance():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    r = subprocess.run([sys.executable, "-c", MULTIDEV_SNIPPET],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    lines = [ln.split() for ln in r.stdout.strip().splitlines()]
    grid = {(p, int(nd)): (int(bit), float(rel))
            for _, p, nd, bit, rel in
            (ln for ln in lines if ln[0] == "GRID")}
    assert len(grid) == 15
    for (pol, ndev), (bit, rel) in grid.items():
        if pol in BITWISE_POLICIES or ndev == 1:
            assert bit == 1, (pol, ndev)        # bitwise, any shard count
        elif pol == "exact2":
            # residual limb folds in device order: ulp-level, not bitwise
            # (the integer limbs are checked bitwise by LIMBS below)
            assert rel < 1e-6, (pol, ndev, rel)
        else:
            assert rel < 1e-5, (pol, ndev, rel)   # documented tolerance
    limbs = {int(nd): int(ok) for tag, nd, ok in
             (ln for ln in lines if ln[0] == "LIMBS")}
    assert limbs == {1: 1, 2: 1, 8: 1}
    perms = {p: (int(bit), float(rel)) for tag, p, bit, rel in
             (ln for ln in lines if ln[0] == "PERM")}
    for p in BITWISE_POLICIES:
        assert perms[p][0] == 1, p
    assert perms["exact2"][1] < 1e-6
    lanes = {(p, int(nd)): int(ok) for tag, p, nd, ok in
             (ln for ln in lines if ln[0] == "LANES")}
    assert len(lanes) == 9
    assert all(ok == 1 for ok in lanes.values()), lanes
    bsweep = {p: int(ok) for tag, p, ok in
              (ln for ln in lines if ln[0] == "BSWEEP")}
    assert bsweep == {p: 1 for p in BITWISE_POLICIES}
    tags = [(ln[0], ln[1]) for ln in lines]
    assert ("AUTO", "1") in tags
    assert ("MESH2D", "1") in tags
    assert ("TRAINSTEP", "1") in tags
    assert ("BINACC", "1") in tags
