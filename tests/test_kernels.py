"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles.

All kernels run in interpret mode on CPU — the kernel body executes with
the exact TPU block schedule (grid steps, BlockSpec tiling, VMEM scratch
semantics), validated elementwise against ref.py.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


@pytest.mark.parametrize("n,d,s,dtype", [
    (512, 128, 8, jnp.float32),
    (1000, 64, 37, jnp.float32),
    (256, 256, 1, jnp.float32),
    (768, 128, 16, jnp.bfloat16),
    (300, 8, 5, jnp.bfloat16),
])
def test_segsum_sweep(n, d, s, dtype):
    rng = np.random.RandomState(n + d)
    vals = jnp.asarray(rng.randn(n, d)).astype(dtype)
    ids = jnp.sort(jnp.asarray(rng.randint(0, s, n)))
    out = ops.segment_sum(vals, ids, s, block_rows=128)
    exp = ref.segsum_ref(vals, ids, s)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=tol, rtol=tol)


def test_segsum_nonmonotone_ids():
    """The kernel's label addressing works for arbitrary (not only
    monotone) id streams — the PIS register file semantics."""
    rng = np.random.RandomState(0)
    vals = jnp.asarray(rng.randn(640, 32).astype(np.float32))
    ids = jnp.asarray(rng.randint(0, 7, 640))      # shuffled labels
    out = ops.segment_sum(vals, ids, 7, block_rows=128)
    exp = ref.segsum_ref(vals, ids, 7)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp), atol=1e-4)


def test_segsum_label_space_tiling():
    """num_segments beyond the VMEM budget splits into label tiles."""
    import repro.kernels.ops as O
    old = O._SEGSUM_ACC_BUDGET
    O._SEGSUM_ACC_BUDGET = 1024          # force tiny tiles
    try:
        rng = np.random.RandomState(1)
        vals = jnp.asarray(rng.randn(512, 64).astype(np.float32))
        ids = jnp.sort(jnp.asarray(rng.randint(0, 50, 512)))
        out = O.segment_sum.__wrapped__(vals, ids, 50, block_rows=128,
                                        interpret=True)
        exp = ref.segsum_ref(vals, ids, 50)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=1e-4)
    finally:
        O._SEGSUM_ACC_BUDGET = old


@pytest.mark.parametrize("n,d,scale", [
    (256, 64, 2.0 ** 18), (700, 32, 2.0 ** 12), (128, 128, 2.0 ** 20)])
def test_intac_accum_sweep(n, d, scale):
    rng = np.random.RandomState(n)
    vals = jnp.asarray(rng.randn(n, d).astype(np.float32))
    limbs = ops.intac_accum(vals, jnp.float32(scale))
    exp = ref.intac_accum_ref(vals, jnp.float32(scale))
    assert np.array_equal(np.asarray(limbs), np.asarray(exp))  # exact int
    back = ref.limbs_to_float(limbs, scale)
    np.testing.assert_allclose(np.asarray(back), np.asarray(vals).sum(0),
                               atol=4.0 / scale * n)


def test_intac_accum_block_invariance():
    """Integer accumulation is associative: block size cannot change bits."""
    vals = jnp.asarray(
        np.random.RandomState(2).randn(512, 16).astype(np.float32))
    a = ops.intac_accum(vals, jnp.float32(2 ** 16), block_rows=64)
    b = ops.intac_accum(vals, jnp.float32(2 ** 16), block_rows=256)
    assert np.array_equal(np.asarray(a), np.asarray(b))


def test_intac_overflow_guard():
    with pytest.raises(ValueError):
        ops.intac_accum(jnp.zeros((1 << 15 + 1, 8)), jnp.float32(1.0))


@pytest.mark.parametrize("b,h,k,s,d,window", [
    (2, 8, 4, 700, 64, None),
    (1, 4, 4, 512, 128, None),
    (2, 8, 2, 300, 32, 128),
    (3, 6, 6, 1024, 64, None),
])
def test_flash_decode_sweep(b, h, k, s, d, window):
    rng = np.random.RandomState(b * s)
    q = jnp.asarray(rng.randn(b, h, d).astype(np.float32))
    kk = jnp.asarray(rng.randn(b, s, k, d).astype(np.float32))
    vv = jnp.asarray(rng.randn(b, s, k, d).astype(np.float32))
    kvlen = jnp.asarray(rng.randint(s // 2, s + 1, b))
    sm = d ** -0.5
    out = ops.flash_decode(q, kk, vv, kvlen, sm_scale=sm, window=window,
                           block_kv=256)
    # reference
    g = h // k
    expect = np.zeros((b, h, d), np.float32)
    pos = np.arange(s)
    for bi in range(b):
        L = int(kvlen[bi])
        valid = pos < L
        if window is not None:
            valid &= pos >= (L - window)
        bias = jnp.asarray(np.where(valid, 0.0, -1e30)[None, :])
        for ki in range(k):
            qg = q[bi].reshape(k, g, d)[ki]
            o = ref.flash_decode_ref(qg, kk[bi, :, ki], vv[bi, :, ki],
                                     bias, sm_scale=sm)
            expect[bi, ki * g:(ki + 1) * g] = np.asarray(o)
    np.testing.assert_allclose(np.asarray(out), expect, atol=2e-3)


def test_flash_decode_block_invariance():
    """Streaming accumulation: block size changes the combine tree, not the
    math (within fp tolerance)."""
    rng = np.random.RandomState(5)
    q = jnp.asarray(rng.randn(1, 4, 64).astype(np.float32))
    kk = jnp.asarray(rng.randn(1, 1024, 2, 64).astype(np.float32))
    vv = jnp.asarray(rng.randn(1, 1024, 2, 64).astype(np.float32))
    kvlen = jnp.asarray([1000])
    a = ops.flash_decode(q, kk, vv, kvlen, sm_scale=0.125, block_kv=128)
    b = ops.flash_decode(q, kk, vv, kvlen, sm_scale=0.125, block_kv=512)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)


# ---------------------------------------------------------------------------
# flash decode: padding contract, paged gather, partial streams
# ---------------------------------------------------------------------------


def test_flash_decode_pallas_pads_non_multiple_s():
    """The kernel wrapper pads a non-multiple S with -inf bias instead of
    asserting — padded keys are invisible to the online softmax."""
    from repro.kernels.flash_decode import flash_decode_pallas
    rng = np.random.RandomState(11)
    g, d, s = 4, 32, 37                    # 37 % 16 != 0
    q = jnp.asarray(rng.randn(g, d).astype(np.float32))
    k = jnp.asarray(rng.randn(s, d).astype(np.float32))
    v = jnp.asarray(rng.randn(s, d).astype(np.float32))
    bias = jnp.zeros((1, s), jnp.float32)
    out = flash_decode_pallas(q, k, v, bias, sm_scale=0.125, block_kv=16,
                              interpret=True)
    expect = ref.flash_decode_ref(q, k, v, bias, sm_scale=0.125)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               atol=2e-5)


def test_flash_decode_pallas_rejects_bad_shapes():
    from repro.kernels.flash_decode import flash_decode_pallas
    q = jnp.zeros((4, 32))
    k = jnp.zeros((64, 32))
    bias = jnp.zeros((1, 64))
    with pytest.raises(ValueError, match="expected q"):
        flash_decode_pallas(q[0], k, k, bias, sm_scale=1.0)
    with pytest.raises(ValueError, match="must match"):
        flash_decode_pallas(q, k, jnp.zeros((32, 32)), bias, sm_scale=1.0)
    with pytest.raises(ValueError, match="head dim"):
        flash_decode_pallas(jnp.zeros((4, 16)), k, k, bias, sm_scale=1.0)
    with pytest.raises(ValueError, match="bias"):
        flash_decode_pallas(q, k, k, jnp.zeros((1, 12)), sm_scale=1.0)


def test_flash_decode_paged_matches_dense_bitwise():
    """The paged-gather kernel with block_kv == page_size walks the same
    blocks in the same order as the dense kernel — outputs are bitwise
    equal on the logically-assembled cache, even with a shuffled physical
    page layout straight out of PagedKVPool."""
    from repro.serve import PagedKVPool
    rng = np.random.RandomState(3)
    b, h, kh, d, ps, nb = 3, 8, 2, 32, 16, 4
    s = nb * ps
    q = jnp.asarray(rng.randn(b, h, d).astype(np.float32))
    k = jnp.asarray(rng.randn(b, s, kh, d).astype(np.float32))
    v = jnp.asarray(rng.randn(b, s, kh, d).astype(np.float32))
    kv_len = jnp.asarray([5, 37, 64], jnp.int32)
    dense = ops.flash_decode(q, k, v, kv_len, sm_scale=0.125, block_kv=ps)

    # interleaved alloc/free so physical pages land in a non-trivial order
    pool = PagedKVPool(num_pages=b * nb + 2, page_size=ps)
    pool.alloc(99, 2 * ps)                 # churn
    tables = []
    for bi in range(b):
        pool.alloc(bi, int(kv_len[bi]))
        if bi == 0:
            pool.free(99)                  # holes for later requests
        tables.append(pool.page_table(bi, max_pages=nb))
    kp = np.zeros((pool.num_pages, ps, kh, d), np.float32)
    vp = np.zeros((pool.num_pages, ps, kh, d), np.float32)
    for bi in range(b):
        for j, pg in enumerate(pool.pages_of(bi)):
            kp[pg] = np.asarray(k[bi, j * ps:(j + 1) * ps])
            vp[pg] = np.asarray(v[bi, j * ps:(j + 1) * ps])

    paged = ops.flash_decode_paged(q, jnp.asarray(kp), jnp.asarray(vp),
                                   jnp.asarray(np.stack(tables)), kv_len,
                                   sm_scale=0.125)
    assert bool(jnp.all(dense == paged)), "paged gather diverged bitwise"


def test_flash_decode_paged_rejects_bad_shapes():
    q = jnp.zeros((2, 4, 16))
    kp = jnp.zeros((8, 16, 2, 16))
    with pytest.raises(ValueError, match="page_tables"):
        ops.flash_decode_paged(q, kp, kp, jnp.zeros((3, 4), jnp.int32),
                               jnp.asarray([1, 1]), sm_scale=1.0)
    with pytest.raises(ValueError, match="expected q"):
        ops.flash_decode_paged(q[0], kp, kp, jnp.zeros((2, 4), jnp.int32),
                               jnp.asarray([1, 1]), sm_scale=1.0)


def test_flash_decode_partial_chunks_matches_single_stream():
    """partial_chunks=k routes the KV stream through k independent
    (m, l, o) partials merged by repro.reduce's FlashAccumulator tree —
    same math as the fused stream, to fp tolerance."""
    rng = np.random.RandomState(7)
    b, h, kh, d, s = 2, 4, 2, 32, 96
    q = jnp.asarray(rng.randn(b, h, d).astype(np.float32))
    k = jnp.asarray(rng.randn(b, s, kh, d).astype(np.float32))
    v = jnp.asarray(rng.randn(b, s, kh, d).astype(np.float32))
    kv_len = jnp.asarray([96, 41], jnp.int32)
    fused = ops.flash_decode(q, k, v, kv_len, sm_scale=0.125, block_kv=16)
    for chunks in (2, 3):
        split = ops.flash_decode(q, k, v, kv_len, sm_scale=0.125,
                                 block_kv=16, partial_chunks=chunks)
        np.testing.assert_allclose(np.asarray(split), np.asarray(fused),
                                   atol=1e-5)
