"""Fault-injection suite: every injected failure is detected, degraded,
or recovered — never silent corruption.

The injectors live in ``repro.testing.faults``; the failure modes and the
contracts asserted here are documented in docs/robustness.md:

  * NaN/Inf payload bursts — sentinel-dropped rows provably never poison
    any tier (bitwise-equal to the clean run); kept-row bursts trip
    ``ReduceStatus.nonfinite``.
  * Overflow guard rails — ``on_overflow="degrade"`` chunks over-bound
    streams and escalates saturated tiers; a saturated tier with no
    escalation raises instead of returning garbage.
  * Checkpoint bit flips / truncation — caught by the CRC sidecars as a
    structured ``CheckpointError``; ``restore_latest_valid`` falls back
    to the newest verifying step.
  * Kill-mid-save — a real subprocess dies at the atomic-rename point;
    the orphaned ``.tmp`` directory is never restored from.
  * Shard dropout — a lost carry in ``merge_carry_across`` degrades to
    exactly the reduction over the surviving shards (bitwise).
  * Elastic resume — train on 2 emulated devices, checkpoint, resume on
    8: bit-identical params and losses vs the uninterrupted run.
"""

import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import reduce as R
from repro.ckpt import checkpoint as ckpt
from repro.testing import faults

REPO = Path(__file__).resolve().parent.parent

pytestmark = pytest.mark.faults

POLICIES = ("fast", "compensated", "exact", "exact2", "procrastinate")


# ---------------------------------------------------------------------------
# NaN/Inf payload bursts
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("kind", ("nan", "inf", "both"))
def test_nonfinite_in_dropped_rows_never_poisons(policy, kind):
    """The guarantee is bitwise: a reduction whose *dropped* rows carry
    NaN/Inf payloads returns the exact bits of the clean run, on every
    tier — the sentinel zeroing happens before any policy sees the
    payloads — and does not trip the nonfinite flag."""
    rng = np.random.RandomState(0)
    x = rng.randn(256, 4).astype(np.float32)
    ids = rng.randint(0, 5, 256).astype(np.int32)
    burst = np.arange(0, 256, 7)
    ids[burst] = R.OUT_OF_RANGE_LABEL
    clean = R.reduce(jnp.asarray(x), segment_ids=jnp.asarray(ids),
                     num_segments=5, policy=policy)
    poisoned = faults.inject_nonfinite(x, rows=burst, kind=kind)
    out, st = R.reduce(jnp.asarray(poisoned), segment_ids=jnp.asarray(ids),
                       num_segments=5, policy=policy, with_status=True)
    assert np.array_equal(np.asarray(clean), np.asarray(out))
    assert np.isfinite(np.asarray(out)).all()
    assert not bool(st.nonfinite)
    assert int(st.kept_rows) == int((ids >= 0).sum())


def test_nonfinite_in_kept_rows_trips_the_flag():
    x = faults.inject_nonfinite(np.ones((8, 2), np.float32), rows=[3],
                                kind="nan")
    out, st = R.reduce(jnp.asarray(x), segment_ids=jnp.zeros(8, np.int32),
                       num_segments=1, policy="fast", with_status=True)
    assert bool(st.nonfinite)
    assert int(st.kept_rows) == 8


def test_with_status_is_jittable_and_free_flags_are_false():
    out, st = jax.jit(
        lambda v: R.reduce(v, policy="exact2", with_status=True))(
            jnp.arange(8.0))
    assert float(out) == 28.0
    assert not bool(st.nonfinite) and not bool(st.saturated)
    assert not bool(st.degraded) and int(st.kept_rows) == 8


# ---------------------------------------------------------------------------
# overflow guard rails: degrade instead of garbage
# ---------------------------------------------------------------------------


def test_degrade_chunks_over_bound_streams():
    """A stream past the block-count headroom bound raises under the
    default, and under ``degrade`` splits into bound-sized chunks folded
    with a compensated accumulator — correct result, flagged."""
    n = (1 << 21) + 3
    x = jnp.ones(n)
    with pytest.raises(ValueError, match="blocks"):
        R.reduce(x, policy="exact2", block_size=64)
    out, st = R.reduce(x, policy="exact2", block_size=64,
                       on_overflow="degrade", with_status=True)
    assert float(out) == float(n)
    assert bool(st.degraded) and not bool(st.saturated)
    assert int(st.kept_rows) == n


def test_saturation_escalates_to_the_next_tier():
    """A tier reporting carry saturation re-runs through its declared
    ``escalation`` tier; the result is the stronger tier's bits and
    ``ReduceStatus.degraded`` records the swap."""
    ExactCls = type(R.get_policy("exact"))

    @R.register_policy
    class _AlwaysSaturated(ExactCls):
        name = "always_saturated"
        escalation = "exact2"

        def carry_status(self, carry):
            return jnp.asarray(True)

    try:
        x = jnp.asarray(np.random.RandomState(2).randn(64)
                        .astype(np.float32))
        ref = float(R.reduce(x, policy="exact2"))
        out, st = R.reduce(x, policy="always_saturated",
                           on_overflow="degrade", with_status=True)
        assert float(out) == ref
        assert bool(st.degraded)
    finally:
        R.POLICIES.pop("always_saturated", None)


def test_saturation_with_no_escalation_raises():
    ExactCls = type(R.get_policy("exact"))

    @R.register_policy
    class _DeadEnd(ExactCls):
        name = "dead_end_saturated"
        escalation = None

        def carry_status(self, carry):
            return jnp.asarray(True)

    try:
        with pytest.raises(OverflowError, match="no stronger tier"):
            R.reduce(jnp.ones(16), policy="dead_end_saturated",
                     on_overflow="degrade")
    finally:
        R.POLICIES.pop("dead_end_saturated", None)


def test_degrade_is_eager_only():
    with pytest.raises(ValueError, match="eager-only"):
        jax.jit(lambda v: R.reduce(v, on_overflow="degrade"))(jnp.ones(4))


# ---------------------------------------------------------------------------
# reduction-algebra ops under the same guard rails (ISSUE 9)
# ---------------------------------------------------------------------------


def test_nonfinite_weight_in_kept_rows_trips_the_flag():
    """The algebra's ``pre`` multiplies before any policy sees the rows,
    so a NaN *weight* on a kept row poisons the transformed stream the
    same way a NaN value would — and the status flag must say so."""
    w = np.ones(8, np.float32)
    w[3] = np.nan
    out, st = R.reduce(jnp.ones((8, 2)), segment_ids=jnp.zeros(8, jnp.int32),
                       num_segments=1, op="weighted_sum",
                       weights=jnp.asarray(w), policy="fast",
                       with_status=True)
    assert bool(st.nonfinite)
    assert int(st.kept_rows) == 8


@pytest.mark.parametrize("op", ("weighted_sum", "moments"))
@pytest.mark.parametrize("policy", POLICIES)
def test_nonfinite_in_dropped_rows_never_poisons_algebra_ops(op, policy):
    """Sentinel zeroing runs downstream of ``pre``, so NaN/Inf payloads
    in dropped rows — in the values *or* the weights — leave the clean
    run's exact bits, for every op x tier."""
    rng = np.random.RandomState(1)
    x = rng.randn(192, 3).astype(np.float32)
    w = rng.uniform(0.5, 2.0, 192).astype(np.float32)
    ids = rng.randint(0, 4, 192).astype(np.int32)
    burst = np.arange(0, 192, 5)
    ids[burst] = R.OUT_OF_RANGE_LABEL
    kw = {"weights": jnp.asarray(w)} if op == "weighted_sum" else {}
    clean = R.reduce(jnp.asarray(x), segment_ids=jnp.asarray(ids),
                     num_segments=4, op=op, policy=policy, **kw)
    xp = faults.inject_nonfinite(x, rows=burst, kind="both")
    if op == "weighted_sum":
        wp = w.copy()
        wp[burst] = np.nan
        kw = {"weights": jnp.asarray(wp)}
    out, st = R.reduce(jnp.asarray(xp), segment_ids=jnp.asarray(ids),
                       num_segments=4, op=op, policy=policy,
                       with_status=True, **kw)
    assert np.array_equal(np.asarray(clean), np.asarray(out)), (op, policy)
    assert np.isfinite(np.asarray(out)).all()
    assert not bool(st.nonfinite)


@pytest.mark.parametrize("op", ("weighted_sum", "moments"))
def test_degrade_chunks_over_bound_streams_algebra_ops(op):
    """The degrade fallback folds the op-transformed stream and applies
    ``post`` once at the end — over-bound weighted/moment reductions
    stay correct and flagged, like plain sums."""
    n = (1 << 21) + 3
    x = jnp.ones(n)
    kw = {"weights": jnp.full((n,), 2.0)} if op == "weighted_sum" else {}
    with pytest.raises(ValueError, match="blocks"):
        R.reduce(x, op=op, policy="exact2", block_size=64, **kw)
    out, st = R.reduce(x, op=op, policy="exact2", block_size=64,
                       on_overflow="degrade", with_status=True, **kw)
    if op == "weighted_sum":
        assert float(out) == float(2.0 * n)
    else:
        assert float(out[0]) == 1.0 and float(out[1]) == 0.0
    assert bool(st.degraded) and not bool(st.saturated)
    assert int(st.kept_rows) == n


# ---------------------------------------------------------------------------
# checkpoint storage faults
# ---------------------------------------------------------------------------


def _tree(shift=0.0):
    return {"w": jnp.arange(12.0).reshape(3, 4) + shift,
            "b": jnp.ones(4) * (1.0 + shift)}


def test_bitflip_is_detected_and_falls_back(tmp_path):
    ckpt.save(tmp_path, 1, _tree(0.0), extra={"next_step": 2})
    ckpt.save(tmp_path, 2, _tree(1.0), extra={"next_step": 3})
    faults.corrupt_checkpoint(tmp_path, 2, mode="bitflip")
    with pytest.raises(ckpt.CheckpointError, match="CRC32"):
        ckpt.restore(tmp_path, 2, _tree())
    tree, manifest, step = ckpt.restore_latest_valid(tmp_path, _tree())
    assert step == 1 and manifest["extra"]["next_step"] == 2
    assert np.array_equal(np.asarray(tree["w"]),
                          np.asarray(_tree(0.0)["w"]))


def test_truncation_is_detected_and_falls_back(tmp_path):
    ckpt.save(tmp_path, 1, _tree(0.0))
    ckpt.save(tmp_path, 2, _tree(1.0))
    faults.corrupt_checkpoint(tmp_path, 2, mode="truncate")
    with pytest.raises(ckpt.CheckpointError):
        ckpt.restore(tmp_path, 2, _tree())
    _, _, step = ckpt.restore_latest_valid(tmp_path, _tree())
    assert step == 1


def test_every_checkpoint_corrupt_raises_structured(tmp_path):
    ckpt.save(tmp_path, 1, _tree())
    faults.corrupt_checkpoint(tmp_path, 1, mode="bitflip")
    with pytest.raises(ckpt.CheckpointError, match="no valid checkpoint"):
        ckpt.restore_latest_valid(tmp_path, _tree())


def test_kill_mid_save_orphan_is_never_restored(tmp_path):
    """A real process death between shard write and rename: the ``.tmp``
    directory stays behind, ``latest_step`` ignores it, and recovery
    resumes from the previous verified step."""
    tree = jax.tree.map(jnp.asarray, faults._demo_tree())
    ckpt.save(tmp_path, 1, tree, extra={"next_step": 2})
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    r = subprocess.run([sys.executable, "-m", "repro.testing.faults",
                        "kill-mid-save", str(tmp_path), "2"],
                       capture_output=True, text=True, env=env, timeout=300)
    assert r.returncode == faults.KILL_EXIT_CODE, (r.returncode, r.stderr)
    assert (tmp_path / "step_00000002.tmp").exists()
    assert not (tmp_path / "step_00000002").exists()
    assert ckpt.latest_step(tmp_path) == 1
    restored, manifest, step = ckpt.restore_latest_valid(tmp_path, tree)
    assert step == 1
    assert all(np.array_equal(np.asarray(a), np.asarray(b))
               for a, b in zip(jax.tree.leaves(restored),
                               jax.tree.leaves(tree)))


# ---------------------------------------------------------------------------
# shard dropout in merge_carry_across
# ---------------------------------------------------------------------------

DROPOUT_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro import reduce as R
from repro.testing.faults import drop_shard_carry

rng = np.random.RandomState(0)
n, d, s, bs, nshards = 1024, 4, 3, 128, 8
vals = jnp.asarray(rng.randn(n, d).astype(np.float32))
ids = jnp.asarray(rng.randint(0, s, n).astype(np.int32))
pol = R.get_policy("exact2")
mids = R.mask_out_of_range(ids, s)
mvals = jnp.where((mids >= 0)[:, None], vals, 0.0)
domain, ctx = pol.prepare(mvals, n)
mesh = Mesh(np.asarray(jax.devices()), ("shards",))
DROP = 3

def body(v, i):
    carry = R.get_backend("blocked").run(v, i, s, policy=pol, block_size=bs)
    carry = drop_shard_carry(carry, "shards", DROP)
    return R.merge_carry_across(pol, carry, ("shards",))

carry = shard_map(body, mesh=mesh,
                  in_specs=(P("shards", None), P("shards")),
                  out_specs=P(), check_rep=False)(domain, mids)
dropped = np.asarray(pol.finalize(carry, ctx))

# ground truth: the identical schedule with shard DROP's rows deleted
# (same prepared domain and ctx, so the quantization grid is unchanged)
rows = np.ones(n, bool)
per = n // nshards
rows[DROP * per:(DROP + 1) * per] = False
csur = R.get_backend("blocked").run(domain[rows], mids[rows], s,
                                    policy=pol, block_size=bs)
survive = np.asarray(pol.finalize(csur, ctx))
print("DROPOUT", int(np.array_equal(dropped, survive)))
"""


def test_shard_dropout_degrades_to_surviving_rows():
    """Zeroing one shard's carry before ``merge_carry_across`` must yield
    *exactly* (bitwise) the reduction over the surviving shards' rows —
    graceful degradation, not corruption."""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    r = subprocess.run([sys.executable, "-c", DROPOUT_SNIPPET],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "DROPOUT 1" in r.stdout


# ---------------------------------------------------------------------------
# the acceptance test: bitwise elastic resume, 2 devices -> 8
# ---------------------------------------------------------------------------

ELASTIC_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.configs import get_smoke_config
from repro.models import init_params
from repro.optim import adamw
from repro.distributed.collectives import make_elastic_train_step
from repro.ckpt import checkpoint as ckpt

ckpt_dir = r"@CKPT@"
cfg = get_smoke_config("xlstm-125m")
params0 = init_params(jax.random.PRNGKey(0), cfg)
opt0 = adamw.init(params0)
lr_fn = adamw.cosine_schedule(1e-3, 2, 20)

def make_batch(step):
    return {"tokens": jax.random.randint(jax.random.PRNGKey(100 + step),
                                         (8, 16), 0, cfg.vocab)}

def run(ndev, params, opt, steps, start=0):
    mesh = Mesh(np.asarray(jax.devices()[:ndev]), ("data",))
    fn = jax.jit(make_elastic_train_step(cfg, mesh, lr_fn=lr_fn,
                                         microbatch_size=1))
    losses = []
    for s in range(start, start + steps):
        params, opt, m = fn(params, opt, make_batch(s))
        losses.append(float(m["loss"]))
    return params, opt, losses

# the uninterrupted reference: 4 steps on 2 devices
pA, oA, lA = run(2, params0, opt0, 4)

# the elastic run: 2 steps on 2 devices, checkpoint, restore, 2 on 8
p1, o1, l1 = run(2, params0, opt0, 2)
ckpt.save(ckpt_dir, 2, {"params": p1, "opt": o1}, extra={"next_step": 2})
state, manifest, step = ckpt.restore_latest_valid(
    ckpt_dir, {"params": p1, "opt": o1})
assert step == 2 and manifest["extra"]["next_step"] == 2
pB, oB, l2 = run(8, state["params"], state["opt"], 2, start=2)

ok_params = all(np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(pA), jax.tree.leaves(pB)))
ok_loss = (l1 + l2) == lA
print("ELASTIC", int(ok_params), int(ok_loss))
"""


@pytest.mark.slow
def test_elastic_resume_is_bitwise_2_to_8_devices(tmp_path):
    """Train 2 steps on 2 emulated devices with the elastic (exact2)
    step, checkpoint, restore, finish on 8 devices: params and every
    per-step loss match the uninterrupted 2-device run bit for bit."""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    snippet = ELASTIC_SNIPPET.replace("@CKPT@", str(tmp_path / "ck"))
    r = subprocess.run([sys.executable, "-c", snippet],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    assert "ELASTIC 1 1" in r.stdout


# ---------------------------------------------------------------------------
# serving faults: a request killed mid-decode
# ---------------------------------------------------------------------------


def test_cancel_mid_decode_frees_pages_and_isolates_survivors():
    """Kill a request mid-decode: its KV pages return to the pool at the
    moment of cancellation (not at drain), a 'cancelled' result still
    arrives in submission order, and the survivors' greedy outputs are
    bitwise identical to a run where the victim never existed (per-slot
    isolation: a dying batchmate cannot perturb anyone's stream)."""
    from repro.configs import get_smoke_config
    from repro.models import init_params
    from repro.serve import Engine, Request

    cfg = get_smoke_config("stablelm-1.6b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    eng = Engine(cfg, params, max_len=96, seed=0, max_batch=4)

    survivors = [Request(prompt=[5, 6, 7], max_new_tokens=6),
                 Request(prompt=[9, 10], max_new_tokens=8),
                 Request(prompt=[2, 3, 4, 5], max_new_tokens=5)]
    victim = Request(prompt=[30, 31, 32], max_new_tokens=20)

    rids = [eng.submit(r) for r in survivors + [victim]]
    victim_rid = rids[-1]
    victim_pages = eng.pool.pages_for(
        min(len(victim.prompt) + victim.max_new_tokens, eng.max_len))
    seen = {}

    def kill(engine, step):
        if step == 3:      # victim is mid-decode (admitted at step 0)
            assert engine.scheduler.tracked(victim_rid).state == "decode"
            before = engine.pool.free_pages
            assert engine.cancel(victim_rid)
            seen["freed"] = engine.pool.free_pages - before
            seen["tokens"] = len(engine.scheduler.tracked(victim_rid).out)

    results = eng.run(on_step=kill)
    assert seen["freed"] == victim_pages          # pages back immediately
    assert [r.rid for r in results] == rids       # in-order incl. victim
    vres = results[-1]
    assert vres.finish_reason == "cancelled"
    assert len(vres.tokens) == seen["tokens"]     # partial output kept
    assert eng.pool.free_pages == eng.pool.num_pages

    clean = eng.generate(survivors)               # victim never existed
    for got, ref_ in zip(results, clean):
        assert got.tokens == ref_.tokens, \
            "cancellation perturbed a surviving request's stream"
        assert got.finish_reason == ref_.finish_reason
