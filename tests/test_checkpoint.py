"""Checkpoint/restore + fault-tolerance drills."""

import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt

REPO = Path(__file__).resolve().parent.parent


def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16),
                  "d": jnp.asarray(3, jnp.int32)}}


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t, extra={"next_step": 8})
    assert ckpt.latest_step(str(tmp_path)) == 7
    restored, manifest = ckpt.restore(str(tmp_path), 7, t)
    assert manifest["extra"]["next_step"] == 8
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_retention(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, t, keep=2)
    steps = sorted(p.name for p in tmp_path.iterdir()
                   if p.name.startswith("step_"))
    assert steps == ["step_00000004", "step_00000005"]


def test_partial_write_is_ignored(tmp_path):
    """A crash mid-write (.tmp dir, no manifest) must not be 'latest'."""
    t = _tree()
    ckpt.save(str(tmp_path), 3, t)
    (tmp_path / "step_00000009.tmp").mkdir()
    (tmp_path / "step_00000009.tmp" / "shard_00000of00001.msgpack") \
        .write_bytes(b"garbage")
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_missing_leaf_raises(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    t2 = dict(t, extra_leaf=jnp.zeros(3))
    with pytest.raises(KeyError):
        ckpt.restore(str(tmp_path), 1, t2)


def test_restore_missing_step_is_structured(tmp_path):
    """Regression: restore on a step that was never written used to leak
    a raw FileNotFoundError; callers (restore_latest_valid, the trainer)
    key on CheckpointError."""
    with pytest.raises(ckpt.CheckpointError, match="manifest.json"):
        ckpt.restore(str(tmp_path), 42, _tree())
    err = None
    try:
        ckpt.restore(str(tmp_path), 42, _tree())
    except ckpt.CheckpointError as e:
        err = e
    assert err.step == 42 and str(tmp_path) in str(err.path)


def test_restore_truncated_shard_is_structured(tmp_path):
    """Regression: a torn shard used to surface as a raw zlib/msgpack
    decode error."""
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    shard = next((tmp_path / "step_00000001").glob("shard_*.msgpack"))
    blob = shard.read_bytes()
    shard.write_bytes(blob[: len(blob) // 2])
    with pytest.raises(ckpt.CheckpointError):
        ckpt.restore(str(tmp_path), 1, t)


def test_restore_corrupt_manifest_is_structured(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    (tmp_path / "step_00000001" / "manifest.json").write_text("{not json")
    with pytest.raises(ckpt.CheckpointError, match="manifest"):
        ckpt.restore(str(tmp_path), 1, t)


def test_latest_step_empty_and_partial_dirs(tmp_path):
    assert ckpt.latest_step(str(tmp_path)) is None
    assert ckpt.latest_step(str(tmp_path / "never_created")) is None
    (tmp_path / "step_00000004.tmp").mkdir()
    assert ckpt.latest_step(str(tmp_path)) is None
    ckpt.save(str(tmp_path), 2, _tree())
    assert ckpt.latest_step(str(tmp_path)) == 2


def test_restore_latest_valid_empty_dir_returns_none(tmp_path):
    assert ckpt.restore_latest_valid(str(tmp_path), _tree()) is None
    assert ckpt.restore_latest_valid(str(tmp_path / "nope"), _tree()) is None


def test_restore_latest_valid_skips_broken_newest(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    ckpt.save(str(tmp_path), 2, t)
    # newest loses its manifest (partial cleanup after a crash)
    (tmp_path / "step_00000002" / "manifest.json").unlink()
    tree, manifest, step = ckpt.restore_latest_valid(str(tmp_path), t)
    assert step == 1
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(tree)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


def test_v1_checkpoint_without_sidecar_still_restores(tmp_path):
    """Back-compat: pre-CRC checkpoints have no .crc.json — restore is
    lenient (no integrity check possible) instead of refusing."""
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    for sc in (tmp_path / "step_00000001").glob("*.crc.json"):
        sc.unlink()
    restored, _ = ckpt.restore(str(tmp_path), 1, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))


@pytest.mark.slow
def test_failover_restart_equivalence(tmp_path):
    """The full drill: crash at step 6, restart, final loss must equal an
    uninterrupted run — checkpoint + pure data pipeline = exact resume."""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"),
               JAX_PLATFORMS="cpu")
    common = [sys.executable, "-m", "repro.launch.train",
              "--arch", "xlstm-125m", "--smoke", "--steps", "10",
              "--batch", "2", "--seq", "32", "--ckpt-every", "3",
              "--log-every", "1"]
    # uninterrupted
    r = subprocess.run(common + ["--ckpt-dir", str(tmp_path / "a")],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    loss_a = r.stdout.strip().splitlines()[-1]

    # crash at 6, then restart
    r1 = subprocess.run(common + ["--ckpt-dir", str(tmp_path / "b"),
                                  "--simulate-failure-at", "6"],
                        capture_output=True, text=True, env=env, timeout=900)
    assert r1.returncode == 17          # simulated crash
    r2 = subprocess.run(common + ["--ckpt-dir", str(tmp_path / "b")],
                        capture_output=True, text=True, env=env, timeout=900)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "[restore] resumed" in r2.stdout
    loss_b = r2.stdout.strip().splitlines()[-1]
    assert loss_a.split("loss")[-1] == loss_b.split("loss")[-1], \
        (loss_a, loss_b)
