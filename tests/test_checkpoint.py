"""Checkpoint/restore + fault-tolerance drills."""

import json
import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt import checkpoint as ckpt

REPO = Path(__file__).resolve().parent.parent


def _tree():
    return {"a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
            "b": {"c": jnp.ones((5,), jnp.bfloat16),
                  "d": jnp.asarray(3, jnp.int32)}}


def test_roundtrip(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 7, t, extra={"next_step": 8})
    assert ckpt.latest_step(str(tmp_path)) == 7
    restored, manifest = ckpt.restore(str(tmp_path), 7, t)
    assert manifest["extra"]["next_step"] == 8
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_retention(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, t, keep=2)
    steps = sorted(p.name for p in tmp_path.iterdir()
                   if p.name.startswith("step_"))
    assert steps == ["step_00000004", "step_00000005"]


def test_partial_write_is_ignored(tmp_path):
    """A crash mid-write (.tmp dir, no manifest) must not be 'latest'."""
    t = _tree()
    ckpt.save(str(tmp_path), 3, t)
    (tmp_path / "step_00000009.tmp").mkdir()
    (tmp_path / "step_00000009.tmp" / "shard_00000of00001.msgpack") \
        .write_bytes(b"garbage")
    assert ckpt.latest_step(str(tmp_path)) == 3


def test_missing_leaf_raises(tmp_path):
    t = _tree()
    ckpt.save(str(tmp_path), 1, t)
    t2 = dict(t, extra_leaf=jnp.zeros(3))
    with pytest.raises(KeyError):
        ckpt.restore(str(tmp_path), 1, t2)


@pytest.mark.slow
def test_failover_restart_equivalence(tmp_path):
    """The full drill: crash at step 6, restart, final loss must equal an
    uninterrupted run — checkpoint + pure data pipeline = exact resume."""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"),
               JAX_PLATFORMS="cpu")
    common = [sys.executable, "-m", "repro.launch.train",
              "--arch", "xlstm-125m", "--smoke", "--steps", "10",
              "--batch", "2", "--seq", "32", "--ckpt-every", "3",
              "--log-every", "1"]
    # uninterrupted
    r = subprocess.run(common + ["--ckpt-dir", str(tmp_path / "a")],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-2000:]
    loss_a = r.stdout.strip().splitlines()[-1]

    # crash at 6, then restart
    r1 = subprocess.run(common + ["--ckpt-dir", str(tmp_path / "b"),
                                  "--simulate-failure-at", "6"],
                        capture_output=True, text=True, env=env, timeout=900)
    assert r1.returncode == 17          # simulated crash
    r2 = subprocess.run(common + ["--ckpt-dir", str(tmp_path / "b")],
                        capture_output=True, text=True, env=env, timeout=900)
    assert r2.returncode == 0, r2.stderr[-2000:]
    assert "[restore] resumed" in r2.stdout
    loss_b = r2.stdout.strip().splitlines()[-1]
    assert loss_a.split("loss")[-1] == loss_b.split("loss")[-1], \
        (loss_a, loss_b)
