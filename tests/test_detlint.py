"""The determinism linter, linted: fixture twins per AST rule, waiver
pragma semantics, the ratchet, and the jaxpr contract checker catching
a deliberately broken policy.

Layer-1 fixtures go through ``walker.parse_source`` — the same path
real files take — with fake paths placed inside/outside the front-door
directories to exercise ``applies``.  Layer-2 tests register a broken
policy in the live registry (cleaned up in ``finally``) and assert the
contract checker flags it loudly.
"""

import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(REPO / "tools"))

import detlint  # noqa: E402
from repro.analysis import rules, walker  # noqa: E402
from repro.analysis.rules import Finding  # noqa: E402

MODELS = "src/repro/models/fixture.py"
REDUCE = "src/repro/reduce/fixture.py"
SERVE = "src/repro/serve/fixture.py"


def lint(text: str, path: str, rule_id: str):
    """Run one AST rule over a fixture snippet; returns its findings."""
    mod = walker.parse_source(text, path)
    (rule,) = [r for r in rules.AST_RULES if r.rule == rule_id]
    return rule.run(mod)


def unwaived(findings):
    return [f for f in findings if not f.waived]


# ---------------------------------------------------------------------------
# DET001 — raw reductions outside the front door
# ---------------------------------------------------------------------------


def test_det001_flags_raw_sum_in_models():
    src = "import jax.numpy as jnp\n\ndef f(x):\n    return jnp.sum(x)\n"
    assert unwaived(lint(src, MODELS, "DET001"))


def test_det001_flags_method_sum_and_psum():
    src = ("import jax\n"
           "def f(x):\n"
           "    a = x.sum(axis=0)\n"
           "    return jax.lax.psum(a, 'dp')\n")
    found = unwaived(lint(src, MODELS, "DET001"))
    assert len(found) == 2


def test_det001_ignores_reduce_internals_and_front_door_calls():
    src = "import jax.numpy as jnp\n\ndef f(x):\n    return jnp.sum(x)\n"
    assert not lint(src, REDUCE, "DET001")      # implementation layer
    front = ("from repro.reduce import reduce\n"
             "def f(x):\n    return reduce(x, op='sum')\n")
    assert not lint(front, MODELS, "DET001")


def test_det001_ignores_host_math_roots():
    src = ("import numpy as np, math, jax\n"
           "def f(x, xs):\n"
           "    _ = jax.device_count()\n"
           "    return np.sum(x) + math.fsum(xs)\n")
    # np.*/math.* are host-side: deterministic already, not the rule's
    # business (the method form on an *unknown* root still flags)
    assert not lint(src, MODELS, "DET001")


# ---------------------------------------------------------------------------
# DET002 — float fold loops without optimization_barrier
# ---------------------------------------------------------------------------

_FOLD = """\
import jax.numpy as jnp

def fold(blocks):
    acc = jnp.zeros((4,))
    for b in blocks:
        c = jnp.asarray(b)
        acc = acc + c
    return acc
"""


def test_det002_flags_barrierless_fold():
    found = unwaived(lint(_FOLD, MODELS, "DET002"))
    assert len(found) == 1 and "`acc`" in found[0].message


def test_det002_barrier_in_loop_clears():
    src = _FOLD.replace("acc = acc + c",
                        "acc = jax.lax.optimization_barrier(acc + c)")
    assert not lint(src, MODELS, "DET002")


def test_det002_ignores_host_int_folds():
    src = ("import jax.numpy as jnp\n"
           "def count(params):\n"
           "    total = 0\n"
           "    for p in params:\n"
           "        total += int(p.size)\n"
           "    return jnp.zeros((total,))\n")
    assert not lint(src, MODELS, "DET002")


def test_det002_flags_tuple_fold_calls():
    src = ("import jax.numpy as jnp\n"
           "from repro.core.floats import two_sum\n"
           "def resolve(parts):\n"
           "    acc, err = jnp.float32(0), jnp.float32(0)\n"
           "    for p in parts:\n"
           "        acc, e = two_sum(acc, p)\n"
           "        err = err + e\n"
           "    return acc + err\n")
    found = unwaived(lint(src, MODELS, "DET002"))
    assert found and "`acc`" in found[0].message


# ---------------------------------------------------------------------------
# DET003 — .at[] scatters without explicit mode=
# ---------------------------------------------------------------------------


def test_det003_flags_modeless_scatter():
    src = ("import jax.numpy as jnp\n"
           "def f(out, ids, v):\n"
           "    return out.at[ids].add(v)\n")
    assert unwaived(lint(src, MODELS, "DET003"))


def test_det003_explicit_mode_clears():
    src = ("import jax.numpy as jnp\n"
           "def f(out, ids, v):\n"
           "    return out.at[ids].add(v, mode='drop')\n")
    assert not lint(src, MODELS, "DET003")


# ---------------------------------------------------------------------------
# DET004 — bare random.split in serving code
# ---------------------------------------------------------------------------


def test_det004_flags_split_in_serve_only():
    src = ("import jax\n"
           "def step(key):\n"
           "    key, sub = jax.random.split(key)\n"
           "    return sub\n")
    assert unwaived(lint(src, SERVE, "DET004"))
    assert not lint(src, MODELS, "DET004")      # rule is serve/-scoped


def test_det004_fold_in_clears():
    src = ("import jax\n"
           "def step(seed, rid, t):\n"
           "    return jax.random.fold_in(jax.random.fold_in(seed, rid), t)\n")
    assert not lint(src, SERVE, "DET004")


# ---------------------------------------------------------------------------
# DET006 — f32 count/index arithmetic
# ---------------------------------------------------------------------------


def test_det006_flags_float_ones_count_and_float_arange():
    src = ("import jax.numpy as jnp\n"
           "def f(ids, n):\n"
           "    ones = jnp.ones((n,), jnp.float32)\n"
           "    c = jnp.sum(ones)\n"
           "    i = jnp.arange(n, dtype=jnp.float32)\n"
           "    return c, i\n")
    found = unwaived(lint(src, REDUCE, "DET006"))
    assert len(found) == 2


def test_det006_int_counts_clear():
    src = ("import jax.numpy as jnp\n"
           "def f(ids, n):\n"
           "    ones = jnp.ones((n,), jnp.int32)\n"
           "    return jnp.sum(ones), jnp.arange(n)\n")
    assert not lint(src, REDUCE, "DET006")


# ---------------------------------------------------------------------------
# Waiver pragmas
# ---------------------------------------------------------------------------


def test_same_line_pragma_waives():
    src = ("import jax.numpy as jnp\n"
           "def f(x):\n"
           "    return jnp.sum(x)  # detlint: ok[DET001] scalar summary\n")
    (f,) = lint(src, MODELS, "DET001")
    assert f.waived and f.reason == "scalar summary"


def test_comment_pragma_covers_next_code_line_through_comments():
    src = ("import jax.numpy as jnp\n"
           "def f(blocks):\n"
           "    acc = jnp.zeros((4,))\n"
           "    # detlint: ok[DET002] order pinned by data dependence\n"
           "    # (continuation of the justification)\n"
           "\n"
           "    for b in blocks:\n"
           "        c = jnp.asarray(b)\n"
           "        acc = acc + c\n"
           "    return acc\n")
    (f,) = lint(src, MODELS, "DET002")
    assert f.waived


def test_wrong_rule_id_does_not_waive():
    src = ("import jax.numpy as jnp\n"
           "def f(x):\n"
           "    return jnp.sum(x)  # detlint: ok[DET003] wrong rule\n")
    (f,) = lint(src, MODELS, "DET001")
    assert not f.waived


def test_pragma_inside_multiline_call_span_waives():
    src = ("import jax.numpy as jnp\n"
           "def f(x):\n"
           "    return jnp.sum(\n"
           "        x,  # detlint: ok[DET001] spans the call\n"
           "        axis=0,\n"
           "    )\n")
    (f,) = lint(src, MODELS, "DET001")
    assert f.waived


# ---------------------------------------------------------------------------
# The ratchet
# ---------------------------------------------------------------------------


def test_ratchet_fails_on_increase_passes_on_equal_notes_decrease():
    base = {"DET001": 3, "DET002": 2}
    errors, notes = detlint.check_ratchet({"DET001": 3, "DET002": 2}, base)
    assert not errors and not notes
    errors, _ = detlint.check_ratchet({"DET001": 4, "DET002": 2}, base)
    assert len(errors) == 1 and "DET001" in errors[0]
    errors, notes = detlint.check_ratchet({"DET001": 3, "DET002": 1}, base)
    assert not errors and len(notes) == 1 and "DET002" in notes[0]
    # a brand-new rule with waivers is an increase from 0
    errors, _ = detlint.check_ratchet({"DET009": 1}, {})
    assert errors


def test_baseline_file_matches_live_waiver_counts():
    """tools/detlint_baseline.json is the checked-in ratchet state: it
    must equal the current per-rule waiver counts exactly (CI fails on
    increase; a stale-high baseline would let new waivers slip in)."""
    import json
    files = walker.iter_source_files([REPO / "src" / "repro"])
    counts = detlint.waiver_counts(rules.run_lint(files))
    baseline = json.loads((REPO / "tools" /
                           "detlint_baseline.json").read_text())
    ast_rules = {k: v for k, v in baseline.items()
                 if not k.startswith("DET1")}
    assert counts == ast_rules, (
        f"baseline drift: live {counts} vs pinned {ast_rules} — run "
        f"`python tools/detlint.py --write-baseline`")


# ---------------------------------------------------------------------------
# The repo itself is clean
# ---------------------------------------------------------------------------


def test_repo_has_zero_unwaived_ast_findings():
    files = walker.iter_source_files([REPO / "src" / "repro"])
    found = unwaived(rules.run_lint(files))
    assert not found, "\n".join(str(f) for f in found)


def test_every_waiver_states_a_reason():
    files = walker.iter_source_files([REPO / "src" / "repro"])
    bare = [f for f in rules.run_lint(files) if f.waived and not f.reason]
    assert not bare, "\n".join(str(f) for f in bare)


# ---------------------------------------------------------------------------
# Layer 2: jaxpr contract checks
# ---------------------------------------------------------------------------


def test_count_primitive_recurses_into_scan_bodies():
    import jax
    import jax.numpy as jnp
    from repro.analysis import contracts

    def barrierless(v):
        acc = jnp.zeros((2,))
        for i in range(4):
            acc = acc + v[i]
        return acc

    def pinned(v):
        def body(acc, row):
            return jax.lax.optimization_barrier(acc + row), None
        acc, _ = jax.lax.scan(body, jnp.zeros((2,)), v)
        return acc

    vals = jnp.ones((4, 2))
    assert contracts.count_primitive(
        jax.make_jaxpr(barrierless)(vals), "optimization_barrier") == 0
    # the barrier lives in the scan *body* jaxpr: counting must recurse
    assert contracts.count_primitive(
        jax.make_jaxpr(pinned)(vals), "optimization_barrier") >= 1


def test_contracts_clean_on_live_registries():
    """The full traced matrix: carry dtypes, barriers, invariance and
    coverage all hold; the only expected finding is the documented
    ``fast``-tier float-merge tolerance, surfaced as *waived*."""
    from repro.analysis import contracts
    findings = contracts.run_contracts()
    assert not [f for f in findings if not f.waived], \
        "\n".join(str(f) for f in findings)
    assert any(f.rule == "DET102" and f.path == "fast" and f.waived
               for f in findings)


def test_contract_coverage_spans_the_whole_matrix():
    """Every registered policy x backend x op that claims support must
    trace — and the matrix must actually be the full outer product
    (today: 6 ops x (4+4+4+3+4 supported policy/backend pairs) = 114+,
    pinned here as >= 100 so registry growth can only raise it)."""
    from repro.analysis.contracts import _Ctx
    ctx = _Ctx.build()
    combos = sum(1 for _ in ctx.ops
                 for p in ctx.policies.values()
                 for b in ctx.backends.values() if b.supports(p))
    assert combos >= 100
    assert combos == len(ctx.ops) * sum(
        1 for p in ctx.policies.values()
        for b in ctx.backends.values() if b.supports(p))


def test_det101_catches_wrong_carry_dtype():
    """A policy declaring an int32 carry while its fold actually carries
    f32 is exactly the bug the carry contract exists for."""
    import jax.numpy as jnp
    from repro.analysis import contracts
    from repro.reduce.policy import POLICIES, Policy

    class _BrokenInt(Policy):
        name = "_broken_int"
        merge_is_add = True

        @property
        def carry_dtypes(self):
            return (jnp.int32,)        # lies: update() folds f32

        def update(self, carry, contrib):
            (c,) = carry
            return (c.astype(jnp.float32) + contrib,)

    POLICIES["_broken_int"] = _BrokenInt()
    try:
        findings = contracts.run_contracts(checks=("carry",))
        hits = [f for f in findings
                if f.rule == "DET101" and "_broken_int" in f.path
                and not f.waived]
        assert hits, "\n".join(str(f) for f in findings)
    finally:
        del POLICIES["_broken_int"]


def test_det102_catches_unallowlisted_float_merge():
    """merge_is_add + float carry leaves without a tolerance entry must
    surface as an *unwaived* DET102 (the fast tier only passes because
    TOLERATED_FLOAT_MERGE vouches for it)."""
    from repro.analysis import contracts
    from repro.reduce.policy import POLICIES, Policy

    class _FloatMerge(Policy):
        name = "_float_merge"
        merge_is_add = True            # psum of float partials, no waiver

    POLICIES["_float_merge"] = _FloatMerge()
    try:
        findings = contracts.run_contracts(checks=("carry",))
        hits = [f for f in findings
                if f.rule == "DET102" and f.path == "_float_merge"]
        assert hits and not hits[0].waived
    finally:
        del POLICIES["_float_merge"]


def test_det005_catches_missing_hook_signature():
    """A registered policy whose ``update`` cannot accept the schedule's
    two positional args is flagged by the registry reflection rule."""
    from repro.reduce.policy import POLICIES, Policy

    class _BadHook(Policy):
        name = "_bad_hook"

        def update(self, carry):       # schedule calls update(carry, c)
            return carry

    POLICIES["_bad_hook"] = _BadHook()
    try:
        findings = rules.check_registries()
        hits = [f for f in findings
                if f.rule == "DET005" and "_bad_hook" in f.message
                and not f.waived]
        assert hits, "\n".join(str(f) for f in findings)
    finally:
        del POLICIES["_bad_hook"]


# ---------------------------------------------------------------------------
# The CLI
# ---------------------------------------------------------------------------


def test_cli_exits_zero_on_clean_repo_with_ratchet():
    assert detlint.main(["--ast-only", "--check-waivers", "-q"]) == 0


def test_cli_exits_nonzero_on_dirty_fixture(tmp_path):
    bad = tmp_path / "src" / "repro" / "models" / "bad.py"
    bad.parent.mkdir(parents=True)
    bad.write_text("import jax.numpy as jnp\n"
                   "def f(x):\n"
                   "    return jnp.sum(x)\n")
    assert detlint.main(["--ast-only", "-q", str(bad)]) == 1


def test_symbol_origin_ok_rejects_stale_reexport():
    """The moved-module guard the doc checker now runs: a documented
    path resolving only through a foreign package's re-export fails."""
    import repro.serve as serve
    import repro.reduce as reduce_pkg
    serve.ReduceOp = reduce_pkg.ReduceOp       # simulate a stale re-export
    try:
        assert walker.symbol_resolves("repro.serve.ReduceOp")
        assert not walker.symbol_origin_ok("repro.serve.ReduceOp")
        assert walker.symbol_origin_ok("repro.reduce.ReduceOp")
    finally:
        del serve.ReduceOp
