"""Staged block-program tests: the planner, the two contrib forms, and
the double-buffered kernel grid.

The contracts under test:

  * ``plan_program`` picks the lane-parallel contrib only for
    integer-domain policies at large label counts ("auto" is a pure
    performance decision);
  * the lane form is **bitwise** the one-hot dot for integer-domain
    tiers, on every backend (associative int32 addition — same multiset
    of adds per segment), and tolerance-close for the float tiers;
  * the pallas supertile depth (``blocks_per_step``) never changes a
    result bit, for any policy — the double buffering moves tiles, not
    the fold order;
  * the staged prepare split (``prepare_ctx`` + row-local ``to_domain``)
    reproduces the whole-stream ``prepare`` bit for bit, which is what
    lets the shard_map backend digitize in-shard.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import reduce as R
from repro.kernels import ops
from repro.kernels.jugglepac_segsum import (blocks_per_step_for,
                                            segsum_policy_pallas)
from repro.reduce.program import (LANE_MIN_SEGMENTS, BlockProgram,
                                  block_contrib, plan_program)

POLICIES = ("fast", "compensated", "exact", "exact2", "procrastinate")
INT_POLICIES = ("exact", "exact2", "procrastinate")
FLOAT_POLICIES = ("fast", "compensated")
BACKENDS = ("ref", "blocked", "pallas")


def _data(n, d, s, seed=0):
    rng = np.random.RandomState(seed)
    return (jnp.asarray(rng.randn(n, d).astype(np.float32)),
            jnp.asarray(rng.randint(0, s, n)))


# ---------------------------------------------------------------------------
# the planner
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", POLICIES)
def test_plan_auto_contrib_selection(policy):
    pol = R.get_policy(policy)
    small = plan_program(pol, num_segments=LANE_MIN_SEGMENTS - 1,
                         domain_width=pol.domain_width(8))
    large = plan_program(pol, num_segments=LANE_MIN_SEGMENTS,
                         domain_width=pol.domain_width(8))
    assert small.contrib == "dot"       # below crossover: always the dot
    if policy in INT_POLICIES:
        assert large.contrib == "lanes"
    else:
        # float tiers never switch under auto (rounding-order consent)
        assert large.contrib == "dot"


def test_plan_program_declares_both_stages_with_bounds():
    prog = plan_program("exact2", num_segments=64, domain_width=128)
    assert isinstance(prog, BlockProgram)
    assert prog.stage("contrib").bound == "memory"
    assert prog.stage("update").bound == "compute"
    assert prog.stage("contrib").bytes > 0
    assert prog.stage("update").flops > 0
    with pytest.raises(KeyError, match="no stage"):
        prog.stage("gather")
    # hashable: rides through jit static args like ReduceSpec
    assert hash(prog) == hash(plan_program("exact2", num_segments=64,
                                           domain_width=128))


def test_dot_flops_grow_with_segments_lanes_flops_do_not():
    pol = R.get_policy("exact2")
    dot_small = pol.stage_costs(512, 128, 16, contrib="dot")
    dot_large = pol.stage_costs(512, 128, 1024, contrib="dot")
    lane_small = pol.stage_costs(512, 128, 16, contrib="lanes")
    lane_large = pol.stage_costs(512, 128, 1024, contrib="lanes")
    assert dot_large["contrib"]["flops"] > dot_small["contrib"]["flops"]
    assert lane_large["contrib"]["flops"] == lane_small["contrib"]["flops"]


def test_reduce_rejects_unknown_contrib():
    with pytest.raises(ValueError, match="contrib"):
        R.reduce(jnp.ones(8), contrib="scatter")


# ---------------------------------------------------------------------------
# lanes vs dot, per backend
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("policy", INT_POLICIES)
def test_lanes_bitwise_equals_dot_for_integer_tiers(policy, backend):
    """The planner's crossover is bitwise-invisible where it applies."""
    vals, ids = _data(600, 8, 40, seed=1)        # S > LANE_MIN_SEGMENTS
    kw = dict(segment_ids=ids, num_segments=40, policy=policy,
              backend=backend, block_size=128)
    a = np.asarray(R.reduce(vals, contrib="dot", **kw))
    b = np.asarray(R.reduce(vals, contrib="lanes", **kw))
    c = np.asarray(R.reduce(vals, contrib="auto", **kw))
    assert np.array_equal(a, b)                  # zero bits changed
    assert np.array_equal(a, c)


@pytest.mark.parametrize("policy", FLOAT_POLICIES)
def test_lanes_opt_in_close_for_float_tiers(policy):
    vals, ids = _data(600, 8, 40, seed=2)
    kw = dict(segment_ids=ids, num_segments=40, policy=policy,
              backend="blocked", block_size=128)
    a = np.asarray(R.reduce(vals, contrib="dot", **kw))
    b = np.asarray(R.reduce(vals, contrib="lanes", **kw))
    # auto == dot for float tiers (no silent rounding-order change) ...
    assert np.array_equal(a, np.asarray(R.reduce(vals, contrib="auto",
                                                 **kw)))
    # ... and the opt-in lane fold is the same sum, different order
    np.testing.assert_allclose(a, b, rtol=1e-6, atol=1e-6)


def test_block_contrib_is_the_shared_gather():
    """ref/blocked/pallas all call this helper; check both forms against
    a scatter oracle on one block."""
    pol = R.get_policy("exact")
    rng = np.random.RandomState(3)
    vals = jnp.asarray(rng.randint(-50, 50, (128, 4)).astype(np.int32))
    ids = jnp.asarray(rng.randint(0, 6, 128).astype(np.int32))
    oracle = np.zeros((6, 4), np.int32)
    np.add.at(oracle, np.asarray(ids), np.asarray(vals))
    dot = block_contrib(vals, ids, 6, pol)
    prog = plan_program(pol, num_segments=6, domain_width=4,
                        contrib="lanes")
    lanes = block_contrib(vals, ids, 6, pol, prog)
    assert np.array_equal(np.asarray(dot), oracle)
    assert np.array_equal(np.asarray(lanes), oracle)


# ---------------------------------------------------------------------------
# the double-buffered pallas grid
# ---------------------------------------------------------------------------


def test_blocks_per_step_sizing():
    assert blocks_per_step_for(512, 16) == 8     # tiny rows: cap at 8
    assert blocks_per_step_for(512, 4096) == 1   # huge rows: no stacking
    # monotone non-increasing in width
    widths = [16, 64, 256, 1024, 4096]
    depths = [blocks_per_step_for(512, w) for w in widths]
    assert depths == sorted(depths, reverse=True)


@pytest.mark.parametrize("policy", POLICIES)
def test_pallas_supertile_depth_is_bitwise_invisible(policy):
    """blocks_per_step ∈ {1, 2, 4, 8} — including depths that force
    whole-sentinel-block padding — changes zero bits for every tier."""
    pol = R.get_policy(policy)
    vals, ids = _data(768, 8, 5, seed=4)         # 6 blocks of 128
    ids = R.mask_out_of_range(ids, 5)
    domain, ctx = pol.prepare(vals, 768)
    outs = []
    for bps in (1, 2, 4, 8):                     # 6 % 4 != 0: pads
        carry = segsum_policy_pallas(domain, ids, 5, policy=pol,
                                     block_rows=128, interpret=True,
                                     blocks_per_step=bps)
        outs.append(np.asarray(pol.finalize(carry, ctx)))
    for o in outs[1:]:
        assert np.array_equal(outs[0], o)


def test_ops_segment_sum_bps_bitwise():
    vals, ids = _data(1024, 4, 8, seed=5)
    base = np.asarray(ops.segment_sum(vals, ids, 8))
    for bps in (1, 2, 4):
        out = np.asarray(ops.segment_sum(vals, ids, 8,
                                         blocks_per_step=bps))
        assert np.array_equal(base, out)


# ---------------------------------------------------------------------------
# the staged prepare split
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", POLICIES)
def test_prepare_ctx_to_domain_equals_prepare(policy):
    """The split the shard_map backend runs in-shard: global stat →
    ctx, then row-local to_domain — must equal whole-stream prepare
    bitwise, row subsets included."""
    pol = R.get_policy(policy)
    vals, _ = _data(500, 8, 1, seed=6)
    v32 = vals.astype(jnp.float32)
    domain, ctx = pol.prepare(vals, 500)
    m = jnp.max(jnp.abs(v32)) if pol.needs_max_stat else None
    ctx2 = pol.prepare_ctx(m, 500)
    split = pol.to_domain(v32, ctx2)
    assert np.array_equal(np.asarray(domain), np.asarray(split))
    # row-locality: a shard's slice maps identically under the shared ctx
    half = pol.to_domain(v32[:250], ctx2)
    assert np.array_equal(np.asarray(domain)[:250], np.asarray(half))
    if ctx is not None:
        assert np.asarray(ctx) == np.asarray(ctx2)


@pytest.mark.parametrize("policy", POLICIES)
def test_front_door_auto_program_matches_explicit(policy):
    """reduce() plans the program itself; pinning the same program via
    ReduceSpec(contrib=...) must reproduce it bitwise."""
    vals, ids = _data(400, 4, 64, seed=7)        # S past the crossover
    out_auto = np.asarray(R.reduce(vals, segment_ids=ids, num_segments=64,
                                   policy=policy, backend="blocked"))
    forced = "lanes" if policy in INT_POLICIES else "dot"
    spec = R.ReduceSpec(policy=policy, backend="blocked", contrib=forced)
    out_spec = np.asarray(R.reduce(vals, segment_ids=ids, num_segments=64,
                                   spec=spec))
    assert np.array_equal(out_auto, out_spec)
