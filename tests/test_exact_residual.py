"""exact2's residual limb: "exact means exact" off the dyadic grid.

The old two-limb exact2 silently dropped the sub-quantum bits of any
input not on its ~2^-21-of-max dyadic grid; these tests pin adversarial
non-dyadic streams where that defect *provably* exceeds 1 ulp vs the f64
reference, and assert the three-limb tier closes it on every backend —
ref / blocked / pallas in-process, shard_map at 1/2/8 simulated devices
in a subprocess — while the canonical int32 hi/lo limbs stay bitwise
identical across backends, block sizes, and shard counts.
"""

import os
import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro import reduce as R
from repro.core import intac

REPO = Path(__file__).resolve().parent.parent
N = 1 << 20


def _ulp(x: float) -> float:
    return float(np.spacing(np.abs(np.float32(x)), dtype=np.float32))


def third_stream(n=N) -> np.ndarray:
    """1/3 + ulp-scale noise: every value sits ~1/3 of a quantum off the
    exact2 grid with a shared bias, so the old tier's per-element drop
    accumulates linearly (~3 ulp of the sum at N=2^20)."""
    rng = np.random.RandomState(7)
    return (1 / 3 + rng.randn(n) * 1e-9).astype(np.float32)


def cancellation_stream(n=N) -> np.ndarray:
    """Catastrophic-cancellation pairs (+/- up-to-1000 values that cancel
    exactly) interleaved with an off-grid 1/3 payload: the huge max|x|
    coarsens the old tier's quantum to ~2^-11, shredding the payload
    (~11 ulp of the surviving sum at N=2^20)."""
    rng = np.random.RandomState(11)
    big = rng.uniform(100.0, 1000.0, n // 2).astype(np.float32)
    x = np.empty(n, np.float32)
    x[0::4] = big[0::2]
    x[1::4] = -big[0::2]
    x[2::4] = big[1::2] + np.float32(1 / 3)
    x[3::4] = -big[1::2]
    return x


def _old_exact2(x: np.ndarray) -> float:
    """The pre-fix behavior: run the schedule, finalize the *integer
    limbs only* (what the two-limb tier returned)."""
    pol = R.get_policy("exact2")
    xj = jnp.asarray(x)[:, None]
    domain, scale = pol.prepare(xj, len(x))
    carry = R.get_backend("blocked").run(
        domain, jnp.zeros(len(x), jnp.int32), 1, policy=pol, block_size=512)
    return float(intac.limbs_resolve(carry[0], carry[1], scale)[0, 0])


@pytest.mark.parametrize("stream", [third_stream, cancellation_stream])
def test_pinned_streams_defeat_the_old_tier(stream):
    """Regression pin: on these streams the integer limbs alone — the
    whole of the old exact2 — exceed 1 ulp vs f64.  If this ever stops
    holding, the adversarial fixtures have gone stale."""
    x = stream()
    ref = float(np.sum(x.astype(np.float64)))
    assert abs(_old_exact2(x) - ref) > _ulp(ref)


@pytest.mark.parametrize("stream", [third_stream, cancellation_stream])
def test_residual_limb_within_1ulp_on_local_backends(stream):
    """The fix, end to end: <= 1 ulp vs f64 at N=2^20 on blocked/pallas
    (and on ref at 2^16 — the unrolled oracle is too slow to jit 2048
    blocks), with bitwise-equal results across backends at a fixed
    schedule and bitwise-equal canonical limbs across block sizes."""
    x = stream()
    ref = float(np.sum(x.astype(np.float64)))
    outs = {b: float(R.reduce(jnp.asarray(x), policy="exact2", backend=b))
            for b in ("blocked", "pallas")}
    for b, out in outs.items():
        assert abs(out - ref) <= _ulp(ref), (b, out, ref)
    assert outs["blocked"] == outs["pallas"]          # same schedule: bits

    xs = x[: 1 << 16]
    refs = float(np.sum(xs.astype(np.float64)))
    out_ref = float(R.reduce(jnp.asarray(xs), policy="exact2",
                             backend="ref"))
    assert abs(out_ref - refs) <= _ulp(refs)

    # canonical integer limbs: bitwise across block sizes and backends
    pol = R.get_policy("exact2")
    domain, _ = pol.prepare(jnp.asarray(x)[:, None], len(x))
    ids = jnp.zeros(len(x), jnp.int32)
    limbs = []
    for bk, bs in (("blocked", 512), ("blocked", 128), ("pallas", 512)):
        c = R.get_backend(bk).run(domain, ids, 1, policy=pol, block_size=bs)
        limbs.append([np.asarray(v)
                      for v in intac.limbs_canonical(c[0], c[1])])
    for other in limbs[1:]:
        assert all(np.array_equal(a, b) for a, b in zip(limbs[0], other))


SHARD_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro import reduce as R
from repro.core import intac
import sys
sys.path.insert(0, "@TESTDIR@")
from test_exact_residual import third_stream, cancellation_stream, _ulp

for name, stream in (("third", third_stream), ("cancel",
                                               cancellation_stream)):
    x = stream()
    ref = float(np.sum(x.astype(np.float64)))
    xj = jnp.asarray(x)
    pol = R.get_policy("exact2")
    domain, _ = pol.prepare(xj[:, None], len(x))
    ids = jnp.zeros(len(x), jnp.int32)
    base = R.get_backend("blocked").run(domain, ids, 1, policy=pol,
                                        block_size=512)
    lbase = [np.asarray(v)
             for v in intac.limbs_canonical(base[0], base[1])]
    for ndev in (1, 2, 8):
        mesh = Mesh(np.asarray(jax.devices()[:ndev]), ("shards",))
        out = float(R.reduce(xj, policy="exact2", backend="shard_map",
                             mesh=mesh))
        csh = R.get_backend("shard_map").run(domain, ids, 1, policy=pol,
                                             block_size=512, mesh=mesh)
        lsh = intac.limbs_canonical(csh[0], csh[1])
        limbs_ok = all(np.array_equal(a, np.asarray(b))
                       for a, b in zip(lbase, lsh))
        ok = abs(out - ref) <= _ulp(ref)
        print(f"SHARD {name} {ndev} {int(ok)} {int(limbs_ok)}")
"""


def test_residual_limb_within_1ulp_through_shard_map():
    """The fix across the mesh: <= 1 ulp vs f64 at N=2^20 through the
    shard_map backend at 1/2/8 simulated devices, with the canonical
    integer limbs bitwise identical to the single-device schedule."""
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    snippet = SHARD_SNIPPET.replace("@TESTDIR@", str(REPO / "tests"))
    r = subprocess.run([sys.executable, "-c", snippet],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    rows = [ln.split() for ln in r.stdout.strip().splitlines()
            if ln.startswith("SHARD")]
    assert len(rows) == 6
    for _, name, ndev, ok, limbs_ok in rows:
        assert ok == "1", (name, ndev)
        assert limbs_ok == "1", (name, ndev)
