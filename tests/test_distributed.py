"""Distribution-layer tests: sharding specs, mesh plans, shard_map step.

These run on 8 fake CPU devices (set before jax import via conftest's
child-process helper is unnecessary — we spawn with XLA_FLAGS here).
"""

import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.distributed import sharding as shd
from repro.launch import specs as sp
from repro.models.config import SHAPES_BY_NAME

REPO = Path(__file__).resolve().parent.parent


class _FakeMesh:
    """Just enough mesh for mesh_plan / spec-structure tests."""
    def __init__(self, shape):
        self.shape = dict(shape)
        self.axis_names = tuple(shape)
        self.size = int(np.prod(list(self.shape.values())))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_specs_cover_tree(arch):
    """Every leaf gets a spec of matching rank; stacked leading axis is
    never sharded."""
    cfg = get_config(arch)
    params_abs = sp.abstract_params(cfg)
    specs = shd.param_specs(cfg, params_abs)
    flat_p = jax.tree_util.tree_flatten_with_path(params_abs)[0]
    flat_s = jax.tree_util.tree_flatten_with_path(
        specs, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))[0]
    assert len(flat_p) == len(flat_s)
    for (pp, leaf), (ps, spec) in zip(flat_p, flat_s):
        assert len(spec) <= leaf.ndim, (pp, spec, leaf.shape)
        # stacked block leaves: leading (n_periods) dim unsharded
        if "blocks" in "/".join(str(x) for x in pp):
            assert len(spec) == 0 or spec[0] is None


@pytest.mark.parametrize("arch,shape", [
    ("mixtral-8x22b", "train_4k"), ("xlstm-125m", "train_4k"),
    ("jamba-v0.1-52b", "long_500k"), ("phi3-medium-14b", "decode_32k")])
def test_mesh_plan(arch, shape):
    cfg = get_config(arch)
    mesh = _FakeMesh({"data": 16, "model": 16})
    plan = shd.mesh_plan(cfg, SHAPES_BY_NAME[shape], mesh)
    if arch == "xlstm-125m":
        assert plan["replicate_params"]
        if shape == "train_4k":
            assert plan["batch_dp"] == ("data", "model")
    else:
        assert not plan["replicate_params"]
    if shape == "long_500k":
        assert plan["batch_dp"] == ()            # batch=1 can't shard
    if arch == "mixtral-8x22b":
        assert plan["moe_ff_axis"] == "model"    # 8 experts on 16: expert-TP
    if arch == "jamba-v0.1-52b":
        assert plan["moe_expert_axis"] == "model"  # 16 experts: true EP


def test_fsdp_param_bytes_fit():
    """Param + optimizer bytes per device fit the 16 GB HBM budget for
    every arch under the plan's shardings (analytic check)."""
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        total = cfg.param_counts()["total"]
        devices = 256
        if cfg.family == "ssm":
            per_dev = total * (2 + 8)            # replicated, tiny
        else:
            per_dev = total * (2 + 8) / devices  # bf16 + f32 m,v; 2D-sharded
        assert per_dev < 10e9, (arch, per_dev / 1e9)


SHARDMAP_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs import get_smoke_config
from repro.models import init_params
from repro.optim import adamw
from repro.launch.mesh import make_mesh
from repro.distributed.collectives import make_shardmap_train_step, init_residuals
cfg = get_smoke_config("stablelm-1.6b")
params = init_params(jax.random.PRNGKey(0), cfg)
opt = adamw.init(params); res = init_residuals(params)
mesh = make_mesh((4, 2), ("data", "pod"))
lr_fn = adamw.cosine_schedule(1e-3, 2, 20)
batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (16, 64), 0, cfg.vocab)}
# compressed + microbatched
s1 = jax.jit(make_shardmap_train_step(cfg, mesh, lr_fn=lr_fn,
      num_microbatches=2, compress_bits=8))
p1, o1, r1, m1 = s1(params, opt, res, batch)
p1b, *_ = s1(params, opt, res, batch)
det = all(np.array_equal(a, b) for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p1b)))
# uncompressed reference
s2 = jax.jit(make_shardmap_train_step(cfg, mesh, lr_fn=lr_fn,
      num_microbatches=1, compress_bits=None))
p2, o2, r2, m2 = s2(params, opt, res, batch)
# integer-exact end to end: microbatch accumulation through the
# repro.reduce front door + exact2 cross-device mean
s3 = jax.jit(make_shardmap_train_step(cfg, mesh, lr_fn=lr_fn,
      num_microbatches=2, compress_bits=None, reduce_policy="exact2",
      microbatch_reduce="exact2"))
p3, o3, r3, m3 = s3(params, opt, res, batch)
p3b, *_ = s3(params, opt, res, batch)
det3 = all(np.array_equal(a, b) for a, b in zip(jax.tree.leaves(p3), jax.tree.leaves(p3b)))
num3 = sum(float(jnp.sum((a.astype(jnp.float32)-b.astype(jnp.float32))**2))
           for a, b in zip(jax.tree.leaves(p3), jax.tree.leaves(p2)))
# compressed step must track the exact step closely (8-bit + EF)
num = sum(float(jnp.sum((a.astype(jnp.float32)-b.astype(jnp.float32))**2))
          for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
den = sum(float(jnp.sum((a.astype(jnp.float32)-b.astype(jnp.float32))**2))
          for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p2)))
print("DET", det)
print("RELERR", num / max(den, 1e-30))
print("LOSS", float(m1["loss"]), float(m2["loss"]))
print("DET3", det3)
print("RELERR3", num3 / max(den, 1e-30))
"""


@pytest.mark.slow
def test_shardmap_intac_step():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    r = subprocess.run([sys.executable, "-c", SHARDMAP_SNIPPET],
                       capture_output=True, text=True, env=env, timeout=900)
    assert r.returncode == 0, r.stderr[-3000:]
    out = dict(line.split(None, 1) for line in r.stdout.strip().splitlines())
    assert out["DET"] == "True"
    assert float(out["RELERR"].split()[0]) < 0.5
    assert out["DET3"] == "True"
    assert float(out["RELERR3"].split()[0]) < 0.5
