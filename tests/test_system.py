"""End-to-end system behaviour.

The paper's contribution is an accumulation *discipline*; the system test
is that the full framework — model zoo, data pipeline, optimizer, pairing
trees, checkpointing — trains: loss decreases on the structured synthetic
stream, deterministically, and remat/chunking choices don't change the math.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.data.pipeline import DataCfg, SyntheticLM
from repro.models import init_params, loss_fn
from repro.optim import adamw
from repro.train.steps import make_train_step


def test_loss_decreases_end_to_end():
    cfg = get_smoke_config("stablelm-1.6b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    dcfg = DataCfg(vocab=cfg.vocab, seq_len=64, global_batch=4, seed=1)
    src = SyntheticLM(dcfg)
    lr_fn = adamw.cosine_schedule(3e-3, 5, 60)
    step = jax.jit(make_train_step(cfg, lr_fn=lr_fn, remat=False,
                                   moe_impl="dense"))
    losses = []
    for i in range(30):
        batch = {k: jnp.asarray(v) for k, v in src.batch(i).items()}
        params, opt, metrics = step(params, opt, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.2, losses


def test_training_deterministic():
    cfg = get_smoke_config("xlstm-125m")
    dcfg = DataCfg(vocab=cfg.vocab, seq_len=32, global_batch=2, seed=3)
    src = SyntheticLM(dcfg)
    lr_fn = adamw.cosine_schedule(1e-3, 2, 10)

    def run():
        params = init_params(jax.random.PRNGKey(0), cfg)
        opt = adamw.init(params)
        step = jax.jit(make_train_step(cfg, lr_fn=lr_fn, remat=False,
                                       moe_impl="dense"))
        for i in range(5):
            batch = {k: jnp.asarray(v) for k, v in src.batch(i).items()}
            params, opt, m = step(params, opt, batch)
        return params, float(m["loss"])

    p1, l1 = run()
    p2, l2 = run()
    assert l1 == l2
    assert all(np.array_equal(a, b)
               for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))


def test_data_pipeline_restart_purity():
    dcfg = DataCfg(vocab=1000, seq_len=16, global_batch=4, seed=9)
    src = SyntheticLM(dcfg)
    a = src.batch(17)["tokens"]
    b = SyntheticLM(dcfg).batch(17)["tokens"]      # fresh instance
    assert np.array_equal(a, b)


def test_data_pipeline_host_sharding():
    h0 = SyntheticLM(DataCfg(vocab=1000, seq_len=16, global_batch=8,
                             seed=4, num_hosts=2, host_id=0)).batch(0)["tokens"]
    h1 = SyntheticLM(DataCfg(vocab=1000, seq_len=16, global_batch=8,
                             seed=4, num_hosts=2, host_id=1)).batch(0)["tokens"]
    assert h0.shape == (4, 16) and h1.shape == (4, 16)
    assert not np.array_equal(h0, h1)


def test_remat_matches_no_remat():
    cfg = get_smoke_config("phi3-medium-14b")
    params = init_params(jax.random.PRNGKey(2), cfg)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(3), (2, 32),
                                          0, cfg.vocab)}
    g1 = jax.grad(lambda p: loss_fn(p, cfg, batch, remat=False,
                                    moe_impl="dense")[0])(params)
    g2 = jax.grad(lambda p: loss_fn(p, cfg, batch, remat=True,
                                    moe_impl="dense")[0])(params)
    for a, b in zip(jax.tree.leaves(g1), jax.tree.leaves(g2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   atol=1e-4, rtol=1e-3)
