"""Docs stay truthful: link/symbol resolution + doctest health.

CI runs ``tools/check_docs.py`` and ``pytest --doctest-modules`` as
explicit steps; these tests keep the same checks inside tier-1 so drift
is caught on any plain ``pytest`` run too.
"""

import doctest
import importlib
import subprocess
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent

sys.path.insert(0, str(REPO / "tools"))
import check_docs  # noqa: E402


def test_doc_references_resolve():
    errors = []
    for f in check_docs.DOC_FILES:
        errors.extend(check_docs.check_file(f))
    errors.extend(check_docs.check_required_symbols())
    assert not errors, "\n".join(errors)


def test_docs_exist_and_are_linked_from_readme():
    readme = (REPO / "README.md").read_text()
    for doc in ("docs/architecture.md", "docs/policies.md"):
        assert (REPO / doc).exists(), doc
        assert doc in readme, f"README does not link {doc}"


def test_no_stale_shim_references_in_sources_or_docs():
    """The PR-3-deleted shims must not be referenced as live API anywhere
    in sources, docs, or examples (tests/CHANGES record history and are
    exempt)."""
    stale = ("segment_sum_blocked", "intac_sum_exact")
    roots = [REPO / "src", REPO / "docs", REPO / "examples",
             REPO / "benchmarks", REPO / "README.md"]
    hits = []
    for root in roots:
        files = [root] if root.is_file() else \
            [*root.rglob("*.py"), *root.rglob("*.md")]
        for f in files:
            text = f.read_text()
            hits.extend(f"{f.relative_to(REPO)}: {s}"
                        for s in stale if s in text)
    assert not hits, hits


def test_no_tracked_bytecode():
    """Build products must never be committed (a past commit checked
    ``src/repro/**/__pycache__`` .pyc binaries in): the git index must
    hold no ``.pyc``/``__pycache__`` paths, and .gitignore must keep it
    that way.  CI mirrors this as an explicit hygiene step."""
    try:
        out = subprocess.run(["git", "ls-files"], cwd=REPO,
                             capture_output=True, text=True, timeout=60)
    except (OSError, subprocess.TimeoutExpired):
        pytest.skip("git unavailable")
    if out.returncode != 0:
        pytest.skip("not a git checkout")
    tracked = out.stdout.splitlines()
    bad = [p for p in tracked
           if p.endswith((".pyc", ".pyo")) or "__pycache__" in p]
    assert not bad, f"tracked bytecode: {bad}"
    gitignore = (REPO / ".gitignore").read_text()
    assert "__pycache__/" in gitignore and "*.py[cod]" in gitignore


def test_reduce_package_doctests_pass():
    """Every public-surface example in src/repro/reduce/ executes as
    written (the same modules CI runs --doctest-modules over)."""
    failures, total = 0, 0
    for mod_name in ("repro.reduce.api", "repro.reduce.policy",
                     "repro.reduce.backends", "repro.reduce.collective",
                     "repro.reduce.accumulator"):
        mod = importlib.import_module(mod_name)
        res = doctest.testmod(mod, verbose=False)
        failures += res.failed
        total += res.attempted
    assert failures == 0
    assert total >= 10          # the audit promised examples, keep them
