"""Property-based laws of the reduction algebra (ISSUE 9).

Every law lives in a plain ``check_*`` helper driven twice:

  * a hypothesis ``@given`` wrapper — randomized inputs, runs wherever
    hypothesis is installed (CI's tier1 job installs the [dev] extra);
  * a fixed-example ``test_*`` twin — runs everywhere, so the laws stay
    exercised even where the conftest stub skips the ``@given`` path.

The laws:
  * all-ones ``weighted_sum`` is *bitwise* ``op="sum"`` on every tier
    (IEEE ``x * 1.0`` is an identity, and the algebra's ``pre`` runs
    above every policy);
  * integer tiers are linear and permutation-invariant in the weighted
    stream (associative int32 folds; the quantization scale is a
    function of max|value| and N, both permutation-invariant);
  * ``moments`` is shift-robust under the exact tiers and its variance
    is never negative;
  * the cascaded-accumulator construction (CascadeAccumulator +
    cascade_poly_coeffs) reproduces the direct ``op="poly"`` weighting,
    and ``fir_weights`` matches the convolution oracle.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro import reduce as R
from repro.reduce import CascadeAccumulator
from repro.reduce.algebra import (cascade_poly_coeffs, cascade_weights,
                                  fir_weights, poly_weights)

POLICIES = ("fast", "compensated", "exact", "exact2", "procrastinate")
INT_POLICIES = ("exact", "exact2", "procrastinate")


def _data(n, d, s, seed):
    rng = np.random.RandomState(seed)
    vals = (rng.randn(n, d) * 10 ** rng.uniform(-2, 2, (n, 1))) \
        .astype(np.float32)
    ids = rng.randint(-1, s, n).astype(np.int32)   # -1: sentinel rows too
    w = rng.uniform(-2.0, 2.0, n).astype(np.float32)
    return vals, ids, w


# ---------------------------------------------------------------------------
# law: weighted_sum(w=1) == sum, bitwise, per tier
# ---------------------------------------------------------------------------


def check_all_ones_weighted_is_sum(seed, s, policy, block_size=64):
    vals, ids, _ = _data(200, 3, s, seed)
    kw = dict(segment_ids=jnp.asarray(ids), num_segments=s, policy=policy,
              backend="blocked", block_size=block_size)
    plain = R.reduce(jnp.asarray(vals), op="sum", **kw)
    ones = R.reduce(jnp.asarray(vals), op="weighted_sum",
                    weights=jnp.ones(len(vals)), **kw)
    assert np.array_equal(np.asarray(plain), np.asarray(ones)), policy


@pytest.mark.parametrize("policy", POLICIES)
def test_all_ones_weighted_is_sum(policy):
    for seed in (0, 1, 2):
        check_all_ones_weighted_is_sum(seed, 5, policy)


@settings(max_examples=15, deadline=None)
@given(seed=st.integers(0, 2 ** 16), s=st.integers(1, 8),
       policy=st.sampled_from(POLICIES))
def test_prop_all_ones_weighted_is_sum(seed, s, policy):
    check_all_ones_weighted_is_sum(seed, s, policy)


# ---------------------------------------------------------------------------
# law: integer tiers — permutation invariance of the weighted stream
# ---------------------------------------------------------------------------


def check_weighted_permutation_invariance(seed, policy):
    vals, ids, w = _data(160, 2, 4, seed)
    perm = np.random.RandomState(seed + 1).permutation(len(vals))
    kw = dict(num_segments=4, policy=policy, backend="blocked",
              block_size=32)
    a = R.reduce(jnp.asarray(vals), segment_ids=jnp.asarray(ids),
                 op="weighted_sum", weights=jnp.asarray(w), **kw)
    b = R.reduce(jnp.asarray(vals[perm]), segment_ids=jnp.asarray(ids[perm]),
                 op="weighted_sum", weights=jnp.asarray(w[perm]), **kw)
    assert np.array_equal(np.asarray(a), np.asarray(b)), policy


@pytest.mark.parametrize("policy", INT_POLICIES)
def test_weighted_permutation_invariance(policy):
    for seed in (0, 3):
        check_weighted_permutation_invariance(seed, policy)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), policy=st.sampled_from(INT_POLICIES))
def test_prop_weighted_permutation_invariance(seed, policy):
    check_weighted_permutation_invariance(seed, policy)


# ---------------------------------------------------------------------------
# law: integer tiers — linearity in the weights
# ---------------------------------------------------------------------------


def check_weighted_linearity(seed, policy):
    """reduce(v, w1+w2) == reduce(v, w1) + reduce(v, w2) up to the tier's
    own resolution (each term is within ~1 ulp of its f64 reference for
    the exact2/procrastinate tiers, so the defect is bounded by the
    oracle's)."""
    vals, ids, w1 = _data(128, 2, 4, seed)
    w2 = np.roll(w1, 7)
    kw = dict(segment_ids=jnp.asarray(ids), num_segments=4, policy=policy,
              backend="blocked", block_size=32)
    vj = jnp.asarray(vals)
    both = np.asarray(R.reduce(vj, op="weighted_sum",
                               weights=jnp.asarray(w1 + w2), **kw))
    split = (np.asarray(R.reduce(vj, op="weighted_sum",
                                 weights=jnp.asarray(w1), **kw))
             + np.asarray(R.reduce(vj, op="weighted_sum",
                                   weights=jnp.asarray(w2), **kw)))
    scale = np.abs(vals).max() * np.abs(w1).max() * len(vals)
    assert np.allclose(both, split, atol=1e-4 * scale), policy


@pytest.mark.parametrize("policy", INT_POLICIES)
def test_weighted_linearity(policy):
    for seed in (0, 5):
        check_weighted_linearity(seed, policy)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), policy=st.sampled_from(INT_POLICIES))
def test_prop_weighted_linearity(seed, policy):
    check_weighted_linearity(seed, policy)


# ---------------------------------------------------------------------------
# law: moments — var >= 0 everywhere, shift-robust on the exact tiers
# ---------------------------------------------------------------------------


def check_moments_nonnegative_var(seed, policy):
    vals, ids, _ = _data(120, 3, 4, seed)
    mv = np.asarray(R.reduce(jnp.asarray(vals), segment_ids=jnp.asarray(ids),
                             num_segments=4, op="moments", policy=policy,
                             backend="blocked", block_size=32))
    assert mv.shape == (4, 2, 3)
    assert (mv[:, 1] >= 0.0).all(), policy


def check_moments_shift_robust(seed, policy, shift=64.0):
    """var(x + c) == var(x) up to the tier's resolution: the running
    sums are exact under the integer tiers, so the cancellation in
    E[x^2] - E[x]^2 is the only f32 step left."""
    rng = np.random.RandomState(seed)
    x = rng.randn(256).astype(np.float32)
    kw = dict(op="moments", policy=policy, backend="blocked", block_size=64)
    v0 = float(R.reduce(jnp.asarray(x), **kw)[1])
    v1 = float(R.reduce(jnp.asarray(x + np.float32(shift)), **kw)[1])
    assert v0 >= 0.0 and v1 >= 0.0
    assert abs(v0 - v1) <= 1e-3 * max(v0, 1.0), (policy, v0, v1)


@pytest.mark.parametrize("policy", POLICIES)
def test_moments_nonnegative_var(policy):
    for seed in (0, 1):
        check_moments_nonnegative_var(seed, policy)


@pytest.mark.parametrize("policy", ("exact2", "procrastinate"))
def test_moments_shift_robust(policy):
    for seed in (0, 2):
        check_moments_shift_robust(seed, policy)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), policy=st.sampled_from(POLICIES))
def test_prop_moments_nonnegative_var(seed, policy):
    check_moments_nonnegative_var(seed, policy)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16),
       policy=st.sampled_from(("exact2", "procrastinate")),
       shift=st.sampled_from((16.0, 64.0, 256.0)))
def test_prop_moments_shift_robust(seed, policy, shift):
    check_moments_shift_robust(seed, policy, shift)


# ---------------------------------------------------------------------------
# law: cascaded FIR == direct polynomial oracle
# ---------------------------------------------------------------------------


def check_cascade_matches_poly(seed, coeffs, n=48):
    """depth-k chained accumulators + the stage-mixing solve reproduce
    the direct ``op="poly"`` weighting (and both match the f64 oracle)."""
    rng = np.random.RandomState(seed)
    x = rng.randn(n).astype(np.float32)
    deg = len(coeffs)
    acc = CascadeAccumulator(deg)
    stt = acc.init(jnp.zeros(()))
    for v in x:
        stt = acc.push(stt, jnp.asarray(v))
    stages = np.asarray(acc.finalize(stt), np.float64)          # (deg,)
    alpha = cascade_poly_coeffs(coeffs, n)
    cascaded = float(sum(a * s for a, s in zip(alpha, stages)))
    direct = float(R.reduce(jnp.asarray(x), op="poly", coeffs=coeffs,
                            policy="exact2", backend="blocked"))
    i = np.arange(n, dtype=np.float64)
    oracle = float(np.sum(x.astype(np.float64)
                          * sum(c * i ** p for p, c in enumerate(coeffs))))
    tol = 1e-4 * max(1.0, abs(oracle))
    assert abs(direct - oracle) <= tol, (direct, oracle)
    assert abs(cascaded - oracle) <= tol, (cascaded, oracle)


def check_cascade_merge_is_concat(seed, depth, n=32, cut=13):
    """merge(prefix, suffix) == one-shot stream, exactly (the binomial
    stage-mixing law), and the final stage weights match
    ``cascade_weights``."""
    rng = np.random.RandomState(seed)
    x = rng.randn(n).astype(np.float32)
    acc = CascadeAccumulator(depth)

    def run(xs):
        s = acc.init(jnp.zeros(()))
        for v in xs:
            s = acc.push(s, jnp.asarray(v))
        return s

    whole = np.asarray(acc.finalize(run(x)))
    merged = np.asarray(acc.finalize(acc.merge(run(x[:cut]), run(x[cut:]))))
    assert np.allclose(whole, merged, rtol=1e-5), depth
    w = np.asarray(cascade_weights(n, depth), np.float64)       # (depth, n)
    oracle = w @ x.astype(np.float64)
    assert np.allclose(whole, oracle, rtol=1e-4), depth


@pytest.mark.parametrize("coeffs", [(1.0,), (0.0, 1.0), (2.0, -1.0, 0.5)])
def test_cascade_matches_poly(coeffs):
    for seed in (0, 1):
        check_cascade_matches_poly(seed, coeffs)


@pytest.mark.parametrize("depth", (1, 2, 3, 4))
def test_cascade_merge_is_concat(depth):
    check_cascade_merge_is_concat(0, depth)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16),
       coeffs=st.lists(st.sampled_from((-1.0, -0.5, 0.0, 0.5, 1.0, 2.0)),
                       min_size=1, max_size=4).map(tuple))
def test_prop_cascade_matches_poly(seed, coeffs):
    check_cascade_matches_poly(seed, coeffs)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), depth=st.integers(1, 4),
       cut=st.integers(1, 31))
def test_prop_cascade_merge_is_concat(seed, depth, cut):
    check_cascade_merge_is_concat(seed, depth, cut=cut)


def test_fir_weights_match_convolution_oracle():
    rng = np.random.RandomState(4)
    x = rng.randn(64).astype(np.float32)
    taps = (0.5, 0.25, 0.125, 0.0625)
    out = float(R.reduce(jnp.asarray(x), op="weighted_sum",
                         weights=fir_weights(len(x), taps),
                         policy="exact2"))
    oracle = float(np.convolve(x.astype(np.float64), taps, "full")
                   [len(x) - 1])
    assert abs(out - oracle) <= 1e-5 * max(1.0, abs(oracle))


def test_poly_weights_is_horner():
    w = np.asarray(poly_weights(5, (2.0, 3.0, 1.0)))
    i = np.arange(5.0)
    assert np.array_equal(w, (2.0 + 3.0 * i + i ** 2).astype(np.float32))
