"""Full-matrix coverage for the reduction-algebra ops (ISSUE 9).

The algebra's ``pre`` hook runs once, above every backend and policy, so
each new op must inherit the whole determinism contract for free:

  * backend invariance — ref / blocked / pallas produce *bitwise*
    identical results for every op x policy cell (mirroring
    test_reduce.test_segmented_backends_bitwise_equal);
  * block-size invariance — the integer tiers are bitwise across the
    block-size sweep for every op;
  * shard invariance — the integer tiers are bitwise at 1 / 2 / 8
    simulated devices (subprocess, test_shard_backend pattern);
  * the in-model dogfood knobs default to off (bitwise-legacy) and are
    deterministic when on;
  * the front door validates op arguments loudly.
"""

import os
import subprocess
import sys
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import reduce as R

REPO = Path(__file__).resolve().parent.parent
BACKENDS = ("ref", "blocked", "pallas")
POLICIES = ("fast", "compensated", "exact", "exact2", "procrastinate")
INT_POLICIES = ("exact", "exact2", "procrastinate")
NEW_OPS = ("weighted_sum", "sumsq", "moments", "poly")


def _data(n=420, d=6, s=5, seed=0):
    rng = np.random.RandomState(seed)
    vals = jnp.asarray(rng.randn(n, d).astype(np.float32))
    ids = jnp.asarray(rng.randint(-1, s, n))        # sentinel rows included
    w = jnp.asarray(rng.uniform(-2, 2, n).astype(np.float32))
    return vals, ids, w


def _kwargs(op, w):
    if op == "weighted_sum":
        return {"weights": w}
    if op == "poly":
        return {"coeffs": (1.0, 0.5, -0.25)}
    return {}


# ---------------------------------------------------------------------------
# backend x op x policy: bitwise across executors
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize("op", NEW_OPS)
def test_op_backends_bitwise_equal(op, policy):
    vals, ids, w = _data()
    outs = [np.asarray(R.reduce(vals, segment_ids=ids, num_segments=5,
                                op=op, policy=policy, backend=b,
                                block_size=64, **_kwargs(op, w)))
            for b in BACKENDS]
    for o in outs[1:]:
        assert np.array_equal(outs[0], o), (op, policy)
    if op == "moments":
        assert outs[0].shape == (5, 2, 6)


@pytest.mark.parametrize("policy", INT_POLICIES)
@pytest.mark.parametrize("op", NEW_OPS)
def test_op_block_size_sweep_bitwise(op, policy):
    vals, ids, w = _data(seed=3)
    outs = [np.asarray(R.reduce(vals, segment_ids=ids, num_segments=5,
                                op=op, policy=policy, backend="blocked",
                                block_size=bs, **_kwargs(op, w)))
            for bs in (32, 64, 256)]
    for o in outs[1:]:
        assert np.array_equal(outs[0], o), (op, policy)


@pytest.mark.parametrize("op", NEW_OPS)
def test_op_oracle_f64(op):
    """Every cell of the matrix tracks the f64 oracle (exact2 shown;
    the cross-backend tests pin the other tiers to this one)."""
    vals, ids, w = _data(seed=5)
    out = np.asarray(R.reduce(vals, segment_ids=ids, num_segments=5,
                              op=op, policy="exact2", backend="blocked",
                              block_size=64, **_kwargs(op, w)))
    v = np.asarray(vals, np.float64)
    i = np.asarray(ids)
    keep = i >= 0
    if op == "weighted_sum":
        v = v * np.asarray(w, np.float64)[:, None]
    elif op == "sumsq":
        v = v * v
    elif op == "poly":
        c = _kwargs(op, w)["coeffs"]
        t = np.arange(len(v), dtype=np.float64)
        v = v * sum(cc * t ** p for p, cc in enumerate(c))[:, None]
    if op == "moments":
        ref = np.zeros((5, 2, v.shape[1]))
        for seg in range(5):
            rows = v[keep & (i == seg)]
            if len(rows):
                ref[seg, 0] = rows.mean(0)
                ref[seg, 1] = rows.var(0)
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=1e-5)
    else:
        ref = np.zeros((5, v.shape[1]))
        np.add.at(ref, i[keep], v[keep])
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# shard_map: 1 / 2 / 8 simulated devices, bitwise for the integer tiers
# ---------------------------------------------------------------------------

MULTIDEV_SNIPPET = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro import reduce as R

rng = np.random.RandomState(0)
n, d, s, bs = 900, 8, 5, 128              # uneven: 900 % (8*128) != 0
vals = jnp.asarray(rng.randn(n, d).astype(np.float32))
ids = jnp.asarray(rng.randint(-1, s, n))
w = jnp.asarray(rng.uniform(-2, 2, n).astype(np.float32))

def kwargs(op):
    if op == "weighted_sum":
        return {"weights": w}
    if op == "poly":
        return {"coeffs": (1.0, 0.5)}
    return {}

for op in ("weighted_sum", "sumsq", "moments", "poly"):
    for pol in ("fast", "compensated", "exact", "exact2", "procrastinate"):
        base = np.asarray(R.reduce(vals, segment_ids=ids, num_segments=s,
                                   op=op, policy=pol, backend="blocked",
                                   block_size=bs, **kwargs(op)))
        scale = max(float(np.abs(base).max()), 1e-30)
        for ndev in (1, 2, 8):
            mesh = Mesh(np.asarray(jax.devices()[:ndev]), ("shards",))
            out = np.asarray(R.reduce(vals, segment_ids=ids,
                                      num_segments=s, op=op, policy=pol,
                                      backend="shard_map", mesh=mesh,
                                      block_size=bs, **kwargs(op)))
            bit = int(np.array_equal(base, out))
            rel = float(np.abs(base - out).max()) / scale
            print(f"GRID {op} {pol} {ndev} {bit} {rel:.3e}")

# collective companions of the new ops
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
mesh8 = Mesh(np.asarray(jax.devices()), ("data",))
x8 = jnp.asarray(rng.randn(8, 16).astype(np.float32))
w8 = jnp.asarray(rng.uniform(0.1, 2.0, (8, 16)).astype(np.float32))

def wmean(xs, ws):
    return R.collective_weighted_mean(xs, ws, ("data",), policy="exact2")
got = np.asarray(shard_map(wmean, mesh=mesh8,
                           in_specs=(P("data"), P("data")), out_specs=P(),
                           check_rep=False)(x8, w8))[0]
xf = np.asarray(x8, np.float64)
wf = np.asarray(w8, np.float64)
ref = (xf * wf).sum(0) / wf.sum(0)        # per-element, over the device axis
print(f"WMEAN {int(np.allclose(got, ref, rtol=1e-4, atol=1e-5))}")

def moms(xs):
    return R.collective_moments(xs, ("data",), policy="exact2")
m1, var = shard_map(moms, mesh=mesh8, in_specs=P("data"),
                    out_specs=(P(), P()), check_rep=False)(x8)
ok = (np.allclose(np.asarray(m1)[0], xf.mean(0), rtol=1e-4, atol=1e-5)
      and np.allclose(np.asarray(var)[0], xf.var(0), rtol=1e-3, atol=1e-4)
      and (np.asarray(var) >= 0.0).all())
print(f"CMOMS {int(ok)}")
"""


def test_multidevice_op_invariance():
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    r = subprocess.run([sys.executable, "-c", MULTIDEV_SNIPPET],
                       capture_output=True, text=True, env=env, timeout=600)
    assert r.returncode == 0, r.stderr[-3000:]
    lines = [ln.split() for ln in r.stdout.strip().splitlines()]
    grid = {(op, p, int(nd)): (int(bit), float(rel))
            for _, op, p, nd, bit, rel in
            (ln for ln in lines if ln[0] == "GRID")}
    assert len(grid) == len(NEW_OPS) * len(POLICIES) * 3
    for (op, pol, ndev), (bit, rel) in grid.items():
        if pol in INT_POLICIES or ndev == 1:
            assert bit == 1, (op, pol, ndev)    # bitwise at any shard count
        else:
            assert rel < 1e-5, (op, pol, ndev, rel)
    tags = [(ln[0], ln[1]) for ln in lines]
    assert ("WMEAN", "1") in tags
    assert ("CMOMS", "1") in tags


# ---------------------------------------------------------------------------
# dogfood: the in-model call sites and their knobs
# ---------------------------------------------------------------------------


def test_dogfood_knobs_default_off():
    """Stock configs must keep every algebra knob at None, so mainline
    serving/training output is bitwise the pre-algebra path."""
    from repro.configs import all_configs
    for arch, cfg in all_configs().items():
        assert cfg.norm_reduce_policy is None, arch
        if cfg.moe is not None:
            assert cfg.moe.router_norm_policy is None, arch


def test_rmsnorm_knob_off_is_bitwise_legacy():
    from repro.models.layers import rmsnorm
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(2, 5, 32).astype(np.float32))
    g = jnp.asarray(rng.randn(32).astype(np.float32))
    got = np.asarray(rmsnorm(g, x))
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    ref = (xf * jax.lax.rsqrt(var + 1e-5)).astype(x.dtype) * g
    assert np.array_equal(got, np.asarray(ref))


@pytest.mark.parametrize("policy", ("fast", "exact2"))
def test_rmsnorm_knob_on_close_and_deterministic(policy):
    from repro.models.layers import rmsnorm
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(3, 7, 64).astype(np.float32))
    g = jnp.asarray(rng.randn(64).astype(np.float32))
    a = np.asarray(rmsnorm(g, x, policy=policy))
    b = np.asarray(rmsnorm(g, x, policy=policy))
    assert np.array_equal(a, b)
    jitted = np.asarray(jax.jit(
        lambda gg, xx: rmsnorm(gg, xx, policy=policy))(g, x))
    assert np.array_equal(a, jitted)
    np.testing.assert_allclose(a, np.asarray(rmsnorm(g, x)),
                               rtol=1e-4, atol=1e-5)


def test_global_norm_policy_matches_legacy():
    from repro.optim import adamw
    rng = np.random.RandomState(2)
    tree = {"a": jnp.asarray(rng.randn(37, 5).astype(np.float32)),
            "b": [jnp.asarray(rng.randn(2049).astype(np.float32)),
                  jnp.asarray(rng.randn(3).astype(np.float32)
                              ).astype(jnp.bfloat16)]}
    legacy = float(adamw.global_norm(tree))
    for pol in ("fast", "exact2"):
        got = float(adamw.global_norm(tree, policy=pol))
        assert got == pytest.approx(legacy, rel=1e-5), pol
        jitted = float(jax.jit(
            lambda t: adamw.global_norm(t, policy=pol))(tree))
        assert jitted == pytest.approx(got, rel=0, abs=0)


def test_router_norm_policy_matches_legacy():
    from repro.models.config import MoECfg
    from repro.models.moe import router_topk
    import dataclasses
    rng = np.random.RandomState(3)
    m = MoECfg(num_experts=8, top_k=2, d_ff_expert=16,
               router_norm_topk=True)
    router_w = jnp.asarray(rng.randn(32, 8).astype(np.float32))
    x = jnp.asarray(rng.randn(24, 32).astype(np.float32))
    w0, i0, a0 = router_topk(router_w, x, m)
    mp = dataclasses.replace(m, router_norm_policy="exact2")
    w1, i1, a1 = router_topk(router_w, x, mp)
    assert np.array_equal(np.asarray(i0), np.asarray(i1))
    assert float(a0) == float(a1)
    np.testing.assert_allclose(np.asarray(w0), np.asarray(w1),
                               rtol=1e-5, atol=1e-7)
    row_sums = np.asarray(w1).sum(-1)
    np.testing.assert_allclose(row_sums, 1.0, rtol=1e-4)


def test_model_forward_with_knobs_on_deterministic_and_close():
    from repro.configs import get_smoke_config
    from repro.models import forward, init_params
    import dataclasses
    cfg = get_smoke_config("deepseek-7b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0,
                                cfg.vocab)
    base, _, _ = forward(params, cfg, tokens=tokens, mode="train")
    cfg_on = dataclasses.replace(cfg, norm_reduce_policy="exact2")
    on1, _, _ = forward(params, cfg_on, tokens=tokens, mode="train")
    on2, _, _ = forward(params, cfg_on, tokens=tokens, mode="train")
    assert np.array_equal(np.asarray(on1, np.float32),
                          np.asarray(on2, np.float32))
    np.testing.assert_allclose(np.asarray(on1, np.float32),
                               np.asarray(base, np.float32),
                               rtol=5e-2, atol=5e-2)


def test_train_step_norm_policy_runs():
    from repro.configs import get_smoke_config
    from repro.models import init_params
    from repro.optim import adamw
    from repro.train.steps import make_train_step
    cfg = get_smoke_config("stablelm-1.6b")
    params = init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.init(params)
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                          0, cfg.vocab)}
    kw = dict(lr_fn=adamw.cosine_schedule(1e-3, 2, 20), remat=False,
              moe_impl="dense")
    p0, _, m0 = jax.jit(make_train_step(cfg, **kw))(params, opt, batch)
    p1, _, m1 = jax.jit(make_train_step(cfg, norm_policy="exact2",
                                        **kw))(params, opt, batch)
    assert float(m1["grad_norm"]) == pytest.approx(float(m0["grad_norm"]),
                                                   rel=1e-5)
    num = sum(float(jnp.sum((jnp.asarray(a, jnp.float32)
                             - jnp.asarray(b, jnp.float32)) ** 2))
              for a, b in zip(jax.tree.leaves(p0), jax.tree.leaves(p1)))
    den = sum(float(jnp.sum(jnp.asarray(a, jnp.float32) ** 2))
              for a in jax.tree.leaves(p0))
    assert num / max(den, 1e-30) < 1e-8


# ---------------------------------------------------------------------------
# front-door validation
# ---------------------------------------------------------------------------


def test_unknown_op_rejected_with_registry_listing():
    with pytest.raises(ValueError, match="weighted_sum"):
        R.reduce(jnp.ones(4), op="median")


def test_weighted_sum_requires_weights():
    with pytest.raises(ValueError, match="weights"):
        R.reduce(jnp.ones(4), op="weighted_sum")


def test_poly_requires_coeffs():
    with pytest.raises(ValueError, match="coeffs"):
        R.reduce(jnp.ones(4), op="poly")


def test_weights_on_weightless_op_rejected():
    with pytest.raises(ValueError, match="weights"):
        R.reduce(jnp.ones(4), op="sum", weights=jnp.ones(4))


def test_coeffs_on_coeffless_op_rejected():
    with pytest.raises(ValueError, match="coeffs"):
        R.reduce(jnp.ones(4), op="sum", coeffs=(1.0, 2.0))


def test_weights_shape_validated():
    with pytest.raises(ValueError, match="weights"):
        R.reduce(jnp.ones((4, 2)), op="weighted_sum", weights=jnp.ones(3))
    with pytest.raises(ValueError, match="weights"):
        R.reduce(jnp.ones((4, 2)), op="weighted_sum",
                 weights=jnp.ones((4, 2)))
