"""Paper-claim validation: the cycle-accurate JugglePAC / INTAC simulators.

These tests pin the faithful-reproduction layer to the paper's own claims:
Table I (schedule), Table II (min set size vs PIS registers), §III-A
(in-order results, single adder, 4-slot FIFO, L+3 timeout), §III-B (INTAC
exactness, resource-shared final adder), Eq. 1 (INTAC latency).
"""

import random

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.circuit import (INTAC, JugglePAC, PipelinedAdder,
                                jugglepac_min_set_size)
from repro.core import circuit_jax


def test_pipelined_adder_latency():
    add = PipelinedAdder(5)
    outs = []
    for cyc in range(12):
        issue = (1.0, 2.0, 7) if cyc == 0 else None
        outs.append(add.tick(issue))
    # result appears exactly L cycles after issue
    assert outs[:5] == [None] * 5
    assert outs[5] == (3.0, 7)
    assert all(o is None for o in outs[6:])


def test_table1_schedule_shape():
    """The Fig.2/Table I discipline at L=2: raw pairs are issued on the
    cycle the 2nd element arrives; odd leftovers pair with 0 on the next
    start; FIFO pairs fill free slots; results are correct and in order."""
    pac = JugglePAC(adder_latency=2, num_registers=4)
    sets = [[1, 2, 3, 4, 5], [10, 20, 30, 40],
            [100, 200, 300, 400, 500, 600, 700, 800, 900]]
    res = pac.run(sets)
    assert [r.set_index for r in res] == [0, 1, 2]          # input order
    for r, s in zip(res, sets):
        assert r.value == sum(s)
    # a4 paired with zero exactly when b starts (cycle 5)
    zero_pairs = [(c, a, b) for c, a, b, l in pac.adder_issue_log if b == 0.0]
    assert zero_pairs and zero_pairs[0][0] == 5 and zero_pairs[0][1] == 5
    # single adder: at most one issue per cycle
    cycles = [c for c, *_ in pac.adder_issue_log]
    assert len(cycles) == len(set(cycles))
    assert pac.fifo_overflows == 0


def test_throughput_back_to_back():
    """Full throughput: back-to-back sets with no stalls (the paper's core
    claim vs [3], [4]) — inputs are consumed every cycle, results emitted."""
    sizes = [40, 33, 50, 29, 64, 41]
    sets = [[float(i * 100 + j) for j in range(n)]
            for i, n in enumerate(sizes)]
    pac = JugglePAC(adder_latency=14, num_registers=4)
    res = pac.run(sets)
    assert len(res) == len(sets)
    assert [r.set_index for r in res] == list(range(len(sets)))
    for r, s in zip(res, sets):
        assert abs(r.value - sum(s)) < 1e-6 * max(1.0, abs(sum(s)))


def test_latency_bound_table2():
    """Latency <= DS + c with a small constant at L=14 (Table II reports
    c <= 113; our scheduler's measured c is checked to be <= 113 too)."""
    worst_c = 0
    for n in (30, 64, 128, 200):
        sets = [[1.0] * n for _ in range(6)]
        pac = JugglePAC(adder_latency=14, num_registers=4)
        res = pac.run(sets)
        for r in res:
            worst_c = max(worst_c, r.latency - n)
    assert worst_c <= 113, worst_c


@pytest.mark.parametrize("regs,paper_min", [(2, 94), (4, 29), (8, 18)])
def test_min_set_size_table2(regs, paper_min):
    """Table II trend: min set size falls steeply with PIS registers.
    Our scheduler is a mild idealization (no routing-delay cycles), so we
    assert ours <= paper's number and within the same regime (> 1/4 of it),
    and record both in EXPERIMENTS.md §Paper-validation."""
    m = jugglepac_min_set_size(14, regs)
    assert m <= paper_min
    assert m >= max(2, paper_min // 4)


def test_below_min_set_size_fails():
    """The design restriction (§IV-A): sets far below the minimum mix data
    between sets — the failure mode the paper documents."""
    pac = JugglePAC(adder_latency=14, num_registers=2)
    sets = [[1.0] * 5 for _ in range(20)]          # 5 << 94
    res = pac.run(sets)
    ok = (len(res) == len(sets)
          and all(abs(r.value - 5.0) < 1e-9 for r in res)
          and [r.set_index for r in res] == list(range(20)))
    assert not ok


@settings(max_examples=20, deadline=None)
@given(st.lists(st.integers(min_value=30, max_value=70), min_size=2,
                max_size=5),
       st.integers(min_value=2, max_value=20))
def test_jax_scan_matches_python_sim(sizes, latency):
    rng = random.Random(7)
    sets = [[float(rng.randrange(1, 50)) for _ in range(n)] for n in sizes]
    pac = JugglePAC(latency, 4)
    py = [(r.set_index, r.value, r.cycle) for r in pac.run(sets)]
    jx, ovf = circuit_jax.run_sets(sets, latency=latency, num_registers=4)
    assert not ovf
    assert len(py) == len(jx)
    for (si, v, c), (si2, v2, c2) in zip(py, jx):
        assert si == si2 and c == c2 and abs(v - v2) < 1e-3


def test_reduction_operator_generality():
    """§III-A: 'any multi-cycle operator' — run with multiplication."""
    pac = JugglePAC(adder_latency=6, num_registers=4,
                    op=lambda a, b: a * b, zero=1.0)
    sets = [[1.5, 2.0, 3.0] + [1.0] * 40, [2.0] * 35]
    res = pac.run(sets)
    assert abs(res[0].value - 9.0) < 1e-6
    assert abs(res[1].value - 2.0 ** 35) < 1e-3 * 2.0 ** 35


# ---------------------------------------------------------------------------
# INTAC
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=2 ** 64 - 1),
                min_size=1, max_size=200),
       st.sampled_from([1, 2, 4, 16]),
       st.sampled_from([1, 2]))
def test_intac_exact(values, fa_cells, inputs_per_cycle):
    it = INTAC(64, 128, inputs_per_cycle, fa_cells)
    res = it.accumulate(values)
    assert res.value == sum(values) % (1 << 128)


def test_intac_latency_eq1():
    """Eq. 1: Latency = ceil(I/N) + ceil((M-R)/FAs) + 1."""
    for n_in, fas, count in [(1, 1, 64), (1, 16, 100), (2, 2, 64)]:
        it = INTAC(64, 128, n_in, fas)
        res = it.accumulate(list(range(count)))
        assert res.cycle == INTAC.latency_eq1(count, n_in, 128, fas)


def test_intac_min_set_size_rule():
    """§IV-C: min set = ceil(M*inputs/FAs)."""
    assert INTAC(64, 128, 1, 1).min_set_size() == 128
    assert INTAC(64, 128, 2, 16).min_set_size() == 16


def test_intac_table5_latency_trend():
    """Table V: more FA cells => lower latency (N+128 / N+64 / N+8)."""
    lat = {fas: INTAC.latency_eq1(1000, 1, 128, fas) - 1000
           for fas in (1, 2, 16)}
    assert lat[1] > lat[2] > lat[16]
    assert lat[1] == 129 and lat[2] == 65 and lat[16] == 9
