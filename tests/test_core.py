"""Property tests for the production core: trees, segmented reduction,
INTAC fixed point, gradient juggler."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import intac, juggler, segmented, trees


# ---------------------------------------------------------------------------
# pairing trees
# ---------------------------------------------------------------------------


@settings(max_examples=30, deadline=None)
@given(st.integers(min_value=1, max_value=100))
def test_tree_sum_matches(n):
    x = jnp.asarray(np.random.RandomState(n).randn(n, 3).astype(np.float32))
    assert np.allclose(trees.pairwise_tree_sum(x, 0), np.asarray(x).sum(0),
                       atol=1e-4)


def test_tree_depth():
    assert trees.tree_depth(1) == 0
    assert trees.tree_depth(2) == 1
    assert trees.tree_depth(6) == 3
    assert trees.tree_depth(1024) == 10


def test_tree_error_growth_vs_serial():
    """The paper's numerical motivation: pairwise-tree error << serial
    error on large ill-conditioned sums (fp32)."""
    rng = np.random.RandomState(0)
    x = (rng.randn(1 << 16) * 10 ** rng.uniform(-4, 4, 1 << 16)) \
        .astype(np.float32)
    exact = np.sum(x.astype(np.float64))
    serial = np.float32(0.0)
    for v in x:
        serial += v
    tree = float(trees.pairwise_tree_sum(jnp.asarray(x), 0))
    err_serial = abs(float(serial) - exact)
    err_tree = abs(tree - exact)
    assert err_tree <= err_serial * 1.01


def test_tree_combine_nonpow2_order():
    """Fixed schedule: result independent of padding tricks, equals ref."""
    x = jnp.arange(11, dtype=jnp.float32)
    assert float(trees.pairwise_tree_sum(x, 0)) == 55.0


# ---------------------------------------------------------------------------
# segmented reduction (variable-length sets)
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(min_value=1, max_value=60), min_size=1,
                max_size=12),
       st.integers(min_value=1, max_value=8),
       st.sampled_from([64, 128, 257]))
def test_blocked_segment_sum(lengths, d, block):
    from repro import reduce as R
    total = sum(lengths)
    ids = segmented.segments_from_lengths(jnp.asarray(lengths), total)
    vals = jnp.asarray(
        np.random.RandomState(total).randn(total, d).astype(np.float32))
    ref = segmented.segment_sum_ref(vals, ids, len(lengths))
    out = R.reduce(vals, segment_ids=ids, num_segments=len(lengths),
                   backend="blocked", block_size=block)
    assert np.allclose(out, ref, atol=1e-4)


def test_segments_from_lengths():
    ids = segmented.segments_from_lengths(jnp.asarray([3, 1, 2]), 6)
    assert list(np.asarray(ids)) == [0, 0, 0, 1, 2, 2]


def test_segment_mean():
    vals = jnp.asarray([[1.0], [3.0], [10.0]])
    ids = jnp.asarray([0, 0, 1])
    out = segmented.segment_mean(vals, ids, 2)
    assert np.allclose(out[:, 0], [2.0, 10.0])


def test_flash_partial_combine_tree():
    """Combining flash partials with the fixed tree == full softmax."""
    rng = np.random.RandomState(1)
    nshards, g, d, s = 8, 4, 16, 32
    q = rng.randn(g, d).astype(np.float32)
    k = rng.randn(nshards, s, d).astype(np.float32)
    v = rng.randn(nshards, s, d).astype(np.float32)
    ms, ls, os_ = [], [], []
    for i in range(nshards):
        sc = q @ k[i].T
        m = sc.max(-1)
        p = np.exp(sc - m[:, None])
        ms.append(m)
        ls.append(p.sum(-1))
        os_.append(p @ v[i])
    m, l, o = segmented.combine_flash_partials_tree(
        jnp.asarray(np.stack(ms)), jnp.asarray(np.stack(ls)),
        jnp.asarray(np.stack(os_)), axis=0)
    out = np.asarray(o) / np.asarray(l)[:, None]
    # reference: softmax over the concatenated kv
    kk = k.reshape(-1, d)
    vv = v.reshape(-1, d)
    sc = q @ kk.T
    p = np.exp(sc - sc.max(-1, keepdims=True))
    ref = (p / p.sum(-1, keepdims=True)) @ vv
    assert np.allclose(out, ref, atol=1e-4)


# ---------------------------------------------------------------------------
# INTAC fixed point
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(st.integers(min_value=1, max_value=400))
def test_intac_sum_order_independent(n):
    x = jnp.asarray(np.random.RandomState(n).randn(n).astype(np.float32))
    a = float(intac.intac_sum(x))
    b = float(intac.intac_sum(x[::-1]))
    assert a == b            # bitwise identical under reordering


def test_intac_sum_accuracy():
    x = jnp.asarray(np.random.RandomState(0).randn(1000).astype(np.float32))
    exact = float(np.sum(np.asarray(x, np.float64)))
    assert abs(float(intac.intac_sum(x)) - exact) < 1e-3


def test_choose_scale_no_overflow():
    for n, amax in [(10, 1.0), (65536, 100.0), (3, 1e-8)]:
        scale = float(intac.choose_scale(jnp.float32(amax), n))
        assert n * amax * scale < 2 ** 31
        # power of two
        assert float(np.log2(scale)) == int(np.log2(scale))


def test_limb_split_boundaries():
    """The limb split is pure integer shift/mask, so it reconstructs
    exactly at and beyond the f32 24-bit mantissa boundary (a float-domain
    split rounds there).  2^24 + 1 itself is not an f32, so the nearest
    representable neighbours bracket the boundary."""
    scale = jnp.float32(2.0 ** 16)
    for q in (2 ** 24 - 1, 2 ** 24, 2 ** 24 + 2, 2 ** 30,
              -(2 ** 24 - 1), -(2 ** 24 + 2), -(2 ** 30)):
        x = jnp.float32(q * 2.0 ** -16)          # quantizes to exactly q
        st_ = intac.limb_add(intac.limb_init((), scale), x)
        hi, lo = int(st_.hi), int(st_.lo)
        assert 0 <= lo < (1 << intac.LIMB_SHIFT)       # canonical split
        assert hi * (1 << intac.LIMB_SHIFT) + lo == q  # exact identity
        assert float(intac.limb_finalize(st_)) == float(x)


def test_limb_resolve_is_decomposition_independent():
    """limbs_resolve canonicalizes in the integer domain, so any (hi, lo)
    pair representing the same total resolves to the same bits."""
    scale = jnp.float32(1.0)
    a = intac.limbs_resolve(jnp.int32(1000), jnp.int32(2 ** 26 + 123), scale)
    hi2 = 1000 + ((2 ** 26 + 123) >> intac.LIMB_SHIFT)
    lo2 = (2 ** 26 + 123) & ((1 << intac.LIMB_SHIFT) - 1)
    b = intac.limbs_resolve(jnp.int32(hi2), jnp.int32(lo2), scale)
    assert float(a) == float(b)


def test_limb_split3_is_lossless():
    """The three-limb split loses nothing: x == (hi*2^15 + lo)/scale + r
    holds *exactly* in f64 (the residual capture is exact Dekker/Sterbenz
    arithmetic), even for values far off the scale's dyadic grid."""
    scale = np.float32(2.0 ** 12)
    for v in (1 / 3, -2.7182818, 1.0000001, 123.4567, 1e-6, -1e-9, 0.0):
        x = np.float32(v)
        hi, lo, r = intac.limb_split3(jnp.float32(x), scale)
        q = int(hi) * (1 << intac.LIMB_SHIFT) + int(lo)
        assert np.float64(q) / np.float64(scale) + np.float64(np.float32(r)) \
            == np.float64(x)


def test_limbs_resolve3_decomposition_independent_and_1ulp():
    """The integer canonicalization makes resolve3 independent of the
    (hi, lo) decomposition, and the compensated combine lands within 1
    ulp of the f64 reference even when hi exceeds the f32 mantissa."""
    scale = jnp.float32(1.0)
    res = jnp.float32(0.37)
    a = intac.limbs_resolve3(jnp.int32(1000), jnp.int32(2 ** 26 + 123),
                             res, scale)
    hi2, lo2 = (np.int32(v) for v in
                intac.limbs_canonical(jnp.int32(1000),
                                      jnp.int32(2 ** 26 + 123)))
    b = intac.limbs_resolve3(jnp.asarray(hi2), jnp.asarray(lo2), res, scale)
    assert float(a) == float(b)
    # hi*2^15 needs >24 bits: the split-and-two_sum combine must not lose
    # the low-order quanta the naive f32 conversion rounds away
    hi, lo = jnp.int32(1 << 26), jnp.int32(3)
    ref = np.float64((1 << 26) * (1 << 15) + 3) + np.float64(0.37)
    got = float(intac.limbs_resolve3(hi, lo, res, scale))
    assert abs(got - float(ref)) <= np.spacing(np.float32(ref),
                                               dtype=np.float32)


def test_limb3_accumulate_off_grid_within_1ulp():
    """Off-grid stream (1/3-ish values): the three-limb path tracks the
    f64 oracle to 1 ulp where the two-limb path visibly rounds, and the
    split/merge law holds with bitwise-equal canonical integer limbs."""
    rng = np.random.RandomState(29)
    xs = (rng.randn(256, 4) / 3 + np.float32(1 / 3)).astype(np.float32)
    scale = 2.0 ** 16
    st = intac.limb3_init((4,), scale)
    for r in xs:
        st = intac.limb_add3(st, jnp.asarray(r))
    ref = np.sum(xs.astype(np.float64), axis=0)
    out3 = np.asarray(intac.limb3_finalize(st))
    assert (np.abs(out3 - ref)
            <= np.spacing(np.abs(ref.astype(np.float32)))).all()
    st2 = intac.limb_init((4,), scale)
    for r in xs:
        st2 = intac.limb_add(st2, jnp.asarray(r))
    out2 = np.asarray(intac.limb_finalize(st2))
    assert (np.abs(out2 - ref)
            > np.spacing(np.abs(ref.astype(np.float32)))).any()
    # split/merge law
    a = intac.limb3_init((4,), scale)
    b = intac.limb3_init((4,), scale)
    for r in xs[:128]:
        a = intac.limb_add3(a, jnp.asarray(r))
    for r in xs[128:]:
        b = intac.limb_add3(b, jnp.asarray(r))
    m = intac.limb_merge3(a, b)
    for u, v in zip(intac.limbs_canonical(m.hi, m.lo),
                    intac.limbs_canonical(st.hi, st.lo)):
        assert np.array_equal(np.asarray(u), np.asarray(v))
    assert (np.abs(np.asarray(intac.limb3_finalize(m)) - ref)
            <= np.spacing(np.abs(ref.astype(np.float32)))).all()


def test_wrap_add_trips_exactly_at_the_int32_edge():
    """The wrap predicate is exact: carries within +/-1 of the int32
    boundary flag iff the two's-complement sum actually wrapped."""
    mx, mn = np.int32(2**31 - 1), np.int32(-(2**31))
    cases = [(mx - 1, 1, False), (mx, 0, False), (mx, 1, True),
             (mn + 1, -1, False), (mn, 0, False), (mn, -1, True),
             (mx, mn, False), (0, 0, False)]
    for a, b, wraps in cases:
        s, w = intac.wrap_add(jnp.int32(a), jnp.int32(b))
        assert bool(w) == wraps, (a, b)
        if not wraps:
            assert int(s) == int(a) + int(b)


def test_limb_add3_saturation_boundary():
    """ovf trips exactly when a limb add wraps — a carry landing *at*
    2^31 - 1 is still canonical and raises no flag."""
    mx = np.int32(2**31 - 1)
    z = jnp.zeros((), jnp.float32)
    scale = jnp.float32(1.0)
    x = jnp.float32(2.0**15)        # quantizes to hi=1, lo=0

    def state(hi):
        return intac.Limb3State(jnp.int32(hi), jnp.int32(0), z, z, scale,
                                jnp.int32(0))

    at_edge = intac.limb_add3(state(mx - 1), x)
    assert int(at_edge.hi) == int(mx) and int(at_edge.ovf) == 0
    past = intac.limb_add3(state(mx), x)
    assert int(past.ovf) == 1       # canonical total is now wrong
    # a further non-wrapping add keeps (not resets) the count
    again = intac.limb_add3(past, jnp.float32(1.0))
    assert int(again.ovf) == 1
    # None ovf (5-field pre-guard-rail construction) stays disabled
    legacy = intac.Limb3State(jnp.int32(mx), jnp.int32(0), z, z, scale)
    assert intac.limb_add3(legacy, x).ovf is None


def test_limb_merge3_saturation_boundary():
    """Merging pools both sides' wrap counts plus any wrap the merge
    itself causes, and trips only when the canonical sum would wrap."""
    mx = np.int32(2**31 - 1)
    z = jnp.zeros((), jnp.float32)
    scale = jnp.float32(1.0)

    def state(hi, lo=0, ovf=0):
        o = None if ovf is None else jnp.int32(ovf)
        return intac.Limb3State(jnp.int32(hi), jnp.int32(lo), z, z, scale, o)

    ok = intac.limb_merge3(state(mx - 1), state(1))
    assert int(ok.hi) == int(mx) and int(ok.ovf) == 0
    bad = intac.limb_merge3(state(mx), state(1))
    assert int(bad.ovf) == 1
    # both limbs wrap in one merge, on top of prior pooled counts
    both = intac.limb_merge3(state(mx, mx, ovf=2), state(1, 1, ovf=3))
    assert int(both.ovf) == 2 + 3 + 2
    # None on both sides disables tracking; one-sided None counts as zero
    assert intac.limb_merge3(state(1, ovf=None), state(2, ovf=None)).ovf \
        is None
    assert int(intac.limb_merge3(state(mx, ovf=None), state(1, ovf=4)).ovf) \
        == 5


def test_choose_scale_zero_and_nan_streams_are_benign():
    """max_abs == 0 (all-zero or all-padding stream) pins the unit scale
    instead of the degenerate near-2^127 clamp; a NaN statistic must not
    poison the scale either."""
    assert float(intac.choose_scale(jnp.float32(0.0), 1024)) == 1.0
    assert float(intac.choose_scale(jnp.float32(0.0), 1)) == 1.0
    s = float(intac.choose_scale(jnp.float32(np.nan), 16))
    assert np.isfinite(s) and s == 1.0
    # tiny-but-nonzero streams keep the clamped-scale behavior
    assert float(intac.choose_scale(jnp.float32(2e-38), 2)) == 2.0 ** 127


def test_bin_split_combine_exact_roundtrip():
    """Exponent-bin digits reconstruct arbitrary f32 exactly within the
    48-bit window, and the bin sums are bitwise permutation-invariant."""
    rng = np.random.RandomState(9)
    x = jnp.asarray((rng.randn(2000) * 10 ** rng.uniform(-4, 4, 2000))
                    .astype(np.float32))
    e_ref = intac.bin_ref_exponent(jnp.max(jnp.abs(x)))
    rec = intac.bin_combine(intac.bin_split(x, e_ref), e_ref)
    # per-element roundtrip is exact for values within 2^24 of the max
    big = np.abs(np.asarray(x)) >= float(jnp.max(jnp.abs(x))) * 2.0 ** -24
    assert np.array_equal(np.asarray(rec)[big], np.asarray(x)[big])
    perm = rng.permutation(2000)
    a = intac.bin_combine(jnp.sum(intac.bin_split(x, e_ref), axis=1), e_ref)
    b = intac.bin_combine(jnp.sum(intac.bin_split(x[perm], e_ref), axis=1),
                          e_ref)
    assert float(a) == float(b)


def test_limb_accumulator_exact_merge():
    rng = np.random.RandomState(3)
    xs = rng.randn(200, 8).astype(np.float32)
    scale = 2.0 ** 16
    st_a = intac.limb_init((8,), scale)
    for r in xs[:100]:
        st_a = intac.limb_add(st_a, jnp.asarray(r))
    st_b = intac.limb_init((8,), scale)
    for r in xs[100:]:
        st_b = intac.limb_add(st_b, jnp.asarray(r))
    merged = intac.limb_finalize(intac.limb_merge(st_a, st_b))
    direct = intac.limb_init((8,), scale)
    for r in xs:
        direct = intac.limb_add(direct, jnp.asarray(r))
    assert np.array_equal(np.asarray(merged),
                          np.asarray(intac.limb_finalize(direct)))


# ---------------------------------------------------------------------------
# gradient juggler (binary-counter pairing tree)
# ---------------------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=33))
def test_juggler_matches_sum(n):
    gs = [jnp.asarray(np.random.RandomState(i).randn(4).astype(np.float32))
          for i in range(n)]
    stt = juggler.juggler_init(gs[0], juggler.num_slots_for(n))
    for g in gs:
        stt = juggler.juggler_push(stt, g)
    tot = juggler.juggler_finalize(stt)
    assert np.allclose(tot, sum(np.asarray(g) for g in gs), atol=1e-4)
    assert int(stt.count) == n


def test_juggler_slot_bound():
    """Live-slot occupancy never exceeds ceil(log2 n)+1 — the PIS register
    bound translated to memory."""
    k = juggler.num_slots_for(19)
    stt = juggler.juggler_init(jnp.zeros((2,)), k)
    max_occ = 0
    for i in range(19):
        stt = juggler.juggler_push(stt, jnp.ones((2,)))
        max_occ = max(max_occ, int(jnp.sum(stt.occupancy)))
    assert max_occ <= k
    assert float(juggler.juggler_finalize(stt)[0]) == 19.0


def test_accumulate_microbatch_grads():
    from repro import reduce as R
    def grad_fn(p, mb):
        return jax.tree.map(lambda x: mb["x"].sum() * jnp.ones_like(x), p), \
            jnp.float32(0.0)
    params = {"w": jnp.zeros((3,))}
    mbs = {"x": jnp.arange(8, dtype=jnp.float32).reshape(4, 2)}
    g, _ = R.accumulate_microbatch_grads(
        grad_fn, params, mbs, num_microbatches=4, mean=True)
    assert np.allclose(g["w"], np.full(3, 28.0 / 4))
