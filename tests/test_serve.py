"""Serving engine tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serve.engine import Engine, Request

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def engine():
    cfg = get_smoke_config("stablelm-1.6b")
    params = init_params(KEY, cfg)
    return Engine(cfg, params, max_len=96, seed=0)


def test_generate_batched(engine):
    reqs = [Request(prompt=[5, 6, 7], max_new_tokens=6),
            Request(prompt=[9, 10, 11, 12, 13], max_new_tokens=4),
            Request(prompt=[2], max_new_tokens=8)]
    res = engine.generate(reqs)
    assert len(res) == 3
    for r, q in zip(res, reqs):
        assert r.tokens[:r.prompt_len] == list(q.prompt)
        assert 1 <= len(r.tokens) - r.prompt_len <= q.max_new_tokens
        assert all(0 <= t < engine.cfg.vocab for t in r.tokens)


def test_greedy_deterministic(engine):
    reqs = [Request(prompt=[3, 4, 5, 6], max_new_tokens=5, temperature=0.0)]
    a = engine.generate(reqs)[0].tokens
    b = engine.generate(reqs)[0].tokens
    assert a == b


def test_greedy_matches_single_vs_batch(engine):
    """Continuous batching invariant: a greedy request decodes the same
    tokens whether alone or batched with others."""
    target = Request(prompt=[11, 12, 13, 14, 15, 16], max_new_tokens=5,
                     temperature=0.0)
    alone = engine.generate([target])[0].tokens
    other = Request(prompt=[7, 8], max_new_tokens=5, temperature=0.0)
    batched = engine.generate([target, other])[0].tokens
    assert alone == batched


def test_mean_logprob_batched_matches_alone(engine):
    """mean_logprob is a per-request segmented mean over variable-length
    generations: for a greedy request it must not depend on batchmates
    with different lengths (done steps carry the sentinel)."""
    target = Request(prompt=[21, 22, 23], max_new_tokens=3, temperature=0.0)
    other = Request(prompt=[4], max_new_tokens=7, temperature=0.0)
    alone = engine.generate([target])[0]
    batched = engine.generate([target, other])[0]
    assert alone.mean_logprob is not None
    assert np.isfinite(alone.mean_logprob)
    assert np.isclose(alone.mean_logprob, batched.mean_logprob, atol=1e-5)


def test_max_new_tokens_one_yields_one_token(engine):
    res = engine.generate([Request(prompt=[5, 6, 7], max_new_tokens=1),
                           Request(prompt=[9], max_new_tokens=6)])
    assert len(res[0].tokens) - res[0].prompt_len == 1
    assert len(res[1].tokens) - res[1].prompt_len == 6


def test_generate_rejects_empty_batch(engine):
    with pytest.raises(ValueError, match="at least one request"):
        engine.generate([])


def test_generate_rejects_empty_prompt(engine):
    reqs = [Request(prompt=[5, 6], max_new_tokens=2),
            Request(prompt=[], max_new_tokens=2)]
    with pytest.raises(ValueError, match="request 1 has an empty prompt"):
        engine.generate(reqs)


def test_generate_rejects_over_long_prompt(engine):
    """A prompt that cannot fit max_len (plus one generated token) fails
    fast with the offending index and sizes — not a shape error deep in
    prefill."""
    long = list(range(2, 2 + engine.max_len))      # max_len > limit
    with pytest.raises(ValueError) as exc:
        engine.generate([Request(prompt=[5], max_new_tokens=1),
                         Request(prompt=long, max_new_tokens=1)])
    msg = str(exc.value)
    assert "request 1" in msg
    assert f"{len(long)} tokens" in msg
    assert f"max_len={engine.max_len}" in msg
    assert "truncate_prompts=True" in msg


def test_generate_truncate_prompts_keeps_tail(engine):
    """truncate_prompts=True keeps the last max_len - 1 tokens and
    decodes normally; prompt_len reports the truncated length."""
    limit = engine.max_len - 1
    long = [(3 + i) % engine.cfg.vocab for i in range(engine.max_len + 5)]
    res = engine.generate([Request(prompt=long, max_new_tokens=1)],
                          truncate_prompts=True)[0]
    assert res.prompt_len == limit
    assert res.tokens[:limit] == long[-limit:]
    # exactly-at-limit prompts pass untouched either way
    ok = [5] * limit
    for flag in (False, True):
        r = engine.generate([Request(prompt=ok, max_new_tokens=1)],
                            truncate_prompts=flag)[0]
        assert r.tokens[:limit] == ok


def test_eos_stops(engine):
    # find whatever greedy emits first, then use it as eos
    probe = engine.generate([Request(prompt=[5, 5, 5], max_new_tokens=1,
                                     temperature=0.0)])[0]
    eos = probe.tokens[-1]
    res = engine.generate([Request(prompt=[5, 5, 5], max_new_tokens=10,
                                   temperature=0.0, eos_id=eos)])[0]
    assert len(res.tokens) - res.prompt_len <= 10
    assert eos in res.tokens[res.prompt_len:]
