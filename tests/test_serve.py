"""Serving engine tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models import init_params
from repro.serve.engine import Engine, Request

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("stablelm-1.6b")
    params = init_params(KEY, cfg)
    return cfg, params


@pytest.fixture(scope="module")
def engine(setup):
    cfg, params = setup
    return Engine(cfg, params, max_len=96, seed=0)


def test_generate_batched(engine):
    reqs = [Request(prompt=[5, 6, 7], max_new_tokens=6),
            Request(prompt=[9, 10, 11, 12, 13], max_new_tokens=4),
            Request(prompt=[2], max_new_tokens=8)]
    res = engine.generate(reqs)
    assert len(res) == 3
    for r, q in zip(res, reqs):
        assert r.tokens[:r.prompt_len] == list(q.prompt)
        assert 1 <= len(r.tokens) - r.prompt_len <= q.max_new_tokens
        assert all(0 <= t < engine.cfg.vocab for t in r.tokens)


def test_greedy_deterministic(engine):
    reqs = [Request(prompt=[3, 4, 5, 6], max_new_tokens=5, temperature=0.0)]
    a = engine.generate(reqs)[0].tokens
    b = engine.generate(reqs)[0].tokens
    assert a == b


def test_greedy_matches_single_vs_batch(engine):
    """Continuous batching invariant: a greedy request decodes the same
    tokens whether alone or batched with others."""
    target = Request(prompt=[11, 12, 13, 14, 15, 16], max_new_tokens=5,
                     temperature=0.0)
    alone = engine.generate([target])[0].tokens
    other = Request(prompt=[7, 8], max_new_tokens=5, temperature=0.0)
    batched = engine.generate([target, other])[0].tokens
    assert alone == batched


def test_mean_logprob_batched_matches_alone(engine):
    """mean_logprob is a per-request segmented mean over variable-length
    generations: for a greedy request it must not depend on batchmates
    with different lengths (done steps carry the sentinel)."""
    target = Request(prompt=[21, 22, 23], max_new_tokens=3, temperature=0.0)
    other = Request(prompt=[4], max_new_tokens=7, temperature=0.0)
    alone = engine.generate([target])[0]
    batched = engine.generate([target, other])[0]
    assert alone.mean_logprob is not None
    assert np.isfinite(alone.mean_logprob)
    assert np.isclose(alone.mean_logprob, batched.mean_logprob, atol=1e-5)


def test_max_new_tokens_one_yields_one_token(engine):
    res = engine.generate([Request(prompt=[5, 6, 7], max_new_tokens=1),
                           Request(prompt=[9], max_new_tokens=6)])
    assert len(res[0].tokens) - res[0].prompt_len == 1
    assert len(res[1].tokens) - res[1].prompt_len == 6


def test_generate_rejects_empty_batch(engine):
    with pytest.raises(ValueError, match="at least one request"):
        engine.generate([])


def test_generate_rejects_empty_prompt(engine):
    reqs = [Request(prompt=[5, 6], max_new_tokens=2),
            Request(prompt=[], max_new_tokens=2)]
    with pytest.raises(ValueError, match="request 1 has an empty prompt"):
        engine.generate(reqs)


def test_generate_rejects_over_long_prompt(engine):
    """A prompt that cannot fit max_len (plus one generated token) fails
    fast with the offending index and sizes — not a shape error deep in
    prefill."""
    long = list(range(2, 2 + engine.max_len))      # max_len > limit
    with pytest.raises(ValueError) as exc:
        engine.generate([Request(prompt=[5], max_new_tokens=1),
                         Request(prompt=long, max_new_tokens=1)])
    msg = str(exc.value)
    assert "request 1" in msg
    assert f"{len(long)} tokens" in msg
    assert f"max_len={engine.max_len}" in msg
    assert "truncate_prompts=True" in msg


def test_generate_truncate_prompts_keeps_tail(engine):
    """truncate_prompts=True keeps the last max_len - 1 tokens and
    decodes normally; prompt_len reports the truncated length."""
    limit = engine.max_len - 1
    long = [(3 + i) % engine.cfg.vocab for i in range(engine.max_len + 5)]
    res = engine.generate([Request(prompt=long, max_new_tokens=1)],
                          truncate_prompts=True)[0]
    assert res.prompt_len == limit
    assert res.tokens[:limit] == long[-limit:]
    # exactly-at-limit prompts pass untouched either way
    ok = [5] * limit
    for flag in (False, True):
        r = engine.generate([Request(prompt=ok, max_new_tokens=1)],
                            truncate_prompts=flag)[0]
        assert r.tokens[:limit] == ok


def test_eos_stops(engine):
    # find whatever greedy emits first, then use it as eos
    probe = engine.generate([Request(prompt=[5, 5, 5], max_new_tokens=1,
                                     temperature=0.0)])[0]
    eos = probe.tokens[-1]
    res = engine.generate([Request(prompt=[5, 5, 5], max_new_tokens=10,
                                   temperature=0.0, eos_id=eos)])[0]
    assert len(res.tokens) - res.prompt_len <= 10
    assert eos in res.tokens[res.prompt_len:]


# ---------------------------------------------------------------------------
# continuous batching: arrival traces, in-order delivery, composition
# invariance
# ---------------------------------------------------------------------------


def _random_requests(rng, n, vocab, *, max_plen=16, max_new=8):
    reqs = []
    for _ in range(n):
        plen = int(rng.integers(1, max_plen + 1))
        prompt = [int(t) for t in rng.integers(0, vocab, size=plen)]
        reqs.append(Request(prompt=prompt,
                            max_new_tokens=int(rng.integers(1, max_new + 1)),
                            temperature=0.0))
    return reqs


def test_arrival_trace_matches_sequential_oracle(engine):
    """The continuous-batching contract, property-style: 64 requests with
    shuffled arrival times (mid-stream admits into freed slots), staggered
    lengths and max_new_tokens — every request's greedy token stream must
    be *bitwise* identical to running it through the engine alone, and
    results must come back in submission order."""
    rng = np.random.default_rng(42)
    reqs = _random_requests(rng, 64, engine.cfg.vocab)
    arrivals = rng.uniform(0.0, 30.0, size=len(reqs))

    rids = [engine.submit(r, arrival=float(a))
            for r, a in zip(reqs, arrivals)]
    results = engine.run()

    assert [r.rid for r in results] == rids          # in-order delivery
    for req, res in zip(reqs, results):
        oracle = engine.generate([req])[0]           # one-at-a-time spec
        assert res.tokens == oracle.tokens, \
            f"rid {res.rid}: batched stream diverged from the oracle"
        assert res.finish_reason == oracle.finish_reason
        assert res.prompt_len == len(req.prompt)


@pytest.fixture(scope="module")
def engine_exact2(setup):
    cfg, params = setup
    return Engine(cfg, params, max_len=96, seed=0, logprob_policy="exact2")


def test_exact2_logprob_bitwise_across_compositions(engine_exact2):
    """logprob_policy='exact2': a request's mean_logprob is bitwise
    invariant to batch composition — alone, batched at time zero, or
    interleaved with fillers under staggered arrivals, the float is the
    same object to the last bit (serving replicas agree exactly)."""
    eng = engine_exact2
    targets = [Request(prompt=[11, 12, 13, 14], max_new_tokens=5),
               Request(prompt=[7], max_new_tokens=8),
               Request(prompt=[30, 31], max_new_tokens=3)]
    fillers = [Request(prompt=[3, 4, 5], max_new_tokens=6),
               Request(prompt=[9, 9], max_new_tokens=2)]

    alone = [eng.generate([t])[0].mean_logprob for t in targets]
    batch0 = [r.mean_logprob for r in eng.generate(targets)]

    order = [(targets[0], 0.0), (fillers[0], 1.0), (targets[1], 2.0),
             (fillers[1], 4.0), (targets[2], 7.0)]
    rids = {id(req): eng.submit(req, arrival=a) for req, a in order}
    by_rid = {r.rid: r for r in eng.run()}
    staggered = [by_rid[rids[id(t)]].mean_logprob for t in targets]

    for a, b, c in zip(alone, batch0, staggered):
        assert a is not None
        # bitwise, not isclose: exact2 pins the exact float
        assert np.float32(a).tobytes() == np.float32(b).tobytes()
        assert np.float32(a).tobytes() == np.float32(c).tobytes()


def test_request_seed_reproducible_sampling(engine):
    """Per-request PRNG (satellite bugfix): sampled tokens derive from
    (engine seed, Request.seed, step) — not from an engine-wide key split
    — so a seeded request samples the same stream alone, co-batched, or
    resubmitted under a new request id."""
    seeded = Request(prompt=[5, 6, 7], max_new_tokens=6, temperature=0.9,
                     seed=123)
    other = Request(prompt=[40, 41], max_new_tokens=4, temperature=0.0)
    alone = engine.generate([seeded])[0].tokens
    batched = engine.generate([other, seeded])[1].tokens
    again = engine.generate([seeded])[0].tokens
    assert alone == batched == again

    # identical twins with the same explicit seed sample identically
    twin = Request(prompt=[5, 6, 7], max_new_tokens=6, temperature=0.9,
                   seed=7)
    twin2 = Request(prompt=[5, 6, 7], max_new_tokens=6, temperature=0.9,
                    seed=7)
    res = engine.generate([twin, twin2])
    assert res[0].tokens == res[1].tokens


def test_chunked_prefill_chunk_size_invariance(setup):
    """A prompt streamed in 3-token prefill chunks decodes the same greedy
    tokens as one streamed in a single chunk."""
    cfg, params = setup
    small = Engine(cfg, params, max_len=96, seed=0, prefill_chunk=3)
    big = Engine(cfg, params, max_len=96, seed=0, prefill_chunk=64)
    req = Request(prompt=[(2 + i) % cfg.vocab for i in range(11)],
                  max_new_tokens=5, temperature=0.0)
    a = small.generate([req])[0]
    b = big.generate([req])[0]
    assert a.tokens == b.tokens
    assert np.isclose(a.mean_logprob, b.mean_logprob, atol=1e-5)


def test_pool_exhaustion_queues_and_completes(setup):
    """A pool too small for concurrent requests serializes them through
    admission control — everything still completes, in order, with the
    same outputs."""
    cfg, params = setup
    eng = Engine(cfg, params, max_len=96, seed=0, max_batch=4,
                 page_size=16, num_pages=5)
    reqs = [Request(prompt=[(i + j) % cfg.vocab for j in range(30)],
                    max_new_tokens=4, temperature=0.0) for i in range(3)]
    # each needs ceil(34/16) = 3 of 5 pages -> at most one admitted at once
    rids = [eng.submit(r) for r in reqs]
    peak = {"live": 0}

    def probe(engine, step):
        peak["live"] = max(peak["live"], engine.pool.live_requests)

    results = eng.run(on_step=probe)
    assert [r.rid for r in results] == rids
    assert peak["live"] == 1
    assert eng.pool.free_pages == 5                  # all pages returned
    for req, res in zip(reqs, results):
        assert res.tokens == eng.generate([req])[0].tokens


def test_submit_rejects_request_larger_than_pool(setup):
    cfg, params = setup
    eng = Engine(cfg, params, max_len=96, seed=0, num_pages=5, page_size=16)
    with pytest.raises(ValueError, match="raise num_pages"):
        eng.submit(Request(prompt=[1] * 40, max_new_tokens=60))


def test_latency_and_finish_reason_populated(engine):
    res = engine.generate([Request(prompt=[8, 9], max_new_tokens=3)])[0]
    assert res.finish_reason == "length"
    assert res.latency_s >= 0.0
    assert res.rid >= 0
