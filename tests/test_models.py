"""Per-architecture smoke tests + model-level invariants.

For each of the 10 assigned architectures: instantiate the reduced
same-family SMOKE config, run one forward/loss and one train step on CPU,
assert output shapes and finiteness.  Plus: decode-vs-train parity, MoE
capacity-vs-dense equivalence, chunked-attention equivalence, SSM
chunk-invariance — the invariants the production paths rely on.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import (decode_step, encode, forward, init_caches,
                          init_params, loss_fn, pad_caches_to)
from repro.models.config import SHAPES, SHAPES_BY_NAME
from repro.optim import adamw
from repro.train.steps import make_train_step

KEY = jax.random.PRNGKey(0)


def _batch_for(cfg, b=2, s=32):
    batch = {}
    if cfg.embed_inputs and not cfg.is_encdec:
        batch["embeds"] = jax.random.normal(KEY, (b, s, cfg.d_model),
                                            jnp.float32)
        batch["labels"] = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
        if cfg.mrope:
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(s)[None, :, None], (b, s, 3)).astype(jnp.int32)
    else:
        batch["tokens"] = jax.random.randint(KEY, (b, s), 0, cfg.vocab)
    if cfg.is_encdec:
        batch["enc_embeds"] = jax.random.normal(KEY, (b, s, cfg.d_model),
                                                jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    params = init_params(KEY, cfg)
    batch = _batch_for(cfg)

    loss, metrics = loss_fn(params, cfg, batch, moe_impl="dense")
    assert np.isfinite(float(loss)), arch
    assert float(metrics["tokens"]) > 0

    lr_fn = adamw.cosine_schedule(1e-3, 2, 10)
    step = make_train_step(cfg, lr_fn=lr_fn, remat=False, moe_impl="dense")
    opt = adamw.init(params)
    p2, o2, m2 = jax.jit(step)(params, opt, batch)
    assert np.isfinite(float(m2["loss"])), arch
    assert int(o2.count) == 1
    # params actually changed
    delta = sum(float(jnp.abs(a - b).sum())
                for a, b in zip(jax.tree.leaves(params),
                                jax.tree.leaves(p2)))
    assert delta > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_arch_full_config_consistency(arch):
    """The FULL config (exercised via dry-run) is structurally valid."""
    cfg = get_config(arch)
    assert cfg.n_layers % len(cfg.period) == 0
    assert cfg.padded_vocab >= cfg.vocab
    assert cfg.padded_vocab % 256 == 0
    pc = cfg.param_counts()
    assert pc["active"] <= pc["total"]
    if cfg.moe:
        assert pc["active"] < pc["total"]


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if not get_smoke_config(a).embed_inputs])
def test_decode_matches_train(arch):
    cfg = get_smoke_config(arch)
    params = init_params(jax.random.PRNGKey(1), cfg)
    B, S = 2, 24
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S + 1), 0,
                              cfg.vocab)
    enc_out = None
    if cfg.is_encdec:
        enc = jax.random.normal(KEY, (B, 16, cfg.d_model), jnp.float32)
        enc_out = encode(params, cfg, enc)
    full, _, _ = forward(params, cfg, tokens=toks, mode="train",
                         enc_out=enc_out, moe_impl="dense")
    _, caches, _ = forward(params, cfg, tokens=toks[:, :S], mode="prefill",
                           enc_out=enc_out, moe_impl="dense")
    caches = pad_caches_to(cfg, caches, 32)
    dec, _ = decode_step(params, cfg, toks[:, S:S + 1], caches, S,
                         enc_out=enc_out, moe_impl="dense")
    rel = (float(jnp.abs(dec[:, 0] - full[:, S]).max())
           / float(jnp.abs(full[:, S]).max()))
    assert rel < 2e-2, (arch, rel)


def test_vlm_decode_with_tokens():
    """qwen2-vl: embeds prefill (patch stubs) then token decode."""
    cfg = get_smoke_config("qwen2-vl-7b")
    params = init_params(KEY, cfg)
    B, S = 2, 16
    embeds = jax.random.normal(KEY, (B, S, cfg.d_model), jnp.float32)
    pos = jnp.broadcast_to(jnp.arange(S)[None, :, None],
                           (B, S, 3)).astype(jnp.int32)
    _, caches, _ = forward(params, cfg, embeds=embeds, positions=pos,
                           mode="prefill", moe_impl="dense")
    caches = pad_caches_to(cfg, caches, 32)
    tok = jax.random.randint(KEY, (B, 1), 0, cfg.vocab)
    logits, caches2 = decode_step(params, cfg, tok, caches, S,
                                  moe_impl="dense")
    assert logits.shape == (B, 1, cfg.padded_vocab)
    assert np.isfinite(np.asarray(logits)).all()


def test_moe_capacity_matches_dense():
    from repro.models import moe as M
    cfg = get_smoke_config("mixtral-8x22b").scaled(
        moe=get_smoke_config("mixtral-8x22b").moe.__class__(
            num_experts=4, top_k=2, d_ff_expert=64, capacity_factor=8.0))
    p = M.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (2, 40, cfg.d_model))
    yc, auxc = M.moe_apply_capacity(p, x, cfg, group_size=16)
    yd, auxd = M.moe_apply_dense(p, x, cfg)
    np.testing.assert_allclose(np.asarray(yc), np.asarray(yd), atol=1e-4)
    assert np.allclose(float(auxc), float(auxd))


def test_moe_capacity_drops_under_tight_capacity():
    from repro.models import moe as M
    cfg = get_smoke_config("mixtral-8x22b")
    p = M.moe_init(KEY, cfg, jnp.float32)
    x = jax.random.normal(KEY, (1, 64, cfg.d_model))
    y_tight, _ = M.moe_apply_capacity(p, x, cfg, capacity=1, group_size=64)
    y_loose, _ = M.moe_apply_capacity(p, x, cfg, capacity=64, group_size=64)
    assert not np.allclose(np.asarray(y_tight), np.asarray(y_loose))
    assert np.isfinite(np.asarray(y_tight)).all()


def test_chunked_attention_matches_full():
    for arch in ("stablelm-1.6b", "deepseek-v2-lite-16b"):
        cfg = get_smoke_config(arch)
        p = init_params(KEY, cfg)
        toks = jax.random.randint(KEY, (2, 64), 0, cfg.vocab)
        l1, _, _ = forward(p, cfg.scaled(attn_qchunk=4096), tokens=toks,
                           moe_impl="dense")
        l2, _, _ = forward(p, cfg.scaled(attn_qchunk=8), tokens=toks,
                           moe_impl="dense")
        np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                                   atol=2e-3)


def test_swa_ring_cache_long_decode():
    """Mixtral-style SWA: decode far past the window; ring cache stays
    O(window) and matches a full-cache windowed reference."""
    cfg = get_smoke_config("mixtral-8x22b").scaled(window=8, n_layers=2)
    p = init_params(KEY, cfg)
    B, S = 1, 24
    toks = jax.random.randint(jax.random.PRNGKey(3), (B, S + 4), 0,
                              cfg.vocab)
    # reference: full forward logits at each position
    full, _, _ = forward(p, cfg, tokens=toks, mode="train", moe_impl="dense")
    _, caches, _ = forward(p, cfg, tokens=toks[:, :S], mode="prefill",
                           moe_impl="dense")
    assert caches[0]["core"].k.shape[2] == cfg.window      # ring-sized
    pos = S
    for i in range(4):
        lg, caches = decode_step(p, cfg, toks[:, S + i:S + i + 1], caches,
                                 pos, moe_impl="dense")
        rel = (float(jnp.abs(lg[:, 0] - full[:, S + i]).max())
               / float(jnp.abs(full[:, S + i]).max()))
        assert rel < 2e-2, (i, rel)
        pos += 1


def test_ssm_chunk_invariance():
    from repro.models import ssm
    from repro.models.config import MambaCfg
    m = MambaCfg(d_state=4)
    p = ssm.mamba_init(KEY, 16, m, jnp.float32)
    x = jax.random.normal(KEY, (2, 33, 16))
    y1, _ = ssm.mamba_apply(p, x, m, chunk=8)
    y2, _ = ssm.mamba_apply(p, x, m, chunk=64)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_loss_chunk_invariance():
    cfg = get_smoke_config("minitron-8b")
    p = init_params(KEY, cfg)
    batch = {"tokens": jax.random.randint(KEY, (2, 32), 0, cfg.vocab)}
    l1, _ = loss_fn(p, cfg.scaled(loss_chunk=8), batch, moe_impl="dense")
    l2, _ = loss_fn(p, cfg.scaled(loss_chunk=4096), batch, moe_impl="dense")
    assert abs(float(l1) - float(l2)) < 1e-5


def test_mrope_text_equals_rope():
    """M-RoPE with equal position streams == standard RoPE."""
    from repro.models.layers import apply_mrope, apply_rope
    x = jax.random.normal(KEY, (2, 16, 4, 128))
    pos = jnp.broadcast_to(jnp.arange(16)[None, :], (2, 16))
    pos3 = jnp.broadcast_to(pos[..., None], (2, 16, 3))
    a = apply_rope(x, pos, 1e4)
    b = apply_mrope(x, pos3, 1e4, (16, 24, 24))
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_virtual_experts_exact_equivalence():
    """moe_virtual_split=2: splitting each expert's FFN into column shards
    is mathematically exact (y = sum_v (x @ wi_v) @ wo_v)."""
    import dataclasses
    from repro.models import moe as M
    from repro.models.config import BlockSpec, ModelConfig, MoECfg
    base = ModelConfig(name="t", family="moe", n_layers=1, d_model=32,
                       n_heads=4, n_kv_heads=2, d_ff=64, vocab=128,
                       period=(BlockSpec("attn", "moe"),),
                       moe=MoECfg(num_experts=4, top_k=2, d_ff_expert=64,
                                  capacity_factor=8.0))
    cfg2 = base.scaled(moe_virtual_split=2)
    p1 = M.moe_init(KEY, base, jnp.float32)
    e, d, f = p1["wi"].shape
    p2 = {"router": p1["router"],
          "wi": p1["wi"].reshape(e, d, 2, f // 2).transpose(0, 2, 1, 3)
                        .reshape(2 * e, d, f // 2),
          "wg": p1["wg"].reshape(e, d, 2, f // 2).transpose(0, 2, 1, 3)
                        .reshape(2 * e, d, f // 2),
          "wo": p1["wo"].reshape(e, 2, f // 2, d).reshape(2 * e, f // 2, d)}
    x = jax.random.normal(KEY, (2, 40, 32))
    y1, _ = M.moe_apply_capacity(p1, x, base, group_size=16)
    y2, _ = M.moe_apply_capacity(p2, x, cfg2, group_size=16)
    y2d, _ = M.moe_apply_dense(p2, x, cfg2)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y2d), atol=1e-4)
