"""detlint: the determinism-contract static analyzer (CI hard gate).

Layer 1 lints ``src/repro`` ASTs with the DET001–DET006 rules
(``repro.analysis.rules``); layer 2 ``make_jaxpr``-traces every
registered policy × backend × op and checks the carry/barrier/
invariance contracts (DET101–DET105, ``repro.analysis.contracts``).
See docs/determinism-lint.md for the rule table and waiver policy.

    PYTHONPATH=src python tools/detlint.py                 # full run
    PYTHONPATH=src python tools/detlint.py --ast-only      # no tracing
    PYTHONPATH=src python tools/detlint.py --check-waivers # + ratchet
    PYTHONPATH=src python tools/detlint.py --write-baseline

Exit status: nonzero on any unwaived finding; ``--check-waivers``
additionally fails when a rule's waiver count rises above
``tools/detlint_baseline.json`` (the ratchet: waivers may only go
down — tighten the baseline when they do).
"""

from __future__ import annotations

import argparse
import json
import sys
from collections import Counter
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
for _p in (str(REPO), str(REPO / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.analysis import walker, rules  # noqa: E402

BASELINE = REPO / "tools" / "detlint_baseline.json"
DEFAULT_ROOTS = ("src/repro",)


def waiver_counts(findings) -> dict:
    return dict(Counter(f.rule for f in findings if f.waived))


def check_ratchet(counts: dict, baseline: dict):
    """(errors, notes): errors when a rule's waiver count rose above the
    baseline; notes when it fell (tighten the baseline)."""
    errors, notes = [], []
    for rule in sorted(set(counts) | set(baseline)):
        now, base = counts.get(rule, 0), baseline.get(rule, 0)
        if now > base:
            errors.append(
                f"{rule}: {now} waivers > baseline {base} — new waivers "
                f"need a reviewed reason AND a baseline bump in the same "
                f"change (tools/detlint_baseline.json)")
        elif now < base:
            notes.append(
                f"{rule}: {now} waivers < baseline {base} — ratchet down: "
                f"run --write-baseline to lock in the improvement")
    return errors, notes


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="detlint", description=__doc__,
        formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/dirs to lint (default: src/repro)")
    ap.add_argument("--ast-only", action="store_true",
                    help="skip the jaxpr contract checks (layer 2)")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule ids to run (layer 1)")
    ap.add_argument("--check-waivers", action="store_true",
                    help="enforce the waiver-count ratchet against "
                         "tools/detlint_baseline.json")
    ap.add_argument("--write-baseline", action="store_true",
                    help="rewrite the waiver baseline from this run")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="only print failures")
    args = ap.parse_args(argv)

    roots = args.paths or [str(REPO / r) for r in DEFAULT_ROOTS]
    rule_filter = (set(r.strip() for r in args.rules.split(","))
                   if args.rules else None)

    files = walker.iter_source_files(roots)
    findings = rules.run_lint(files, rules=rule_filter)
    if not args.ast_only and rule_filter is None:
        from repro.analysis import contracts
        findings.extend(contracts.run_contracts())

    unwaived = [f for f in findings if not f.waived]
    counts = waiver_counts(findings)

    for f in unwaived:
        print(f)
    if not args.quiet:
        waived = [f for f in findings if f.waived]
        per_rule = ", ".join(f"{r}={n}" for r, n in sorted(counts.items()))
        print(f"detlint: {len(files)} files, {len(unwaived)} unwaived "
              f"finding(s), {len(waived)} waived ({per_rule or 'none'})")

    status = 1 if unwaived else 0

    if args.write_baseline:
        BASELINE.write_text(json.dumps(counts, indent=2, sort_keys=True)
                            + "\n")
        print(f"detlint: baseline written to {BASELINE}")
    elif args.check_waivers:
        baseline = json.loads(BASELINE.read_text()) if BASELINE.exists() \
            else {}
        errors, notes = check_ratchet(counts, baseline)
        for e in errors:
            print(f"detlint ratchet: {e}")
        for n in notes:
            print(f"detlint ratchet (note): {n}")
        if errors:
            status = 1

    return status


if __name__ == "__main__":
    sys.exit(main())
