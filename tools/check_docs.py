"""Doc drift check: README/docs references to files and symbols must
resolve.

Scans README.md and docs/*.md for

  * markdown links to local files/anchors — the target must exist;
  * backticked path-like references (``src/repro/reduce/api.py``,
    ``examples/multi_device_reduce.py``, ``repro/reduce/policy.py`` —
    with or without the ``src/`` prefix, files or directories);
  * backticked dotted symbols rooted at the package
    (``repro.reduce.collective_mean``,
    ``benchmarks.run``) — the import + attribute chain must resolve;
  * ``path.py::symbol`` pytest-style references — file and attribute
    both checked.

Exits non-zero listing every dangling reference, so CI fails on drift
(e.g. a doc still naming a deleted shim like ``segment_sum_blocked``).

Symbol resolution is shared with the determinism linter
(``repro.analysis.walker``): REQUIRED_SYMBOLS entries must not only
resolve but *originate* under their documented package
(``symbol_origin_ok``), so a symbol that moves modules while a stale
package re-export keeps the old path importable fails here instead of
silently passing.

    PYTHONPATH=src python tools/check_docs.py
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# `python tools/check_docs.py` puts tools/ on sys.path, not the repo root:
# make the documented `repro.*` / `benchmarks.*` symbol resolution work
# regardless of how we were invoked.
for _p in (str(REPO), str(REPO / "src")):
    if _p not in sys.path:
        sys.path.insert(0, _p)

from repro.analysis import walker  # noqa: E402

#: files whose references we hold to the resolve-or-fail bar
DOC_FILES = [REPO / "README.md", *sorted((REPO / "docs").glob("*.md"))]

#: load-bearing public API the docs *must* keep naming (and that must
#: keep importing): the contract surface of the three-limb exact path.
#: A rename that forgets the docs — or drops the symbol — fails CI here.
REQUIRED_SYMBOLS = [
    "repro.core.intac.limb_split3",
    "repro.core.intac.limb_add3",
    "repro.core.intac.limb_merge3",
    "repro.core.intac.limbs_resolve3",
    "repro.core.intac.limbs_canonical",
    "repro.core.intac.intac_psum3",
    "repro.core.intac.Limb3State",
    "repro.reduce.Limb3Accumulator",
    "repro.reduce.collective_mean",
    "repro.reduce.merge_carry_across",
    # the robustness surface (docs/robustness.md): status flags, elastic
    # resume, and the crash-safe checkpoint entry points
    "repro.reduce.ReduceStatus",
    "repro.reduce.elastic_reduce_mean",
    "repro.ckpt.checkpoint.CheckpointError",
    "repro.ckpt.checkpoint.restore_latest_valid",
    # the serving surface (docs/serving.md): continuous batching, paged
    # KV admission, and the paged-gather decode kernel
    "repro.serve.engine.Engine",
    "repro.serve.scheduler.Scheduler",
    "repro.serve.kv_pool.PagedKVPool",
    "repro.kernels.ops.flash_decode_paged",
    # the staged block-program surface (docs/performance.md): the planned
    # program every backend executes, its stage cost hints, and the fused
    # collective the shard merges lower through
    "repro.reduce.BlockProgram",
    "repro.reduce.plan_program",
    "repro.reduce.program.BlockStage",
    "repro.reduce.block_contrib",
    "repro.reduce.fused_psum",
    "benchmarks.roofline.reduce_program_table",
    # the reduction-algebra surface (docs/algebra.md): the op registry,
    # the registered ops, the cascaded time-weighting constructors, and
    # the collective companions of the new ops
    "repro.reduce.ReduceOp",
    "repro.reduce.register_op",
    "repro.reduce.get_op",
    "repro.reduce.algebra.WeightedSumOp",
    "repro.reduce.algebra.SumsqOp",
    "repro.reduce.algebra.MomentsOp",
    "repro.reduce.algebra.PolyOp",
    "repro.reduce.CascadeAccumulator",
    "repro.reduce.poly_weights",
    "repro.reduce.fir_weights",
    "repro.reduce.cascade_weights",
    "repro.reduce.cascade_poly_coeffs",
    "repro.reduce.collective_weighted_mean",
    "repro.reduce.collective_moments",
    # the determinism-lint surface (docs/determinism-lint.md): the AST
    # rules, the jaxpr contract checker, and the shared walker they and
    # this very checker discover/resolve through
    "repro.analysis.run_lint",
    "repro.analysis.Finding",
    "repro.analysis.LintRule",
    "repro.analysis.run_contracts",
    "repro.analysis.walker.iter_source_files",
    "repro.analysis.walker.parse_source",
    "repro.analysis.walker.resolve_symbol",
    "repro.analysis.walker.symbol_origin_ok",
]


def check_required_symbols() -> list:
    """Every REQUIRED_SYMBOLS entry must import, *originate* under its
    documented package (``walker.symbol_origin_ok`` — catches stale
    re-exports after a cross-package move), and be mentioned (by its
    unqualified name) somewhere in the doc set."""
    errors = []
    docs_text = "\n".join(p.read_text() for p in DOC_FILES)
    for ref in REQUIRED_SYMBOLS:
        if not walker.symbol_resolves(ref):
            errors.append(f"required symbol {ref!r} does not resolve")
        elif not walker.symbol_origin_ok(ref):
            errors.append(
                f"required symbol {ref!r} resolves but is defined in "
                f"{walker.symbol_origin(ref)!r} — moved module? update "
                f"the docs and this pin")
        if ref.rsplit(".", 1)[-1] not in docs_text:
            errors.append(f"required symbol {ref!r} is not mentioned in "
                          f"any doc file")
    return errors

_BACKTICK = re.compile(r"`([^`\n]+)`")
_MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
_PATHLIKE = re.compile(r"^[\w./-]+(?:\.(?:py|md|txt|yml|toml)|/)$")
_DOTTED = re.compile(r"^(repro|benchmarks)(\.\w+)+$")
_PYTEST_REF = re.compile(r"^([\w./-]+\.py)::(\w+)$")


def _resolve_path(ref: str):
    """The on-disk Path for a doc reference (repo root or src/), or None."""
    ref = ref.rstrip("/")
    for base in (REPO, REPO / "src"):
        if (base / ref).exists():
            return base / ref
    return None


def _path_resolves(ref: str) -> bool:
    return _resolve_path(ref) is not None


def check_file(path: Path) -> list:
    text = path.read_text()
    errors = []

    for m in _MD_LINK.finditer(text):
        target = m.group(1)
        if "://" in target:                     # external URL: out of scope
            continue
        target = target.split("#")[0]
        if target and not (path.parent / target).exists() \
                and not _path_resolves(target):
            errors.append(f"{path.name}: dangling link target {target!r}")

    for m in _BACKTICK.finditer(text):
        ref = m.group(1).strip()
        pytest_ref = _PYTEST_REF.match(ref)
        if pytest_ref:
            fpath, sym = pytest_ref.groups()
            resolved = _resolve_path(fpath)
            if resolved is None:
                errors.append(f"{path.name}: dangling path {fpath!r}")
            elif not re.search(rf"def {sym}\b|class {sym}\b",
                               resolved.read_text()):
                errors.append(f"{path.name}: {fpath!r} has no {sym!r}")
        elif _PATHLIKE.match(ref) and "/" in ref:
            if not _path_resolves(ref):
                errors.append(f"{path.name}: dangling path {ref!r}")
        elif _DOTTED.match(ref):
            if not walker.symbol_resolves(ref):
                errors.append(f"{path.name}: unresolvable symbol {ref!r}")
    return errors


def main() -> int:
    errors = []
    for f in DOC_FILES:
        errors.extend(check_file(f))
    errors.extend(check_required_symbols())
    if errors:
        print(f"doc check: {len(errors)} dangling reference(s)")
        for e in errors:
            print(f"  {e}")
        return 1
    print(f"doc check: {len(DOC_FILES)} files clean "
          f"({', '.join(f.name for f in DOC_FILES)})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
