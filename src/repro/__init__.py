"""repro — JugglePAC/INTAC (pipelined accumulation) as a TPU-native
streaming-reduction framework: faithful cycle-accurate reproduction plus a
multi-pod JAX training/inference stack built on the technique."""

__version__ = "1.0.0"
