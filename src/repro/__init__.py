"""repro — JugglePAC/INTAC (pipelined accumulation) as a TPU-native
streaming-reduction framework: faithful cycle-accurate reproduction plus a
multi-pod JAX training/inference stack built on the technique.

The front door for every reduction is ``repro.reduce``:

    from repro import reduce
    out = reduce(values, segment_ids=ids, num_segments=8,
                 op="mean", policy="exact")     # or call repro.reduce(...)

with accuracy policies (fast / compensated / exact), registered backends
(ref / blocked / pallas), the streaming ``Accumulator`` protocol, and the
policy-selectable cross-device ``collective_mean``.
"""

from . import reduce  # noqa: F401  (callable module: repro.reduce(...))

__version__ = "1.1.0"
