"""repro — JugglePAC/INTAC (pipelined accumulation) as a TPU-native
streaming-reduction framework: faithful cycle-accurate reproduction plus a
multi-pod JAX training/inference stack built on the technique.

The front door for every reduction is ``repro.reduce``:

    from repro import reduce
    out = reduce(values, segment_ids=ids, num_segments=8,
                 op="mean", policy="exact")     # or call repro.reduce(...)

with accuracy policies (fast / compensated / exact / exact2 /
procrastinate), registered backends (ref / blocked / pallas / shard_map —
the last scales across a device mesh with bitwise-identical results for
the integer tiers), the streaming ``Accumulator`` protocol, and the
policy-selectable cross-device ``collective_mean``.  See
docs/architecture.md for the layer map and docs/policies.md for the
accuracy ladder.
"""

from . import reduce  # noqa: F401  (callable module: repro.reduce(...))

__version__ = "1.3.0"
