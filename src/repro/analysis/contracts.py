"""Layer 2 of detlint: jaxpr-level determinism-contract checks.

The AST rules catch textual drift; this layer checks the *traced*
program.  Every registered policy × backend (× op, for coverage) is
``jax.make_jaxpr``-traced on canonical shapes — no compilation, no
device execution — and the traces are held to the contract
docs/architecture.md promises:

DET101  the carry a backend actually produces matches the policy's
        declared ``carry_dtypes`` / ``carry_len`` (a policy that
        declares int32 limbs but traces to f32 has silently left the
        exact tier).
DET102  ``merge_is_add`` policies carry only integer leaves in the
        *traced* carry — a float leaf under a psum merge is
        order-sensitive across shards.  The fast tier's documented
        float tolerance is allowlisted in
        ``rules.TOLERATED_FLOAT_MERGE`` and surfaces as a *waived*
        finding, counted by the ratchet like any pragma.
DET103  fold bodies keep their ``optimization_barrier``s: the unrolled
        ref schedule must trace >= one barrier per block, and the Pallas
        kernel body >= one per fused block per grid step (the PR 8
        regression, checked statically).
DET104  claimed-invariant tiers (all-integer carries) produce
        structurally identical jaxprs across block sizes: same primitive
        vocabulary, same output avals.  A block-size-dependent primitive
        sneaking into an exact tier breaks bitwise-across-block-sizes.
DET105  coverage: the full policy × backend × op matrix traces at all.
        A combination that raises at trace time is a contract hole the
        runtime tests may never visit.

Run via ``python tools/detlint.py`` (layer 2 included by default) or
``repro.analysis.run_contracts()``.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.analysis.rules import Finding, TOLERATED_FLOAT_MERGE

#: canonical trace shapes: small enough to trace in milliseconds, big
#: enough for multiple blocks at the two canonical block sizes
_S, _D, _N = 4, 2, 128
_BLOCK_SIZES = (32, 64)


def _jaxpr_types():
    import jax
    try:
        from jax.extend import core as jex_core
        return (jex_core.Jaxpr, jex_core.ClosedJaxpr)
    except (ImportError, AttributeError):
        return (jax.core.Jaxpr, jax.core.ClosedJaxpr)


def _sub_jaxprs(v, types):
    if isinstance(v, types[0]):
        yield v
    elif isinstance(v, types[1]):
        yield v.jaxpr
    elif isinstance(v, (list, tuple)):
        for x in v:
            yield from _sub_jaxprs(x, types)


def count_primitive(jaxpr, name: str, *, _types=None) -> int:
    """Occurrences of primitive ``name`` in ``jaxpr``, recursing into
    sub-jaxprs (scan bodies, pjit calls, pallas kernel bodies)."""
    types = _types or _jaxpr_types()
    if isinstance(jaxpr, types[1]):
        jaxpr = jaxpr.jaxpr
    n = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == name:
            n += 1
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v, types):
                n += count_primitive(sub, name, _types=types)
    return n


def primitive_names(jaxpr, *, _types=None) -> frozenset:
    """The primitive vocabulary of a jaxpr, recursively."""
    types = _types or _jaxpr_types()
    if isinstance(jaxpr, types[1]):
        jaxpr = jaxpr.jaxpr
    names = set()
    for eqn in jaxpr.eqns:
        names.add(eqn.primitive.name)
        for v in eqn.params.values():
            for sub in _sub_jaxprs(v, types):
                names |= primitive_names(sub, _types=types)
    return frozenset(names)


@dataclasses.dataclass
class _Ctx:
    """Imports + canonical inputs, built once per run."""

    jax: object
    jnp: object
    policies: Dict
    backends: Dict
    ops: Dict
    mesh: object
    vals: np.ndarray
    ids: np.ndarray

    @classmethod
    def build(cls):
        import jax
        import jax.numpy as jnp
        from repro.reduce.policy import POLICIES
        from repro.reduce.backends import BACKENDS, default_mesh
        from repro.reduce.algebra import REDUCE_OPS
        rng = np.random.RandomState(0)
        vals = rng.randn(_N, _D).astype(np.float32)
        ids = (np.arange(_N) % _S).astype(np.int32)
        return cls(jax=jax, jnp=jnp, policies=dict(POLICIES),
                   backends=dict(BACKENDS), ops=dict(REDUCE_OPS),
                   mesh=default_mesh(), vals=vals, ids=ids)

    def run_kwargs(self, backend) -> Dict:
        kw = {}
        if getattr(backend, "distributed", False):
            kw["mesh"] = self.mesh
        return kw

    def trace_carry(self, policy, backend, *, block_size: int, **extra):
        """make_jaxpr of prepare + backend.run; returns the ClosedJaxpr
        whose outputs are the raw carry leaves."""

        def fn(v, i):
            domain, _ctx = policy.prepare(v, _N)
            return backend.run(domain, i, _S, policy=policy,
                               block_size=block_size, interpret=True,
                               **self.run_kwargs(backend), **extra)

        return self.jax.make_jaxpr(fn)(self.vals, self.ids)

    def trace_reduce(self, policy_name: str, backend_name: str,
                     op_name: str, *, block_size: int):
        from repro.reduce import api
        op = self.ops[op_name]
        kw = {}
        if getattr(op, "takes_weights", False):
            kw["weights"] = np.ones((_N,), np.float32)
        if getattr(op, "requires_coeffs", False):
            kw["coeffs"] = (0.0, 1.0)
        if getattr(self.backends[backend_name], "distributed", False):
            kw["mesh"] = self.mesh

        def fn(v, i):
            return api.reduce(v, segment_ids=i, num_segments=_S,
                              op=op_name, policy=policy_name,
                              backend=backend_name, block_size=block_size,
                              interpret=True, **kw)

        return self.jax.make_jaxpr(fn)(self.vals, self.ids)


def _dtypes_of(closed) -> Tuple:
    return tuple(np.dtype(a.dtype) for a in closed.out_avals)


def _carry_dtype_findings(ctx: _Ctx) -> List[Finding]:
    out = []
    seen_102 = set()
    for pname, policy in sorted(ctx.policies.items()):
        declared = tuple(np.dtype(d) for d in policy.carry_dtypes)
        for bname, backend in sorted(ctx.backends.items()):
            if not backend.supports(policy):
                continue
            try:
                closed = ctx.trace_carry(policy, backend,
                                         block_size=_BLOCK_SIZES[0])
            except Exception as e:
                out.append(Finding(
                    rule="DET101", path=f"{pname}/{bname}", line=0,
                    message=f"carry trace failed: "
                            f"{type(e).__name__}: {e}"))
                continue
            traced = _dtypes_of(closed)
            if len(traced) != policy.carry_len or traced != declared:
                out.append(Finding(
                    rule="DET101", path=f"{pname}/{bname}", line=0,
                    message=f"traced carry {[str(d) for d in traced]} != "
                            f"declared carry_dtypes "
                            f"{[str(d) for d in declared]} "
                            f"(carry_len={policy.carry_len})"))
            # DET102 on the *traced* carry, not just the declaration —
            # once per policy (the carry is backend-independent)
            if pname not in seen_102 and \
                    getattr(policy, "merge_is_add", False) and \
                    any(d.kind == "f" for d in traced):
                seen_102.add(pname)
                tol = TOLERATED_FLOAT_MERGE.get(pname)
                out.append(Finding(
                    rule="DET102", path=pname, line=0,
                    message=f"merge_is_add policy traces float carry "
                            f"leaves {[str(d) for d in traced]} — psum "
                            f"merge of floats is shard-order-sensitive",
                    waived=tol is not None, reason=tol or ""))
    return out


def _barrier_findings(ctx: _Ctx) -> List[Finding]:
    """DET103: every policy's unrolled ref schedule keeps one barrier
    per block, and the Pallas kernel body one per fused block."""
    out = []
    nb = _N // _BLOCK_SIZES[0]
    ref = ctx.backends.get("ref")
    pal = ctx.backends.get("pallas")
    for pname, policy in sorted(ctx.policies.items()):
        if ref is not None and ref.supports(policy):
            try:
                closed = ctx.trace_carry(policy, ref,
                                         block_size=_BLOCK_SIZES[0])
                n = count_primitive(closed, "optimization_barrier")
                if n < nb:
                    out.append(Finding(
                        rule="DET103", path=f"{pname}/ref", line=0,
                        message=f"{n} optimization_barrier(s) for {nb} "
                                f"unrolled blocks — XLA may reassociate "
                                f"float folds across block boundaries"))
            except Exception as e:
                out.append(Finding(
                    rule="DET103", path=f"{pname}/ref", line=0,
                    message=f"barrier trace failed: "
                            f"{type(e).__name__}: {e}"))
        if pal is not None and pal.supports(policy):
            bps = 2
            try:
                closed = ctx.trace_carry(policy, pal,
                                         block_size=_BLOCK_SIZES[0],
                                         blocks_per_step=bps)
                n = count_primitive(closed, "optimization_barrier")
                if n < bps:
                    out.append(Finding(
                        rule="DET103", path=f"{pname}/pallas", line=0,
                        message=f"{n} optimization_barrier(s) in the "
                                f"kernel for {bps} fused blocks per grid "
                                f"step — the PR 8 in-kernel fusion bug"))
            except Exception as e:
                out.append(Finding(
                    rule="DET103", path=f"{pname}/pallas", line=0,
                    message=f"kernel barrier trace failed: "
                            f"{type(e).__name__}: {e}"))
    return out


def _invariance_findings(ctx: _Ctx) -> List[Finding]:
    """DET104: all-integer-carry tiers must trace to the same primitive
    vocabulary and output avals at different block sizes."""
    out = []
    for pname, policy in sorted(ctx.policies.items()):
        declared = tuple(np.dtype(d) for d in policy.carry_dtypes)
        if any(d.kind == "f" for d in declared):
            continue       # only the claimed-invariant (integer) tiers
        traces = {}
        for bs in _BLOCK_SIZES:
            try:
                traces[bs] = ctx.trace_reduce(pname, "blocked", "sum",
                                              block_size=bs)
            except Exception as e:
                out.append(Finding(
                    rule="DET104", path=f"{pname}/blocked", line=0,
                    message=f"invariance trace (block_size={bs}) failed: "
                            f"{type(e).__name__}: {e}"))
        if len(traces) != len(_BLOCK_SIZES):
            continue
        a, b = (traces[bs] for bs in _BLOCK_SIZES)
        pa, pb = primitive_names(a), primitive_names(b)
        if pa != pb:
            out.append(Finding(
                rule="DET104", path=f"{pname}/blocked", line=0,
                message=f"primitive vocabulary differs across block "
                        f"sizes {_BLOCK_SIZES}: "
                        f"{sorted(pa ^ pb)} not in both"))
        if _dtypes_of(a) != _dtypes_of(b) or \
                [tuple(x.shape) for x in a.out_avals] != \
                [tuple(x.shape) for x in b.out_avals]:
            out.append(Finding(
                rule="DET104", path=f"{pname}/blocked", line=0,
                message=f"output avals differ across block sizes "
                        f"{_BLOCK_SIZES}"))
    return out


def _coverage_findings(ctx: _Ctx) -> List[Finding]:
    """DET105: the whole registered matrix must trace."""
    out = []
    combos = 0
    for oname in sorted(ctx.ops):
        for pname, policy in sorted(ctx.policies.items()):
            for bname, backend in sorted(ctx.backends.items()):
                if not backend.supports(policy):
                    continue
                combos += 1
                try:
                    ctx.trace_reduce(pname, bname, oname,
                                     block_size=_BLOCK_SIZES[0])
                except Exception as e:
                    out.append(Finding(
                        rule="DET105", path=f"{pname}/{bname}/{oname}",
                        line=0,
                        message=f"front-door trace failed: "
                                f"{type(e).__name__}: {e}"))
    if combos == 0:
        out.append(Finding(rule="DET105", path="<matrix>", line=0,
                           message="registry matrix is empty — nothing "
                                   "was checked"))
    return out


def run_contracts(*, checks: Optional[Sequence[str]] = None
                  ) -> List[Finding]:
    """Run the jaxpr contract checks; returns findings (waived ones only
    where the tolerance table vouches for them).

    ``checks`` filters to a subset of {"carry", "barriers",
    "invariance", "coverage"}.
    """
    try:
        ctx = _Ctx.build()
    except Exception as e:    # loud, unwaivable: checker can't even load
        return [Finding(rule="DET105", path="<registry>", line=0,
                        message=f"contract checker failed to load the "
                                f"registries: {type(e).__name__}: {e}")]
    steps = {
        "carry": _carry_dtype_findings,
        "barriers": _barrier_findings,
        "invariance": _invariance_findings,
        "coverage": _coverage_findings,
    }
    findings: List[Finding] = []
    for name, fn in steps.items():
        if checks and name not in checks:
            continue
        findings.extend(fn(ctx))
    findings.sort(key=lambda f: (f.rule, f.path))
    return findings
