"""Shared file/AST discovery for every static check in this repo.

One walker, three consumers: the determinism linter (``tools/detlint.py``
-> ``repro.analysis.rules``), the jaxpr contract checker
(``repro.analysis.contracts``), and the doc-drift checker
(``tools/check_docs.py``).  Each used to grow its own idea of "the
repo's source files" and "does this dotted symbol resolve"; drift
between those ideas is exactly how a check silently stops covering a
file, so the discovery path lives here, once.

Provides:

  * ``repo_root()`` / ``iter_source_files(roots)`` — the one file
    discovery path (sorted, ``__pycache__``-free, de-duplicated);
  * ``SourceModule`` / ``parse_module`` — a parsed file with its AST,
    source lines, a child->parent node map, and the waiver pragmas;
  * waiver pragmas: ``# detlint: ok[DET001] reason`` (comma-separated
    rule ids) waives findings whose flagged node overlaps the pragma
    line; a pragma on a comment-only line covers the next code line;
  * ``dotted_name(node)`` — "jnp.sum" / "jax.lax.psum" for attribute
    chains (the vocabulary every AST rule matches against);
  * ``resolve_symbol(ref)`` / ``symbol_origin(ref)`` — the import +
    attribute chain resolution the doc checker pins public API with.
    ``symbol_origin`` also reports the resolved object's defining
    module so a *stale re-export* (symbol moved modules, old path still
    resolves via a package ``__init__``) is caught instead of silently
    passing.
"""

from __future__ import annotations

import ast
import dataclasses
import importlib
import re
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

#: directories never scanned, wherever they appear
EXCLUDE_DIRS = {"__pycache__", ".git", ".claude", "experiments"}

#: the waiver pragma: ``# detlint: ok[DET001]`` or
#: ``# detlint: ok[DET001,DET003] why this is fine``
_PRAGMA = re.compile(r"#\s*detlint:\s*ok\[([A-Z0-9,\s]+)\]\s*(.*)$")


def repo_root() -> Path:
    """The repository root (three levels above this file: src/repro/analysis)."""
    return Path(__file__).resolve().parents[3]


def iter_source_files(roots: Sequence, *,
                      suffix: str = ".py") -> List[Path]:
    """Every source file under ``roots`` (files or directories), sorted,
    excluding ``EXCLUDE_DIRS`` — the one discovery path shared by the
    linter and the doc checker."""
    seen: Set[Path] = set()
    out: List[Path] = []
    for root in roots:
        root = Path(root)
        if root.is_file():
            candidates: Iterable[Path] = [root]
        else:
            candidates = sorted(root.rglob(f"*{suffix}"))
        for p in candidates:
            rp = p.resolve()
            if rp in seen or any(part in EXCLUDE_DIRS for part in rp.parts):
                continue
            seen.add(rp)
            out.append(p)
    return out


@dataclasses.dataclass
class Waiver:
    """One parsed ``# detlint: ok[...]`` pragma."""

    line: int                      # 1-based line the pragma covers
    rules: Tuple[str, ...]         # rule ids it waives ("*" = all)
    reason: str = ""

    def covers(self, rule: str, lo: int, hi: int) -> bool:
        return (self.line >= lo and self.line <= hi
                and (rule in self.rules or "*" in self.rules))


@dataclasses.dataclass
class SourceModule:
    """A parsed source file plus everything the rules need to judge it."""

    path: Path
    text: str
    tree: ast.AST
    lines: List[str]
    parents: Dict[ast.AST, ast.AST]
    waivers: List[Waiver]

    @property
    def rel(self) -> str:
        try:
            return str(self.path.resolve().relative_to(repo_root()))
        except ValueError:
            return str(self.path)

    def waiver_for(self, rule: str, node: ast.AST) -> Optional[Waiver]:
        """The pragma waiving ``rule`` at ``node``, if any.  A pragma
        waives a finding when its line falls anywhere inside the flagged
        node's [lineno, end_lineno] span (multi-line calls included)."""
        lo = getattr(node, "lineno", 0)
        hi = getattr(node, "end_lineno", lo)
        for w in self.waivers:
            if w.covers(rule, lo, hi):
                return w
        return None

    def parent(self, node: ast.AST) -> Optional[ast.AST]:
        return self.parents.get(node)


def _parse_waivers(lines: List[str]) -> List[Waiver]:
    waivers = []
    for i, line in enumerate(lines, start=1):
        m = _PRAGMA.search(line)
        if not m:
            continue
        rules = tuple(r.strip() for r in m.group(1).split(",") if r.strip())
        # a comment-only pragma line covers the next code line (skipping
        # the rest of its own comment block and blank lines)
        covered = i
        if line.lstrip().startswith("#"):
            covered = i + 1
            while covered <= len(lines) and (
                    not lines[covered - 1].strip()
                    or lines[covered - 1].lstrip().startswith("#")):
                covered += 1
        waivers.append(Waiver(line=covered, rules=rules,
                              reason=m.group(2).strip()))
    return waivers


def parse_source(text: str, path) -> SourceModule:
    """Parse source text into a ``SourceModule`` (also the test seam:
    fixture snippets parse through the same path real files do)."""
    tree = ast.parse(text)
    parents: Dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    lines = text.splitlines()
    return SourceModule(path=Path(path), text=text, tree=tree, lines=lines,
                        parents=parents, waivers=_parse_waivers(lines))


def parse_module(path) -> SourceModule:
    path = Path(path)
    return parse_source(path.read_text(), path)


def dotted_name(node: ast.AST) -> Optional[str]:
    """'jnp.sum' for Attribute(Name('jnp'), 'sum'); None for anything
    that is not a pure Name/Attribute chain."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if not isinstance(node, ast.Name):
        return None
    parts.append(node.id)
    return ".".join(reversed(parts))


# ---------------------------------------------------------------------------
# Dotted-symbol resolution (the doc checker's pinning machinery)
# ---------------------------------------------------------------------------


def resolve_symbol(ref: str):
    """Resolve 'pkg.mod.attr.attr' to (object, import_cut) or None.

    Imports the longest importable module prefix, then walks attributes.
    ``import_cut`` is the dotted module path actually imported — the
    prefix the caller documented the symbol as living under.
    """
    parts = ref.split(".")
    for cut in range(len(parts), 0, -1):
        mod_path = ".".join(parts[:cut])
        try:
            obj = importlib.import_module(mod_path)
        except ImportError:
            continue
        try:
            for attr in parts[cut:]:
                obj = getattr(obj, attr)
        except AttributeError:
            return None
        return obj, mod_path
    return None


def symbol_resolves(ref: str) -> bool:
    return resolve_symbol(ref) is not None


def symbol_origin(ref: str) -> Optional[str]:
    """The defining module (``__module__``) of the resolved object, or
    None when it does not resolve / has no recorded origin."""
    hit = resolve_symbol(ref)
    if hit is None:
        return None
    obj, _ = hit
    return getattr(obj, "__module__", None) or getattr(obj, "__name__", None)


def symbol_origin_ok(ref: str) -> bool:
    """True when ``ref`` resolves AND its defining module lives under the
    documented prefix.

    This is the moved-module guard: ``repro.serve.engine.Engine`` keeps
    resolving through a stale package re-export even after ``Engine``
    migrates elsewhere — the old checker silently passed that.  Here the
    resolved object's ``__module__`` must share the documented parent
    package (``repro.serve...``), so a cross-package move fails the pin
    until the doc is updated.  Objects without a ``__module__``
    (arrays, ints) only need to resolve.
    """
    hit = resolve_symbol(ref)
    if hit is None:
        return False
    obj, cut = hit
    origin = getattr(obj, "__module__", None)
    if origin is None or origin == cut:
        return True
    # documented parent package: everything up to the symbol's module cut,
    # relaxed to the top two components (repro.serve, repro.reduce, ...)
    doc_pkg = ".".join(cut.split(".")[:2])
    return origin == cut or origin.startswith(doc_pkg)
