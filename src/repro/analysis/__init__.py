"""Static analysis of the determinism contract (see docs/determinism-lint.md).

Layer 1 (``repro.analysis.rules``): AST lint rules DET001–DET006 over
``src/repro`` — raw reductions outside the front door, unbarriered fold
loops, mode-less scatters, order-dependent PRNG in serving code,
registry hook drift, f32 count arithmetic.

Layer 2 (``repro.analysis.contracts``): jaxpr-level checks DET101–DET105
— trace every registered policy × backend × op on canonical shapes and
verify carry dtypes, integer-only ``merge_is_add`` carries, fold
barriers, and cross-block-size structural invariance.

CLI: ``python tools/detlint.py`` (``--check-waivers`` adds the waiver
ratchet CI enforces).
"""

from repro.analysis.walker import (  # noqa: F401
    SourceModule,
    iter_source_files,
    parse_module,
    parse_source,
    repo_root,
    resolve_symbol,
    symbol_origin,
    symbol_origin_ok,
    symbol_resolves,
)
from repro.analysis.rules import (  # noqa: F401
    ALL_RULE_IDS,
    AST_RULES,
    Finding,
    LintRule,
    TOLERATED_FLOAT_MERGE,
    check_registries,
    run_lint,
)

__all__ = [
    "ALL_RULE_IDS",
    "AST_RULES",
    "Finding",
    "LintRule",
    "SourceModule",
    "TOLERATED_FLOAT_MERGE",
    "check_registries",
    "iter_source_files",
    "parse_module",
    "parse_source",
    "repo_root",
    "resolve_symbol",
    "run_contracts",
    "symbol_origin",
    "symbol_origin_ok",
    "symbol_resolves",
    "run_lint",
]


def run_contracts(*args, **kwargs):
    """Lazy forwarder: ``repro.analysis.contracts`` imports jax and the
    live registries, which the pure-AST layer must not require."""
    from repro.analysis import contracts
    return contracts.run_contracts(*args, **kwargs)
