"""Determinism-contract AST lint rules (layer 1 of detlint).

Each rule encodes one way this codebase has actually lost (or nearly
lost) bitwise determinism:

DET001  raw ``jnp.sum/mean/cumsum`` / ``lax.psum`` (or ``.sum()``-style
        method reductions) in model/optim/train/serve/distributed code
        instead of the ``repro.reduce`` front door.  The front door is
        where policies, degrade handling, and the shard-merge contract
        live; a raw reduction silently opts out of all three.
DET002  Python-level float fold loops with no
        ``jax.lax.optimization_barrier`` in the body.  PR 8's tier-1
        catch: XLA fused two unrolled float folds into one reassociated
        add at S=1 — bitwise drift invisible at review time.
DET003  ``.at[...]`` scatter writes without an explicit ``mode=``.
        JAX's default drops out-of-bounds scatter indices *silently*
        (and negative indices wrap!); the mode must be a visible,
        reviewed decision at every write.
DET004  bare ``jax.random.split`` in per-request serving code.  Split
        chains depend on arrival order; the serving contract
        (docs/serving.md) requires order-free ``fold_in(seed, rid)``
        derivation.
DET005  registered ``Policy``/backend/``ReduceOp`` classes missing or
        mis-signaturing required hooks — checked against the *live*
        registries, so a hook rename that misses one policy fails here
        rather than deep inside a backend trace.
DET006  f32 count/index arithmetic: float32 represents integers exactly
        only up to 2^24, so counts accumulated in f32 saturate silently
        on large segments.

Waive a finding with ``# detlint: ok[DET00x] reason`` on (or above) the
offending line; ``tools/detlint.py --check-waivers`` ratchets the
per-rule waiver counts downward via ``tools/detlint_baseline.json``.
"""

from __future__ import annotations

import ast
import dataclasses
import re
from typing import Iterable, List, Optional, Sequence, Set

from repro.analysis import walker
from repro.analysis.walker import SourceModule, dotted_name

#: ``merge_is_add`` policies whose carry deliberately keeps float
#: leaves.  Entries here still count as waived findings in the ratchet
#: (rules DET005 here, DET102 in contracts) — the table is the pragma.
TOLERATED_FLOAT_MERGE = {
    "fast": ("documented-tolerance tier: psum of float partials is the "
             "policy's contract (docs/policies.md), not a determinism "
             "claim"),
}


@dataclasses.dataclass
class Finding:
    """One lint finding (waived or not)."""

    rule: str
    path: str
    line: int
    message: str
    waived: bool = False
    reason: str = ""

    def __str__(self) -> str:
        tag = " [waived]" if self.waived else ""
        return f"{self.path}:{self.line}: {self.rule}{tag} {self.message}"


def _in_dirs(mod: SourceModule, names: Set[str]) -> bool:
    return bool(set(mod.path.parts) & names)


class LintRule:
    """Base class: subclasses set ``rule``/``title`` and implement
    ``check(mod) -> iterable of (node, message)``."""

    rule = "DET000"
    title = ""

    def applies(self, mod: SourceModule) -> bool:
        return True

    def check(self, mod: SourceModule) -> Iterable:
        raise NotImplementedError

    def run(self, mod: SourceModule) -> List[Finding]:
        if not self.applies(mod):
            return []
        out = []
        for node, message in self.check(mod):
            w = mod.waiver_for(self.rule, node)
            out.append(Finding(rule=self.rule, path=mod.rel,
                               line=getattr(node, "lineno", 0),
                               message=message, waived=w is not None,
                               reason=w.reason if w else ""))
        return out


# ---------------------------------------------------------------------------
# DET001 — raw reductions outside the front door
# ---------------------------------------------------------------------------

#: layers that must route reductions through ``repro.reduce`` — the
#: front-door implementation itself (reduce/, kernels/, core/) is where
#: the raw primitives legitimately live.
_FRONT_DOOR_DIRS = {"models", "optim", "train", "serve", "distributed",
                    "launch", "data"}

_RAW_REDUCERS = {
    "jnp.sum", "jnp.mean", "jnp.cumsum", "jnp.nansum", "jnp.nanmean",
    "jax.numpy.sum", "jax.numpy.mean", "jax.numpy.cumsum",
    "lax.psum", "jax.lax.psum", "lax.pmean", "jax.lax.pmean",
}

_REDUCE_METHODS = {"sum", "mean", "cumsum"}
_MODULE_ROOTS = {"jnp", "jax", "lax", "np", "numpy", "math"}


class RawReduction(LintRule):
    rule = "DET001"
    title = "raw reduction outside the repro.reduce front door"

    def applies(self, mod: SourceModule) -> bool:
        return _in_dirs(mod, _FRONT_DOOR_DIRS)

    def check(self, mod: SourceModule):
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func)
            if name in _RAW_REDUCERS:
                yield node, (f"raw `{name}` — route through the "
                             f"repro.reduce front door (policy + degrade "
                             f"+ shard-merge contract), or waive with the "
                             f"reason it must stay raw")
            elif (name is None or name.split(".")[0] not in _MODULE_ROOTS) \
                    and isinstance(node.func, ast.Attribute) \
                    and node.func.attr in _REDUCE_METHODS:
                yield node, (f"method reduction `.{node.func.attr}()` — "
                             f"same contract as DET001 jnp.{node.func.attr}")


# ---------------------------------------------------------------------------
# DET002 — float fold loops without an optimization barrier
# ---------------------------------------------------------------------------

#: callee names that *are* fold steps when their result rebinds an input
_FOLD_CALLS = re.compile(r"(two_sum|wrap_add|limb_add|limb_merge|"
                         r"\bmerge\b|\bupdate\b)")

_JAX_ROOTS = {"jnp", "jax", "lax"}


def _contains_barrier(loop: ast.AST) -> bool:
    for n in ast.walk(loop):
        d = dotted_name(n) if isinstance(n, ast.Attribute) else None
        if d and d.endswith("optimization_barrier"):
            return True
    return False


_HOST_CASTS = {"float", "int", "len", "bool", "str"}


def _is_jaxish_expr(expr: ast.AST, jaxish_names: Set[str]) -> bool:
    """Heuristic: does this expression plausibly produce a traced array?
    True when it contains a call, a jnp/jax/lax-rooted attribute, or a
    name already known to hold a traced value."""
    # a top-level host cast (`t += float(...)`) produces a Python scalar:
    # whatever gets folded is host-side, not traced
    if isinstance(expr, ast.Call) and isinstance(expr.func, ast.Name) \
            and expr.func.id in _HOST_CASTS:
        return False
    for n in ast.walk(expr):
        if isinstance(n, ast.Call):
            return True
        if isinstance(n, ast.Attribute):
            d = dotted_name(n)
            if d and d.split(".")[0] in _JAX_ROOTS:
                return True
        if isinstance(n, ast.Name) and n.id in jaxish_names:
            return True
    return False


def _names_in(node: ast.AST) -> Set[str]:
    return {n.id for n in ast.walk(node) if isinstance(n, ast.Name)}


def _mentions_jax(scope: ast.AST) -> bool:
    for n in ast.walk(scope):
        if isinstance(n, ast.Attribute):
            d = dotted_name(n)
            if d and d.split(".")[0] in _JAX_ROOTS:
                return True
    return False


def _direct_stmts(loop: ast.AST):
    """Statements of ``loop`` excluding the interiors of nested loops
    (those are judged by their own loop's check)."""
    stack = list(ast.iter_child_nodes(loop))
    while stack:
        n = stack.pop()
        yield n
        if not isinstance(n, (ast.For, ast.While)):
            stack.extend(ast.iter_child_nodes(n))


def _direct_add_folds(value: ast.AST, x: str) -> bool:
    """True when ``value`` contains ``... x + e ...`` with ``x`` as a
    *direct* operand of the + (catches ``x = x + e`` and
    ``x = e if c else x + e``; skips host-int shapes like
    ``n = a.shape[0] + (1 if n % 2 else 0)``)."""
    for n in ast.walk(value):
        if isinstance(n, ast.BinOp) and isinstance(n.op, ast.Add):
            for side, other in ((n.left, n.right), (n.right, n.left)):
                if isinstance(side, ast.Name) and side.id == x:
                    return other
    return None


class UnbarrieredFoldLoop(LintRule):
    rule = "DET002"
    title = "float fold loop without optimization_barrier"

    def check(self, mod: SourceModule):
        for loop in ast.walk(mod.tree):
            if not isinstance(loop, (ast.For, ast.While)):
                continue
            if _contains_barrier(loop):
                continue
            # gate: the enclosing function (or module) must touch
            # jnp/jax/lax at all — loops in pure host code (param
            # counting, text parsing) never fold traced arrays
            scope = loop
            while scope in mod.parents and not isinstance(
                    scope, (ast.FunctionDef, ast.AsyncFunctionDef)):
                scope = mod.parents[scope]
            if not _mentions_jax(scope):
                continue
            folded = self._folded_names(loop)
            if folded:
                yield loop, (
                    f"{', '.join(f'`{n}`' for n in sorted(folded))} fold(s) "
                    f"accumulatively in a Python loop with no "
                    f"jax.lax.optimization_barrier — XLA may reassociate "
                    f"consecutive float adds across unrolled iterations "
                    f"(the PR 8 fusion bug)")

    def _folded_names(self, loop: ast.AST) -> Set[str]:
        # names bound inside the loop to plausibly-traced values: a fold
        # of such a name is a fold of array data, not of host ints
        jaxish: Set[str] = set()
        for stmt in ast.walk(loop):
            if isinstance(stmt, ast.Assign) and (
                    isinstance(stmt.value, ast.Call)
                    or _is_jaxish_expr(stmt.value, jaxish)):
                for t in stmt.targets:
                    jaxish |= _names_in(t)

        folded: Set[str] = set()
        for stmt in _direct_stmts(loop):
            # x = ... x + e ... (including `x = e if c else x + e`)
            if isinstance(stmt, ast.Assign) \
                    and len(stmt.targets) == 1 \
                    and isinstance(stmt.targets[0], ast.Name):
                x = stmt.targets[0].id
                other = _direct_add_folds(stmt.value, x)
                if other is not None and _is_jaxish_expr(other, jaxish):
                    folded.add(x)
                    continue
            # x += e (traced e only)
            if isinstance(stmt, ast.AugAssign) \
                    and isinstance(stmt.op, ast.Add) \
                    and isinstance(stmt.target, ast.Name) \
                    and _is_jaxish_expr(stmt.value, jaxish):
                folded.add(stmt.target.id)
            # x, err = two_sum(x, e) / carry = policy.update(carry, c)
            elif isinstance(stmt, ast.Assign) \
                    and isinstance(stmt.value, ast.Call):
                callee = dotted_name(stmt.value.func) or ""
                if not _FOLD_CALLS.search(callee):
                    continue
                tgt_names: Set[str] = set()
                for t in stmt.targets:
                    tgt_names |= _names_in(t)
                arg_names: Set[str] = set()
                for a in stmt.value.args:
                    arg_names |= _names_in(a)
                folded |= tgt_names & arg_names
        return folded


# ---------------------------------------------------------------------------
# DET003 — scatter writes without explicit mode=
# ---------------------------------------------------------------------------

_SCATTER_METHODS = {"set", "add", "subtract", "multiply", "mul", "divide",
                    "div", "power", "min", "max", "apply", "get"}


class ModelessScatter(LintRule):
    rule = "DET003"
    title = ".at[...] write without explicit mode="

    def check(self, mod: SourceModule):
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if not (isinstance(f, ast.Attribute)
                    and f.attr in _SCATTER_METHODS
                    and isinstance(f.value, ast.Subscript)
                    and isinstance(f.value.value, ast.Attribute)
                    and f.value.value.attr == "at"):
                continue
            if any(kw.arg == "mode" for kw in node.keywords):
                continue
            yield node, (f"`.at[...].{f.attr}()` without explicit mode= — "
                         f"the default silently drops OOB indices and "
                         f"*wraps negative ones*; state the intended "
                         f"behavior (mode=\"drop\" is bitwise-identical "
                         f"for in-range indices)")


# ---------------------------------------------------------------------------
# DET004 — order-dependent PRNG derivation in serving code
# ---------------------------------------------------------------------------


class SplitInServe(LintRule):
    rule = "DET004"
    title = "jax.random.split in per-request code"

    def applies(self, mod: SourceModule) -> bool:
        return _in_dirs(mod, {"serve"})

    def check(self, mod: SourceModule):
        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted_name(node.func) or ""
            parts = d.split(".")
            if parts[-1] == "split" and \
                    any("random" in p or p in ("jr", "jrandom")
                        for p in parts[:-1]):
                yield node, ("`random.split` chains depend on request "
                             "arrival order — derive per-request keys "
                             "with fold_in(seed, rid, step) "
                             "(docs/serving.md PRNG contract)")


# ---------------------------------------------------------------------------
# DET006 — f32 count/index arithmetic (exact only to 2^24)
# ---------------------------------------------------------------------------

_FLOAT_DTYPES = {"jnp.float32", "jnp.float64", "jnp.bfloat16",
                 "jax.numpy.float32", "np.float32"}


def _is_float_dtype_expr(node: Optional[ast.AST]) -> bool:
    if node is None:
        return False
    d = dotted_name(node)
    if d in _FLOAT_DTYPES:
        return True
    return isinstance(node, ast.Constant) and \
        isinstance(node.value, str) and "float" in node.value


def _is_float_ones(node: ast.AST) -> bool:
    """``jnp.ones(..., jnp.float32)`` / ``jnp.ones_like(x, jnp.float32)``
    — a count vector built in float."""
    if not isinstance(node, ast.Call):
        return False
    d = dotted_name(node.func) or ""
    if d.split(".")[-1] not in ("ones", "ones_like", "full", "full_like"):
        return False
    dtype_args = [kw.value for kw in node.keywords if kw.arg == "dtype"]
    dtype_args += node.args[1:]
    return any(_is_float_dtype_expr(a) for a in dtype_args)


class FloatCountArithmetic(LintRule):
    rule = "DET006"
    title = "f32 count/index arithmetic (exact only to 2^24)"

    def check(self, mod: SourceModule):
        # names bound (anywhere in the module) to float-ones vectors
        float_ones_names: Set[str] = set()
        for node in ast.walk(mod.tree):
            if isinstance(node, ast.Assign) and _is_float_ones(node.value):
                for t in node.targets:
                    float_ones_names |= _names_in(t)

        for node in ast.walk(mod.tree):
            if not isinstance(node, ast.Call):
                continue
            callee = dotted_name(node.func) or ""
            tail = callee.split(".")[-1]
            # (a) float ones-vector fed to a *sum*/count accumulator
            if "sum" in tail or "count" in tail:
                for a in node.args:
                    if _is_float_ones(a) or (isinstance(a, ast.Name)
                                             and a.id in float_ones_names):
                        yield node, ("counting in f32: a float ones-vector "
                                     "accumulated by a sum saturates at "
                                     "2^24 (f32 integer grid); count in "
                                     "int32/int64 and cast after")
            # (b) psum of a float 1.0 — device counting in float
            if tail in ("psum", "pmean") and node.args:
                a0 = node.args[0]
                if (isinstance(a0, ast.Constant)
                        and isinstance(a0.value, float)) or \
                        (isinstance(a0, ast.Call)
                         and _is_float_dtype_expr(a0.func)):
                    yield node, ("device-counting via psum of a float "
                                 "constant — exact only to 2^24; psum an "
                                 "int and cast after")
            # (c) index grids materialized in float
            if tail in ("arange", "iota", "broadcasted_iota"):
                dtype_args = [kw.value for kw in node.keywords
                              if kw.arg == "dtype"]
                if tail == "arange":
                    dtype_args += node.args[3:]
                else:
                    dtype_args += node.args[:1]
                if any(_is_float_dtype_expr(a) for a in dtype_args):
                    yield node, ("index grid materialized in float — "
                                 "positions past 2^24 collide on the f32 "
                                 "integer grid; build indices in int and "
                                 "cast at the use site")


# ---------------------------------------------------------------------------
# DET005 — registry hook contract (reflection over the live registries)
# ---------------------------------------------------------------------------

_POLICY_HOOKS = {
    # hook -> (min positional args after self, required kwargs)
    "prepare_ctx": (2, ()),
    "to_domain": (2, ()),
    "prepare": (1, ()),
    "contrib": (2, ()),
    "contrib_lanes": (3, ("seg_offset", "lanes")),
    "init": (2, ()),
    "update": (2, ()),
    "merge": (2, ()),
    "merge_across": (2, ()),
    "carry_status": (1, ()),
    "finalize": (2, ()),
    "stage_costs": (1, ()),
    "domain_width": (1, ()),
}

_BACKEND_RUN_KWARGS = ("policy", "block_size", "interpret")


def _sig_accepts(fn, *, min_pos: int = 0,
                 kwargs: Sequence[str] = ()) -> Optional[str]:
    """None when ``fn``'s signature can take ``min_pos`` positional args
    and every kwarg in ``kwargs``; else a human-readable deficit."""
    import inspect
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return "signature not introspectable"
    params = list(sig.parameters.values())
    has_var_pos = any(p.kind is p.VAR_POSITIONAL for p in params)
    has_var_kw = any(p.kind is p.VAR_KEYWORD for p in params)
    n_pos = sum(p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD)
                for p in params)
    if n_pos < min_pos and not has_var_pos:
        return f"takes {n_pos} positional args, needs {min_pos}"
    names = {p.name for p in params}
    missing = [k for k in kwargs if k not in names and not has_var_kw]
    if missing:
        return f"missing keyword(s) {missing}"
    return None


def _def_site(obj):
    """(relpath, lineno) of a class/object definition, best-effort."""
    import inspect
    try:
        cls = obj if isinstance(obj, type) else type(obj)
        path = inspect.getsourcefile(cls)
        _, line = inspect.getsourcelines(cls)
        rel = str(walker.Path(path).resolve().relative_to(walker.repo_root()))
        return rel, line
    except (OSError, TypeError, ValueError):
        return "<registry>", 0


def check_registries() -> List[Finding]:
    """DET005: every registered policy/backend/op satisfies the hook
    contract its registry promises callers.  Runs against the *live*
    registries so a class registered from anywhere is held to the bar."""
    out: List[Finding] = []

    def finding(obj, msg, *, waived=False, reason=""):
        rel, line = _def_site(obj)
        out.append(Finding(rule="DET005", path=rel, line=line, message=msg,
                           waived=waived, reason=reason))

    try:
        import jax.numpy as jnp
        from repro.reduce.policy import POLICIES
        from repro.reduce.backends import BACKENDS
        from repro.reduce.algebra import REDUCE_OPS
    except Exception as e:    # loud, unwaivable: the checker itself broke
        out.append(Finding(rule="DET005", path="<registry>", line=0,
                           message=f"registry reflection failed to load: "
                                   f"{type(e).__name__}: {e}"))
        return out

    for name, p in sorted(POLICIES.items()):
        if getattr(p, "name", None) != name:
            finding(p, f"policy registered as {name!r} but .name is "
                       f"{getattr(p, 'name', None)!r}")
        for hook, (min_pos, kwargs) in _POLICY_HOOKS.items():
            fn = getattr(p, hook, None)
            if not callable(fn):
                finding(p, f"policy {name!r} missing required hook "
                           f"`{hook}`")
                continue
            deficit = _sig_accepts(fn, min_pos=min_pos, kwargs=kwargs)
            if deficit:
                finding(p, f"policy {name!r} hook `{hook}`: {deficit}")
        dts = getattr(p, "carry_dtypes", None)
        clen = getattr(p, "carry_len", None)
        if dts is None or clen is None or len(tuple(dts)) != clen:
            finding(p, f"policy {name!r}: len(carry_dtypes)="
                       f"{None if dts is None else len(tuple(dts))} != "
                       f"carry_len={clen}")
        elif getattr(p, "merge_is_add", False) and \
                not all(jnp.issubdtype(jnp.dtype(d), jnp.integer)
                        for d in dts):
            tol = TOLERATED_FLOAT_MERGE.get(name)
            finding(p, f"policy {name!r}: merge_is_add with non-integer "
                       f"carry leaves {tuple(str(jnp.dtype(d)) for d in dts)}"
                       f" — psum of floats is order-sensitive",
                    waived=tol is not None, reason=tol or "")

    for name, b in sorted(BACKENDS.items()):
        if b.name != name:
            finding(b, f"backend registered as {name!r} but .name is "
                       f"{b.name!r}")
        kwargs = list(_BACKEND_RUN_KWARGS)
        if getattr(b, "staged", False):
            kwargs.append("program")
        if getattr(b, "distributed", False):
            kwargs += ["mesh", "axis_names"]
        deficit = _sig_accepts(b.run, min_pos=3, kwargs=kwargs)
        if deficit:
            finding(b.run, f"backend {name!r} run(): {deficit}")

    for name, op in sorted(REDUCE_OPS.items()):
        if getattr(op, "name", None) != name:
            finding(op, f"op registered as {name!r} but .name is "
                       f"{getattr(op, 'name', None)!r}")
        for hook, spec in (("pre", (1, ("weights", "coeffs"))),
                           ("post", (2, ()))):
            fn = getattr(op, hook, None)
            if not callable(fn):
                finding(op, f"op {name!r} missing required hook `{hook}`")
                continue
            deficit = _sig_accepts(fn, min_pos=spec[0], kwargs=spec[1])
            if deficit:
                finding(op, f"op {name!r} hook `{hook}`: {deficit}")
        comps = getattr(op, "components", None)
        if not isinstance(comps, int) or comps < 1:
            finding(op, f"op {name!r}: components must be a positive int, "
                       f"got {comps!r}")
        for req, takes in (("requires_weights", "takes_weights"),
                           ("requires_coeffs", "takes_coeffs")):
            if getattr(op, req, False) and not getattr(op, takes, False):
                finding(op, f"op {name!r}: {req} without {takes}")

    # apply source-level pragmas to reflection findings too
    cache = {}
    for f in out:
        if f.waived or f.path == "<registry>":
            continue
        p = walker.repo_root() / f.path
        if p not in cache and p.exists():
            cache[p] = walker.parse_module(p)
        mod = cache.get(p)
        if mod is None:
            continue
        node = ast.Module(body=[], type_ignores=[])
        node.lineno = f.line
        node.end_lineno = f.line
        w = mod.waiver_for("DET005", node)
        if w is not None:
            f.waived, f.reason = True, w.reason
    return out


# ---------------------------------------------------------------------------
# runner
# ---------------------------------------------------------------------------

AST_RULES: List[LintRule] = [
    RawReduction(),
    UnbarrieredFoldLoop(),
    ModelessScatter(),
    SplitInServe(),
    FloatCountArithmetic(),
]

ALL_RULE_IDS = tuple(sorted({r.rule for r in AST_RULES} | {"DET005"}))


def run_lint(files: Sequence, *, rules: Optional[Set[str]] = None,
             registry: bool = True) -> List[Finding]:
    """Lint ``files`` (paths) with every AST rule, plus the registry
    reflection rule (DET005) unless ``registry=False``.  ``rules``
    filters to a subset of rule ids."""
    findings: List[Finding] = []
    for path in files:
        mod = walker.parse_module(path)
        for rule in AST_RULES:
            if rules and rule.rule not in rules:
                continue
            findings.extend(rule.run(mod))
    if registry and (not rules or "DET005" in rules):
        findings.extend(check_registries())
    findings.sort(key=lambda f: (f.path, f.line, f.rule))
    return findings
