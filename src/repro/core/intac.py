"""INTAC on TPU: exact accumulation in an integer (carry-save-like) domain.

The circuit's insight — *accumulate in a redundant/exact representation with
a tiny per-step critical path, and pay for the expensive normalization only
once per set* — maps onto TPU as fixed-point accumulation:

  * per-element work: quantize fp32 -> int32 (cheap, VPU) and integer-add
    (exact, associative — the carry-save analogue);
  * the "final addition" (limb carry-resolve + dequantize back to float)
    happens once per segment / step / all-reduce, amortized exactly like the
    resource-shared final adder in Fig. 5.

Because integer addition is associative, the accumulation result is
**bitwise independent of reduction order** — blocks, devices, pods — which is
the TPU answer to the paper's FP non-associativity problem, and the basis of:

  * ``intac_sum``           — exact, deterministic sum of an fp32 array;
  * ``LimbAccumulator``     — two-limb int32 carry-save accumulator (wider
                              dynamic range, deferred carries; the closest
                              software analogue of (sum, carry) feedback);
  * ``intac_psum``          — deterministic cross-device reduction;
  * ``CompressedAllReduce`` — int8/int16-quantized gradient all-reduce with
                              error feedback (the distributed-optimization
                              use of the same primitive).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

# int32 headroom: values quantized to <= 2^QBITS-1 in magnitude can be
# accumulated 2^(31-QBITS) times with no overflow.
_I32_BITS = 31


def choose_scale(max_abs: jnp.ndarray, num_terms: int,
                 qbits: int = 30) -> jnp.ndarray:
    """Power-of-two scale s.t. n * |x|_max * scale < 2^qbits.

    A power of two makes quantization error-free for values already
    representable at the target precision, mirroring the paper's
    "specific accuracy range" argument for fixed point.
    """
    # Work in log space: forming 2^qbits / (n * max_abs) directly overflows
    # f32 to inf for tiny-magnitude streams, and the old 1e-30 floor made
    # their scale so coarse that every value quantized to 0.  Floor at the
    # smallest normal (values below it are flushed by the hardware anyway)
    # and clamp e to the f32 exponent range so the scale stays finite.
    max_abs = jnp.maximum(max_abs, jnp.float32(2.0 ** -126))
    e = jnp.floor(jnp.float32(qbits) - jnp.log2(jnp.float32(num_terms))
                  - jnp.log2(max_abs)).astype(jnp.int32)
    # ldexp(1, e) is an exact power of two; exp2(float) is approximated on
    # some backends (observed 2^26 + 64 on XLA CPU) which breaks exactness.
    return jnp.ldexp(jnp.float32(1.0), jnp.clip(e, -126, 127))


def quantize(x: jnp.ndarray, scale) -> jnp.ndarray:
    return jnp.round(x * scale).astype(jnp.int32)


def dequantize(q: jnp.ndarray, scale) -> jnp.ndarray:
    """Descale by ``scale``; exact two-step ldexp for powers of two.

    In-repo scales all come from ``choose_scale`` (powers of two): for
    those, two half-exponent ldexp steps replace the division — XLA may
    lower x/s as x*(1/s), and for near-clamp scales (e≈127) the
    reciprocal (or a single-step 2^-e) is subnormal and flushes to zero
    on CPU; halving the exponent keeps every factor normal and exact.
    Arbitrary external scales fall back to plain division."""
    scale = jnp.asarray(scale, jnp.float32)
    qf = q.astype(jnp.float32)
    e = jnp.round(jnp.log2(jnp.maximum(scale, jnp.float32(1e-45)))) \
        .astype(jnp.int32)
    half = e // 2
    exact = jnp.ldexp(jnp.ldexp(qf, -half), -(e - half))
    is_pow2 = jnp.ldexp(jnp.float32(1.0), e) == scale
    return jnp.where(is_pow2, exact, qf / scale)


@partial(jax.jit, static_argnames=("axis",))
def intac_sum(x: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """Exact-within-quantization, order-independent sum along ``axis``.

    Two passes (max, then accumulate) — the first pass plays the role of the
    paper's a-priori bit-width parameterization.
    """
    n = x.shape[axis]
    max_abs = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = choose_scale(jnp.max(max_abs), n)
    q = quantize(x, scale)
    return dequantize(jnp.sum(q, axis=axis), scale)


class LimbState(NamedTuple):
    """Two-limb redundant accumulator — the (sum, carry) pair of Fig. 4.

    value represented = (hi * 2^15 + lo) / scale.  Each limb holds partial
    sums < 2^15 in magnitude per term, so 2^16 terms accumulate with no
    overflow and no cross-limb carries until ``finalize`` — deferred carry
    resolution, exactly the carry-save contract.
    """
    hi: jnp.ndarray   # int32
    lo: jnp.ndarray   # int32
    scale: jnp.ndarray


LIMB_SHIFT = 15


def limb_init(shape, scale) -> LimbState:
    z = jnp.zeros(shape, jnp.int32)
    return LimbState(z, z, jnp.asarray(scale, jnp.float32))


def limb_add(state: LimbState, x: jnp.ndarray) -> LimbState:
    """Accumulate one fp32 operand (the 3:2 compressor step)."""
    q = jnp.round(x * state.scale)
    hi = jnp.floor(q / (1 << LIMB_SHIFT))
    lo = q - hi * (1 << LIMB_SHIFT)          # in [0, 2^15)
    return LimbState(state.hi + hi.astype(jnp.int32),
                     state.lo + lo.astype(jnp.int32), state.scale)


def limb_finalize(state: LimbState) -> jnp.ndarray:
    """The once-per-set final addition (resource-shared adder analogue).

    The only floating-point rounding in the whole accumulation happens here.
    """
    return (state.hi.astype(jnp.float32) * (1 << LIMB_SHIFT)
            + state.lo.astype(jnp.float32)) / state.scale


def limb_merge(a: LimbState, b: LimbState) -> LimbState:
    """Merging two redundant accumulators is itself exact/associative."""
    return LimbState(a.hi + b.hi, a.lo + b.lo, a.scale)


# ---------------------------------------------------------------------------
# Distributed reductions
# ---------------------------------------------------------------------------


def intac_psum(x: jnp.ndarray, axis_name, *, qbits: int = 30,
               nterms: Optional[int] = None) -> jnp.ndarray:
    """Bitwise-deterministic cross-device sum (shard_map collective).

    All devices agree on a power-of-two scale (via a max-reduce), quantize,
    integer-psum (associative => any reduction topology gives the same bits),
    dequantize once.  Works across 'data', ('data','pod'), etc.
    """
    n = nterms or jax.lax.psum(1, axis_name)
    gmax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    scale = choose_scale(gmax, n, qbits)
    q = quantize(x, scale)
    return dequantize(jax.lax.psum(q, axis_name), scale)


class EFState(NamedTuple):
    """Error-feedback residual for compressed gradient all-reduce."""
    residual: jnp.ndarray


def compressed_psum_mean(x: jnp.ndarray, residual: jnp.ndarray, axis_name,
                         *, bits: int = 8) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """INTAC-style compressed gradient all-reduce with error feedback.

    1. add the residual carried from the previous step (error feedback);
    2. agree on a shared power-of-two scale targeting ``bits``-bit payloads;
    3. quantize -> int, psum in the exact integer domain, dequantize once;
    4. the local quantization error becomes the next residual.

    Communication payload is ``bits``/32 of fp32 (int8 => 4x compression);
    the integer psum keeps the *reduction* exact and deterministic, so the
    only loss is the explicit, error-fed-back quantization.
    Returns (mean gradient, new residual).
    """
    xr = x + residual
    n = jax.lax.psum(1, axis_name)
    gmax = jax.lax.pmax(jnp.max(jnp.abs(xr)), axis_name)
    # payload must fit `bits` signed bits; headroom for the n-way sum lives
    # in the int32 accumulator, not the payload.
    scale = choose_scale(gmax, 1, qbits=bits - 1)
    q = quantize(xr, scale)
    new_residual = xr - dequantize(q, scale)
    total = jax.lax.psum(q, axis_name)          # int32 accumulate (exact)
    mean = dequantize(total, scale) / n
    return mean, new_residual


def compressed_psum_mean_tree(grads, residuals, axis_name, *, bits: int = 8):
    """Pytree version of ``compressed_psum_mean``."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residuals)
    out, res = [], []
    for g, r in zip(flat_g, flat_r):
        m, nr = compressed_psum_mean(g, r, axis_name, bits=bits)
        out.append(m)
        res.append(nr)
    return tdef.unflatten(out), tdef.unflatten(res)


def zeros_like_residuals(grads):
    return jax.tree.map(jnp.zeros_like, grads)
