"""INTAC on TPU: exact accumulation in an integer (carry-save-like) domain.

The circuit's insight — *accumulate in a redundant/exact representation with
a tiny per-step critical path, and pay for the expensive normalization only
once per set* — maps onto TPU as fixed-point accumulation:

  * per-element work: quantize fp32 -> int32 (cheap, VPU) and integer-add
    (exact, associative — the carry-save analogue);
  * the "final addition" (limb carry-resolve + dequantize back to float)
    happens once per segment / step / all-reduce, amortized exactly like the
    resource-shared final adder in Fig. 5.

Because integer addition is associative, the accumulation result is
**bitwise independent of reduction order** — blocks, devices, pods — which is
the TPU answer to the paper's FP non-associativity problem, and the basis of:

  * ``intac_sum``           — exact, deterministic sum of an fp32 array;
  * ``LimbAccumulator``     — two-limb int32 carry-save accumulator (wider
                              dynamic range, deferred carries; the closest
                              software analogue of (sum, carry) feedback);
  * ``limb_split3`` et al.  — the three-limb path: the exactly-captured
                              quantization residual rides along as a
                              compensated f32 limb, so "exact" holds for
                              arbitrary f32 inputs, not just values on the
                              scale's dyadic grid;
  * ``bin_split/combine``   — exponent-indexed "procrastination" bins
                              (Liguori/Neal): per-element exact digit
                              split, all rounding deferred to one combine;
  * ``intac_psum``          — deterministic cross-device reduction (plus
                              ``intac_psum2`` / ``intac_psum3`` /
                              ``bin_psum``, the two-limb, residual-carrying
                              three-limb, and per-bin variants whose
                              resolution does not shrink with the device
                              count);
  * ``CompressedAllReduce`` — int8/int16-quantized gradient all-reduce with
                              error feedback (the distributed-optimization
                              use of the same primitive).
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

# int32 headroom: values quantized to <= 2^QBITS-1 in magnitude can be
# accumulated 2^(31-QBITS) times with no overflow.
_I32_BITS = 31


def two_sum(a: jnp.ndarray, b: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Knuth two-sum: s = fl(a+b) and the exact rounding error e.

    a + b == s + e exactly, with no magnitude precondition.  Every caller
    (the compensated policy, the bin-combine finalize) must execute these
    six ops in this order — the error term is the whole point, so the
    expression must never be algebraically simplified.
    """
    s = a + b
    bp = s - a
    e = (a - (s - bp)) + (b - bp)
    return s, e


def _ldexp2(x: jnp.ndarray, e) -> jnp.ndarray:
    """x * 2^e in two half-exponent ldexp steps.

    A single step materializes 2^e, which over/underflows f32 for |e| near
    the exponent-range edges even when the *product* is representable;
    halving keeps every intermediate factor finite.
    """
    e = jnp.asarray(e, jnp.int32)
    h = e // 2
    return jnp.ldexp(jnp.ldexp(x, h), e - h)


def choose_scale(max_abs: jnp.ndarray, num_terms: int,
                 qbits: int = 30) -> jnp.ndarray:
    """Power-of-two scale s.t. n * |x|_max * scale < 2^qbits.

    A power of two makes quantization error-free for values already
    representable at the target precision, mirroring the paper's
    "specific accuracy range" argument for fixed point.
    """
    # Work in log space: forming 2^qbits / (n * max_abs) directly overflows
    # f32 to inf for tiny-magnitude streams, and the old 1e-30 floor made
    # their scale so coarse that every value quantized to 0.  Floor at the
    # smallest normal (values below it are flushed by the hardware anyway)
    # and clamp e to the f32 exponent range so the scale stays finite.
    max_abs = jnp.asarray(max_abs, jnp.float32)
    floored = jnp.maximum(max_abs, jnp.float32(2.0 ** -126))
    e = jnp.floor(jnp.float32(qbits) - jnp.log2(jnp.float32(num_terms))
                  - jnp.log2(floored)).astype(jnp.int32)
    # An all-zero (or all-padding) stream has max_abs == 0 — there is
    # nothing to represent, so any scale is "correct", but the clamped
    # near-2^127 scale the floor would produce is a footgun for any later
    # nonzero use (instant overflow) and NaN statistics would poison e
    # outright.  Pin the degenerate case to the benign unit scale.
    e = jnp.where(max_abs > 0, e, jnp.int32(0))
    # ldexp(1, e) is an exact power of two; exp2(float) is approximated on
    # some backends (observed 2^26 + 64 on XLA CPU) which breaks exactness.
    return jnp.ldexp(jnp.float32(1.0), jnp.clip(e, -126, 127))


def quantize(x: jnp.ndarray, scale) -> jnp.ndarray:
    return jnp.round(x * scale).astype(jnp.int32)


def wrap_add(a: jnp.ndarray, b: jnp.ndarray) -> Tuple[jnp.ndarray,
                                                      jnp.ndarray]:
    """int32 add plus an exact wraparound predicate: (a + b, wrapped).

    Two's-complement overflow happens iff both operands share a sign and
    the sum does not: ``((a ^ s) & (b ^ s)) < 0`` checks exactly that with
    three cheap bitwise ops — jittable, branch-free, and free to fuse into
    the accumulation it guards.  This is the guard-rail primitive of the
    integer tiers: every carry update that could saturate threads its
    wrap flags into an overflow counter, so a result whose canonical
    integer total wrapped is *detected* (``ReduceStatus.saturated``)
    instead of silently wrong.
    """
    a = a.astype(jnp.int32)
    b = b.astype(jnp.int32)
    s = a + b
    return s, ((a ^ s) & (b ^ s)) < 0


def descale(xf: jnp.ndarray, scale) -> jnp.ndarray:
    """Divide an f32 value by ``scale``; exact two-step ldexp for powers
    of two.

    In-repo scales all come from ``choose_scale`` (powers of two): for
    those, two half-exponent ldexp steps replace the division — XLA may
    lower x/s as x*(1/s), and for near-clamp scales (e≈127) the
    reciprocal (or a single-step 2^-e) is subnormal and flushes to zero
    on CPU; halving the exponent keeps every factor normal and exact.
    Arbitrary external scales fall back to plain division."""
    scale = jnp.asarray(scale, jnp.float32)
    xf = xf.astype(jnp.float32)
    e = jnp.round(jnp.log2(jnp.maximum(scale, jnp.float32(1e-45)))) \
        .astype(jnp.int32)
    exact = _ldexp2(xf, -e)
    is_pow2 = jnp.ldexp(jnp.float32(1.0), e) == scale
    return jnp.where(is_pow2, exact, xf / scale)


def dequantize(q: jnp.ndarray, scale) -> jnp.ndarray:
    return descale(q.astype(jnp.float32), scale)


@partial(jax.jit, static_argnames=("axis",))
def intac_sum(x: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """Exact-within-quantization, order-independent sum along ``axis``.

    Two passes (max, then accumulate) — the first pass plays the role of the
    paper's a-priori bit-width parameterization.
    """
    n = x.shape[axis]
    max_abs = jnp.max(jnp.abs(x), axis=axis, keepdims=True)
    scale = choose_scale(jnp.max(max_abs), n)
    q = quantize(x, scale)
    return dequantize(jnp.sum(q, axis=axis), scale)


class LimbState(NamedTuple):
    """Two-limb redundant accumulator — the (sum, carry) pair of Fig. 4.

    value represented = (hi * 2^15 + lo) / scale.  Each limb holds partial
    sums < 2^15 in magnitude per term, so 2^16 terms accumulate with no
    overflow and no cross-limb carries until ``finalize`` — deferred carry
    resolution, exactly the carry-save contract.
    """
    hi: jnp.ndarray   # int32
    lo: jnp.ndarray   # int32
    scale: jnp.ndarray


LIMB_SHIFT = 15


def limb_init(shape, scale) -> LimbState:
    z = jnp.zeros(shape, jnp.int32)
    return LimbState(z, z, jnp.asarray(scale, jnp.float32))


def limb_split(q: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Split an int32 value into (hi, lo) limbs with pure integer ops.

    q == hi * 2^LIMB_SHIFT + lo with lo in [0, 2^LIMB_SHIFT) — the
    arithmetic right shift floors, so the identity holds for negatives
    too.  Integer shift/mask, never float divide: a float-domain split
    would round for quantities above the 24-bit mantissa, silently
    breaking the exact-within-quantization contract.
    """
    q = q.astype(jnp.int32)
    hi = jnp.right_shift(q, LIMB_SHIFT)
    lo = jnp.bitwise_and(q, (1 << LIMB_SHIFT) - 1)
    return hi, lo


def limbs_canonical(hi: jnp.ndarray,
                    lo: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Canonicalize an (hi, lo) int32 limb pair in the integer domain.

    lo's bits above ``LIMB_SHIFT`` carry into hi, leaving the unique
    Euclidean pair with lo in [0, 2^LIMB_SHIFT).  The canonical pair is a
    pure function of the represented integer total ``hi * 2^15 + lo`` —
    *this* is the bitwise-invariant object of the limb tiers: raw carries
    depend on how the stream was blocked, the canonical pair does not.
    Tests and the shard_map guarantee compare limbs through here.
    """
    carry = jnp.right_shift(lo, LIMB_SHIFT)
    return hi + carry, jnp.bitwise_and(lo, (1 << LIMB_SHIFT) - 1)


def limb_add(state: LimbState, x: jnp.ndarray) -> LimbState:
    """Accumulate one fp32 operand (the 3:2 compressor step).

    Quantizes to int32 *first* and splits with integer shift/mask — the
    value must satisfy |x * scale| < 2^31 (the int32 contract).
    """
    hi, lo = limb_split(quantize(x, state.scale))
    return LimbState(state.hi + hi, state.lo + lo, state.scale)


def limbs_resolve(hi: jnp.ndarray, lo: jnp.ndarray, scale) -> jnp.ndarray:
    """Carry-resolve two int32 limbs and descale — the once-per-set final
    addition (resource-shared adder analogue).

    First canonicalizes in the integer domain (lo's bits above LIMB_SHIFT
    carry into hi, leaving the unique Euclidean pair with lo in
    [0, 2^LIMB_SHIFT)), so the f32 conversion of hi sees the same integer
    no matter how the stream was blocked — the result is bitwise
    independent of the limb decomposition.  The only floating-point
    rounding in the whole accumulation happens here.  ``lo`` must be
    non-negative (it is a sum of per-step remainders in [0, 2^15)).
    """
    hi, lo = limbs_canonical(hi, lo)
    total = jnp.ldexp(hi.astype(jnp.float32), LIMB_SHIFT) \
        + lo.astype(jnp.float32)
    return descale(total, scale)


def limb_finalize(state: LimbState) -> jnp.ndarray:
    return limbs_resolve(state.hi, state.lo, state.scale)


def limb_merge(a: LimbState, b: LimbState) -> LimbState:
    """Merging two redundant accumulators is itself exact/associative."""
    return LimbState(a.hi + b.hi, a.lo + b.lo, a.scale)


# ---------------------------------------------------------------------------
# Three-limb carry-save: the residual limb
# ---------------------------------------------------------------------------
#
# The two-limb path quantizes each value to the shared power-of-two grid
# and *discards* what the rounding dropped — exact only for inputs already
# on the grid.  The third limb keeps that drop: because the scale is a
# power of two, ``r = x - descale(quantize(x, scale), scale)`` is computed
# *exactly* in f32 (the classic Dekker-split argument: q/scale is x
# rounded to a coarser grid, the difference is a short-mantissa number and
# the subtraction is exact by Sterbenz), so (hi, lo, r) represents x with
# no information loss at all.  The integer limbs keep their associative /
# bitwise-order-independent contract; in the *streaming* accumulator the
# residual limb accumulates compensated-style (a two_sum-carried f32
# pair), which pins its error at the ~f64 level — tolerance, not bits,
# under re-ordering.  The block-schedule tier (``exact2``) goes further:
# per-element residuals split into integer digit bins
# (``RES_BIN_BITS``/``RES_NUM_BINS``) that accumulate associatively, so
# its finalize (``limbs_resolve3_binned``) is bitwise order/topology
# independent outright.  Either finalize is one carry-resolve +
# compensated combine, within 1 ulp of the f64 reference for arbitrary
# f32 streams.


class Limb3State(NamedTuple):
    """Three-limb redundant accumulator: (hi, lo) int32 carry-save limbs
    plus the compensated f32 residual pair (res, comp).

    value represented = (hi * 2^15 + lo) / scale + res + comp.

    ``ovf`` is the saturation guard rail: an int32 count of integer-limb
    wraparound events (``wrap_add``).  Nonzero means some limb overflowed
    and the canonical integer total is wrong — the state is *detectably*
    saturated rather than silently corrupt.  ``None`` (the pre-guard-rail
    default, kept for 5-field constructors) disables tracking.
    """
    hi: jnp.ndarray    # int32
    lo: jnp.ndarray    # int32
    res: jnp.ndarray   # f32: exactly-captured quantization residuals
    comp: jnp.ndarray  # f32: two_sum compensation of the residual limb
    scale: jnp.ndarray
    ovf: Optional[jnp.ndarray] = None   # int32 wrap-event count, or None


def limb3_init(shape, scale) -> Limb3State:
    z = jnp.zeros(shape, jnp.int32)
    r = jnp.zeros(shape, jnp.float32)
    return Limb3State(z, z, r, r, jnp.asarray(scale, jnp.float32), z)


def limb_split3(x: jnp.ndarray, scale) -> Tuple[jnp.ndarray, jnp.ndarray,
                                                jnp.ndarray]:
    """Split one f32 operand into (hi, lo, residual) — lossless.

    hi/lo are the integer limbs of ``quantize(x, scale)`` (pure shift/
    mask, see ``limb_split``); the residual is what quantization rounded
    away, computed exactly: scale is a power of two, so ``x * scale`` is
    exact, ``q / scale`` is x rounded to the grid, and the subtraction of
    two so-close values is exact (Sterbenz / Dekker).
    """
    x = jnp.asarray(x, jnp.float32)
    q = quantize(x, scale)
    hi, lo = limb_split(q)
    return hi, lo, x - dequantize(q, scale)


def limb_add3(state: Limb3State, x: jnp.ndarray) -> Limb3State:
    """Accumulate one fp32 operand losslessly (3:2 compressor + residual).

    Integer limbs add associatively; the residual folds through ``two_sum``
    so its rounding error is carried, not dropped.  Limb adds run through
    ``wrap_add``: a wrap at the int32 edge increments ``ovf`` in the same
    fused update, so saturation is detected exactly when the canonical
    integer total would be wrong (and never before — a carry landing *at*
    ``2^31 - 1`` is still correct and raises no flag).
    """
    hi, lo, r = limb_split3(x, state.scale)
    nhi, w1 = wrap_add(state.hi, hi)
    nlo, w2 = wrap_add(state.lo, lo)
    s, e = two_sum(state.res, r)
    ovf = state.ovf
    if ovf is not None:
        ovf = ovf + w1.astype(jnp.int32) + w2.astype(jnp.int32)
    return Limb3State(nhi, nlo, s, state.comp + e, state.scale, ovf)


def limb_merge3(a: Limb3State, b: Limb3State) -> Limb3State:
    """Merge two three-limb accumulators: integer limbs add exactly (any
    order, same bits); the residual pair merges through ``two_sum`` —
    deterministic for a pinned merge order, ulp-level drift otherwise.
    Wrap flags from the merge adds pool into ``ovf`` alongside both
    sides' prior counts, so saturation anywhere in a merge tree survives
    to ``finalize``."""
    nhi, w1 = wrap_add(a.hi, b.hi)
    nlo, w2 = wrap_add(a.lo, b.lo)
    s, e = two_sum(a.res, b.res)
    ovf = None
    if a.ovf is not None or b.ovf is not None:
        za = jnp.zeros_like(nhi)
        ovf = ((a.ovf if a.ovf is not None else za)
               + (b.ovf if b.ovf is not None else za)
               + w1.astype(jnp.int32) + w2.astype(jnp.int32))
    return Limb3State(nhi, nlo, s, a.comp + b.comp + e, a.scale, ovf)


def limbs_resolve3(hi: jnp.ndarray, lo: jnp.ndarray, res: jnp.ndarray,
                   scale, comp: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Carry-resolve the integer limbs, fold the residual limb back in,
    descale — the three-limb once-per-set final addition.

    The integer canonicalization (as in ``limbs_resolve``) makes the
    (hi, lo) pair a pure function of the accumulated integer total, so
    that part of the result is bitwise independent of blocking/ordering.
    The integer total is then *exactly* decomposed into f32-representable
    pieces (hi alone can exceed the 24-bit mantissa, so hi splits once
    more) and combined with the residual pair least-significant-first
    through compensated two_sums — the one rounding the caller sees is
    the final one, keeping the result within 1 ulp of the f64 reference.
    """
    hi, lo = limbs_canonical(hi, lo)
    # hi may need up to 31 bits: split into two exactly-convertible pieces
    _HSPLIT = 14
    hih = jnp.right_shift(hi, _HSPLIT)               # |hih| <= 2^17
    hil = jnp.bitwise_and(hi, (1 << _HSPLIT) - 1)    # in [0, 2^14)
    acc = res.astype(jnp.float32)
    cmp_ = (jnp.zeros_like(acc) if comp is None
            else comp.astype(jnp.float32))
    # detlint: ok[DET002] two_sum resolve chain: order pinned by data
    # dependence through acc; the final rounding is pinned by ulp tests
    for quanta, shift in ((lo, 0), (hil, LIMB_SHIFT),
                          (hih, LIMB_SHIFT + _HSPLIT)):
        term = descale(_ldexp2(quanta.astype(jnp.float32), shift), scale)
        acc, e = two_sum(acc, term)
        cmp_ = cmp_ + e
    return acc + cmp_


def limb3_finalize(state: Limb3State) -> jnp.ndarray:
    return limbs_resolve3(state.hi, state.lo, state.res, state.scale,
                          comp=state.comp)


# ---------------------------------------------------------------------------
# Exponent-indexed bins ("procrastination" accumulation)
# ---------------------------------------------------------------------------
#
# Liguori's procrastination accumulators (arXiv 2406.05866) and Neal's
# small superaccumulators (arXiv 1505.05571), int32 edition: an f32 value
# is split — exactly, by Dekker-style extraction — into BIN_BITS-wide
# signed digits of a fixed-point window anchored at the stream's maximum
# exponent.  Each digit lands in its own int32 bin; bins add with pure
# (associative) integer arithmetic, so the accumulation is bitwise
# order-independent, and all rounding procrastinates to one carry-resolve
# + compensated combine in ``bin_combine``.
#
# Window: NUM_BINS * BIN_BITS = 48 fractional bits below the max
# exponent, so any value within 2^(48-24) = 2^24 of the maximum splits
# exactly (full f32 mantissa preserved); smaller values round once, per
# element, at the 2^-48 quantum — order-independent, and below 1 ulp of
# the sum whenever the sum itself stays within ~2^24 of the maximum.
# Under catastrophic cancellation the bound degrades to the absolute
# N * 2^-49-of-max truncation error, not a relative one.  Headroom:
# per-element
# digits are bounded by 2^BIN_BITS, so up to 2^(31-BIN_BITS-1) = 2^22
# terms accumulate per bin with no overflow, *independent of magnitude* —
# resolution no longer trades against stream length.

BIN_BITS = 8
NUM_BINS = 6
#: per-bin int32 headroom: max terms accumulated with no overflow
BIN_MAX_TERMS = 1 << (31 - BIN_BITS - 1)

#: the residual superaccumulator of the exact2 tier: the per-element
#: quantization residual (|r * scale| <= 1/2 — below one quantum) splits
#: into RES_NUM_BINS digits of RES_BIN_BITS bits anchored at the quantum
#: (e_ref = 0), a 49-bit window below the scale's grid.  Digits are <=
#: 2^(RES_BIN_BITS - 1) = 64 per element, so a 512-row block contributes
#: <= 2^15 per bin and 2^15 blocks stay within int32 — the same 2x-margin
#: headroom ledger as the integer limbs.  Truncation below the window is
#: <= 2^-50 of a quantum per element: with the exact2 scale (2^21 below
#: max|x|) that is max|x| * 2^-71 per element — far below 1 ulp of any
#: sum of up to 2^24 terms.
RES_BIN_BITS = 7
RES_NUM_BINS = 7


def bin_ref_exponent(max_abs) -> jnp.ndarray:
    """Window anchor: e with max_abs * 2^-e in [0.5, 1); 0 for all-zero.

    A pure function of the stream's maximum magnitude — permutation
    invariant, and shared across devices via a pmax for collectives.
    """
    m = jnp.maximum(jnp.asarray(max_abs, jnp.float32),
                    jnp.float32(2.0 ** -126))
    return jnp.frexp(m)[1].astype(jnp.int32)


def bin_split(x: jnp.ndarray, e_ref, *, bits: int = BIN_BITS,
              num: int = NUM_BINS) -> jnp.ndarray:
    """Split f32 values into (num, *x.shape) int32 exponent-bin digits.

    x == sum_k digits[k] * 2^(e_ref - (k+1)*bits) exactly for values
    within 2^24 of the window anchor; the residual below the window is
    dropped (see module comment).  Each extraction step is exact float
    arithmetic: s = v * 2^W is a power-of-two scaling, round(s) is an
    integer below 2^W, and s - round(s) is a multiple of ulp(s) — the
    classic Dekker split.  Defaults are the procrastinate tier's window;
    the exact2 residual superaccumulator uses ``bits=RES_BIN_BITS,
    num=RES_NUM_BINS`` anchored at its quantum.
    """
    v = _ldexp2(x.astype(jnp.float32), -jnp.asarray(e_ref, jnp.int32))
    radix = jnp.float32(1 << bits)
    digits = []
    for _ in range(num):
        s = v * radix
        d = jnp.round(s)
        v = s - d                         # exact: both multiples of ulp(s)
        digits.append(d.astype(jnp.int32))
    return jnp.stack(digits)


def _bin_carry_resolve(bins: jnp.ndarray, bits: int) -> list:
    """Canonicalize (num, ...) int32 digit bins in the integer domain.

    Each bin's digit beyond +-2^(bits-1) carries into the next-more-
    significant bin, leaving a representation that is a pure function of
    the accumulated total — the bin analogue of ``limbs_canonical``, and
    the reason binned results are bitwise blocking/order-independent.
    """
    num = bins.shape[0]
    resolved = [bins[k] for k in range(num)]
    half = 1 << (bits - 1)
    for k in range(num - 1, 0, -1):
        c = jnp.right_shift(resolved[k] + half, bits)
        resolved[k] = resolved[k] - (c << bits)
        resolved[k - 1] = resolved[k - 1] + c
    return resolved


def bin_combine(bins: jnp.ndarray, e_ref, *,
                bits: int = BIN_BITS) -> jnp.ndarray:
    """The deferred final addition: (num, ...) int32 bins -> f32.

    Integer carry-resolve first (``_bin_carry_resolve``), which makes the
    representation a canonical function of the accumulated total — so the
    f32 result is bitwise independent of how the stream was blocked or
    ordered.  The float combine then runs least-significant-first through
    the compensated two-sum, so the one rounding that reaches the caller
    is the final one.
    """
    e_ref = jnp.asarray(e_ref, jnp.int32)
    num = bins.shape[0]
    resolved = _bin_carry_resolve(bins, bits)
    acc = jnp.zeros(bins.shape[1:], jnp.float32)
    comp = jnp.zeros(bins.shape[1:], jnp.float32)
    # detlint: ok[DET002] two_sum resolve chain: order pinned by data
    # dependence through acc; the final rounding is pinned by ulp tests
    for k in range(num - 1, -1, -1):
        term = _ldexp2(resolved[k].astype(jnp.float32),
                       e_ref - (k + 1) * bits)
        acc, e = two_sum(acc, term)
        comp = comp + e
    return acc + comp


def limbs_resolve3_binned(hi: jnp.ndarray, lo: jnp.ndarray,
                          rbins: jnp.ndarray, scale, *,
                          bits: int = RES_BIN_BITS) -> jnp.ndarray:
    """Resolve (hi, lo) integer limbs plus a binned residual
    superaccumulator — the all-integer three-limb final addition.

    ``rbins`` is (num, ...) int32: sums of per-element residual digits
    (``bin_split(r * scale, 0, bits=RES_BIN_BITS, num=RES_NUM_BINS)``),
    each digit worth ``2^(-(k+1)*bits) / scale``.  Everything entering
    the float combine is a canonical integer (``limbs_canonical`` for the
    limbs, ``_bin_carry_resolve`` for the bins) — a pure function of the
    accumulated integer totals — so the finalized float is **bitwise**
    independent of blocking, ordering, backend, shard count, and mesh
    shape, with no order-pinned float fold left anywhere.  The combine
    runs least-significant-first (residual bins, then lo, then the split
    hi) through compensated two-sums: one rounding reaches the caller.
    """
    hi, lo = limbs_canonical(hi, lo)
    num = rbins.shape[0]
    resolved = _bin_carry_resolve(rbins, bits)
    # hi may need up to 31 bits: split into two exactly-convertible pieces
    _HSPLIT = 14
    hih = jnp.right_shift(hi, _HSPLIT)               # |hih| <= 2^17
    hil = jnp.bitwise_and(hi, (1 << _HSPLIT) - 1)    # in [0, 2^14)
    acc = jnp.zeros(hi.shape, jnp.float32)
    cmp_ = jnp.zeros(hi.shape, jnp.float32)
    terms = [(resolved[k], -(k + 1) * bits) for k in range(num - 1, -1, -1)]
    terms += [(lo, 0), (hil, LIMB_SHIFT), (hih, LIMB_SHIFT + _HSPLIT)]
    # detlint: ok[DET002] two_sum resolve chain: order pinned by data
    # dependence through acc; the final rounding is pinned by ulp tests
    for quanta, shift in terms:
        term = descale(_ldexp2(quanta.astype(jnp.float32), shift), scale)
        acc, e = two_sum(acc, term)
        cmp_ = cmp_ + e
    return acc + cmp_


# ---------------------------------------------------------------------------
# Distributed reductions
# ---------------------------------------------------------------------------


def intac_psum(x: jnp.ndarray, axis_name, *, qbits: int = 30,
               nterms: Optional[int] = None) -> jnp.ndarray:
    """Bitwise-deterministic cross-device sum (shard_map collective).

    All devices agree on a power-of-two scale (via a max-reduce), quantize,
    integer-psum (associative => any reduction topology gives the same bits),
    dequantize once.  Works across 'data', ('data','pod'), etc.
    """
    n = nterms or jax.lax.psum(1, axis_name)
    gmax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    scale = choose_scale(gmax, n, qbits)
    q = quantize(x, scale)
    return dequantize(jax.lax.psum(q, axis_name), scale)


def intac_psum2(x: jnp.ndarray, axis_name, *, qbits: int = 30) -> jnp.ndarray:
    """Two-limb exact cross-device sum: full f32-headroom resolution.

    Unlike ``intac_psum`` — whose shared scale shrinks with the device
    count to keep the single int32 sum in headroom — the scale here is
    sized by magnitude alone (``num_terms=1``): each device splits its
    full-width int32 quantization into (hi, lo) limbs, both limbs psum in
    the exact integer domain (per-device |hi| <= 2^(qbits-15) and lo <
    2^15, so up to 2^15 devices carry-free at qbits=30), and one
    ``limbs_resolve`` per reduction pays for the normalization.
    """
    gmax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    scale = choose_scale(gmax, 1, qbits)
    hi, lo = limb_split(quantize(x, scale))
    return limbs_resolve(jax.lax.psum(hi, axis_name),
                         jax.lax.psum(lo, axis_name), scale)


def limb3_merge_across(hi: jnp.ndarray, lo: jnp.ndarray, res: jnp.ndarray,
                       comp: jnp.ndarray, axis_names) -> Tuple[
                           jnp.ndarray, jnp.ndarray, jnp.ndarray,
                           jnp.ndarray]:
    """The one cross-device merge of three-limb state (inside shard_map).

    Integer limbs reduce with one associative int32 ``psum`` each — any
    reduction topology, same bits, at any device count.  The residual
    pair reduces through a small superaccumulator (Neal, arXiv
    1505.05571): every device splits res and comp into exponent-indexed
    integer digits of a window anchored at the global (pmax-shared)
    residual maximum, the digit bins ``psum`` in the exact integer
    domain, and one carry-resolve + compensated combine rebuilds a float
    residual.  Both the anchor and the integer bin sums are pure
    functions of the *global* per-device residuals — no device-order
    fold remains, so the merged state (and everything finalized from it)
    is bitwise identical at any device count, mesh shape, or device
    permutation.  Every layer that merges three-limb state across
    devices (the exact2 policy, ``Limb3Accumulator``, ``intac_psum3``)
    delegates here so the semantics cannot drift apart.
    """
    axes = tuple(axis_names)
    m = jnp.maximum(jnp.max(jnp.abs(res)), jnp.max(jnp.abs(comp)))
    e_ref = bin_ref_exponent(jax.lax.pmax(m, axes))
    digits = (bin_split(res, e_ref, bits=RES_BIN_BITS, num=RES_NUM_BINS)
              + bin_split(comp, e_ref, bits=RES_BIN_BITS,
                          num=RES_NUM_BINS))
    # one fused int32 psum for all three integer components: psum is
    # elementwise, so summing [hi | lo | digits] concatenated is the same
    # bits as three separate collectives — at a third of the latency
    # floor.  Only the anchor pmax remains separate (it gates digits).
    flat = jax.lax.psum(
        jnp.concatenate([hi.ravel(), lo.ravel(), digits.ravel()]), axes)
    hi = flat[:hi.size].reshape(hi.shape)
    lo = flat[hi.size:hi.size + lo.size].reshape(lo.shape)
    digits = flat[hi.size + lo.size:].reshape(digits.shape)
    res = bin_combine(digits, e_ref, bits=RES_BIN_BITS)
    return hi, lo, res, jnp.zeros_like(res)


def intac_psum3(x: jnp.ndarray, axis_name, *, qbits: int = 30) -> jnp.ndarray:
    """Three-limb exact cross-device sum: two-limb resolution *plus* the
    exactly-captured quantization residual.

    The integer limbs follow ``intac_psum2`` bit for bit (one associative
    int32 psum per limb — any reduction topology, same bits); the residual
    limb reduces through the binned superaccumulator of
    ``limb3_merge_across`` — per-element digit splits into integer bins
    that psum associatively, anchored at a pmax-shared window.  Because
    the per-element digits depend only on each element's value and the
    global anchor, the finalized sum is **bitwise identical at any device
    count or mesh shape**, and within 1 ulp of the f64 reference for
    arbitrary f32 inputs — the residual makes "exact" hold off the
    dyadic grid too.
    """
    gmax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    scale = choose_scale(gmax, 1, qbits)
    hi, lo, res = limb_split3(x, scale)
    hi, lo, res, comp = limb3_merge_across(hi, lo, res, jnp.zeros_like(res),
                                           axis_name)
    return limbs_resolve3(hi, lo, res, scale, comp=comp)


def bin_psum(x: jnp.ndarray, axis_name) -> jnp.ndarray:
    """Exponent-binned exact cross-device sum (per-bin integer psum).

    All devices agree on the window anchor via a pmax, split locally into
    exponent-bin digits, psum the int32 bins (associative => bitwise
    identical for any reduction topology), and carry-resolve once.
    """
    gmax = jax.lax.pmax(jnp.max(jnp.abs(x)), axis_name)
    e_ref = bin_ref_exponent(gmax)
    return bin_combine(jax.lax.psum(bin_split(x, e_ref), axis_name), e_ref)


class EFState(NamedTuple):
    """Error-feedback residual for compressed gradient all-reduce."""
    residual: jnp.ndarray


def compressed_psum_mean(x: jnp.ndarray, residual: jnp.ndarray, axis_name,
                         *, bits: int = 8) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """INTAC-style compressed gradient all-reduce with error feedback.

    1. add the residual carried from the previous step (error feedback);
    2. agree on a shared power-of-two scale targeting ``bits``-bit payloads;
    3. quantize -> int, psum in the exact integer domain, dequantize once;
    4. the local quantization error becomes the next residual.

    Communication payload is ``bits``/32 of fp32 (int8 => 4x compression);
    the integer psum keeps the *reduction* exact and deterministic, so the
    only loss is the explicit, error-fed-back quantization.
    Returns (mean gradient, new residual).
    """
    xr = x + residual
    n = jax.lax.psum(1, axis_name)
    gmax = jax.lax.pmax(jnp.max(jnp.abs(xr)), axis_name)
    # payload must fit `bits` signed bits; headroom for the n-way sum lives
    # in the int32 accumulator, not the payload.
    scale = choose_scale(gmax, 1, qbits=bits - 1)
    q = quantize(xr, scale)
    new_residual = xr - dequantize(q, scale)
    total = jax.lax.psum(q, axis_name)          # int32 accumulate (exact)
    mean = dequantize(total, scale) / n
    return mean, new_residual


def compressed_psum_mean_tree(grads, residuals, axis_name, *, bits: int = 8):
    """Pytree version of ``compressed_psum_mean``."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residuals)
    out, res = [], []
    for g, r in zip(flat_g, flat_r):
        m, nr = compressed_psum_mean(g, r, axis_name, bits=bits)
        out.append(m)
        res.append(nr)
    return tdef.unflatten(out), tdef.unflatten(res)


def zeros_like_residuals(grads):
    return jax.tree.map(jnp.zeros_like, grads)
