"""Cycle-accurate simulators for the paper's two circuits.

This module is the *faithful reproduction* layer: it models JugglePAC
(Fig. 3 / Algorithm 1 / Algorithm 2) and INTAC (Fig. 4 / Fig. 5 / Eq. 1)
at clock-cycle granularity, so the paper's own claims can be validated:

  * JugglePAC: single pipelined adder, 2-state FSM, PIS register file with
    per-register timeout counters (L+3), 4-slot FIFO, in-order results,
    latency <= DS + c, minimum-set-size vs. number of PIS registers
    (paper Table II), and the Table I schedule for L=2.
  * INTAC: 3:2 carry-save compressor with feedback + resource-shared final
    adder with K full-adder cells; latency per Eq. (1).

The simulators are plain Python/NumPy on purpose — they are the oracle the
JAX/Pallas production layer (core/segmented.py, kernels/) is tested against,
and an oracle should be as simple as possible.  A jit-able ``lax.scan``
re-implementation of the JugglePAC FSM lives in core/circuit_jax.py and is
property-tested against this one.
"""

from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Callable, List, Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# Pipelined operator (the paper's "FP adder with latency L")
# ---------------------------------------------------------------------------


class PipelinedAdder:
    """A latency-L pipelined binary operator.

    Each cycle accepts at most one (a, b) issue; the result appears exactly
    L cycles later.  Models the paper's IP FP adder.  ``op`` is the combining
    operator — ``operator.add`` for accumulation, but any associative-ish
    multi-cycle operator works (the paper notes an FP multiplier works too).
    """

    def __init__(self, latency: int, op: Callable = lambda a, b: a + b):
        assert latency >= 1
        self.latency = latency
        self.op = op
        # Each stage holds None or (value, label) — value computed at issue
        # time; the pipeline models latency, not partial arithmetic.
        self._stages: List[Optional[Tuple[object, int]]] = [None] * latency

    def tick(self, issue: Optional[Tuple[object, object, int]]):
        """Advance one clock. ``issue`` is (a, b, label) or None.

        Returns (value, label) completing this cycle, or None.
        """
        done = self._stages[-1]
        self._stages = [None] + self._stages[:-1]
        if issue is not None:
            a, b, label = issue
            self._stages[0] = (self.op(a, b), label)
        return done

    @property
    def busy(self) -> bool:
        return any(s is not None for s in self._stages)


# ---------------------------------------------------------------------------
# JugglePAC
# ---------------------------------------------------------------------------


@dataclass
class JugglePACResult:
    value: object
    set_index: int          # global index of the data set this result belongs to
    cycle: int              # clock cycle the result was produced on
    first_input_cycle: int  # cycle the set's first element entered the circuit

    @property
    def latency(self) -> int:
        return self.cycle - self.first_input_cycle


class JugglePAC:
    """Cycle-accurate JugglePAC (paper §III-A, §IV-B).

    Architecture, per the paper:
      * top-level FSM with two states (Algorithm 1):
          state 1 — the current input is the 2nd of a raw pair: issue
                    (previous input, current input) to the adder;
          state 0 — the adder input slot is free: issue a ready pair from the
                    PIS FIFO, if any;
        on ``start`` (first element of a new set) a dangling previous input
        is paired with 0.
      * a shift register carrying (label, inEn) alongside the adder pipeline;
      * the PIS: ``num_registers`` registers addressed by label, per-register
        timeout counters, and a 4-slot FIFO of ready pairs (Algorithm 2).

    Labels are assigned per set as set_index % num_registers, matching the
    paper's "behaving as a BRAM where the address is the label".
    """

    FIFO_DEPTH = 4

    def __init__(self, adder_latency: int = 14, num_registers: int = 4,
                 op: Callable = lambda a, b: a + b, zero=0.0):
        self.L = adder_latency
        self.R = num_registers
        self.zero = zero
        self.adder = PipelinedAdder(adder_latency, op)
        # PIS register file: per label slot (value or None), wait counter,
        # and which set_index currently owns the slot.
        self.reg: List[Optional[object]] = [None] * num_registers
        self.counter = [0] * num_registers
        self.reg_owner = [-1] * num_registers
        self.fifo: List[Tuple[object, object, int]] = []  # (a, b, label)
        self.cycle = 0
        # FSM / input pairing state
        self.state = 0          # state==1 -> have a pending first-of-pair
        self.pending: Optional[object] = None
        self.pending_label = -1
        self.pending_set = -1
        # bookkeeping
        self.set_count = 0
        self.cur_label = -1
        self.cur_set = -1
        self.first_cycle_of_set: dict = {}
        self.label_to_set: dict = {}
        self.results: List[JugglePACResult] = []
        self.fifo_overflows = 0
        self.adder_issue_log: List[Tuple[int, object, object, int]] = []

    # -- internals ----------------------------------------------------------

    def _pis_insert(self, value, label: int):
        """Adder output (value,label) enters the PIS (pair identification)."""
        if self.reg[label] is None:
            self.reg[label] = value
            self.counter[label] = 0
            self.reg_owner[label] = self.label_to_set[label]
        else:
            if len(self.fifo) >= self.FIFO_DEPTH:
                # The paper sizes the FIFO at 4 and relies on the schedule to
                # never overflow; we count overflows (a correctness bug if >0)
                # rather than silently dropping.
                self.fifo_overflows += 1
            self.fifo.append((self.reg[label], value, label))
            self.reg[label] = None
            self.counter[label] = 0

    def _pis_timeout_scan(self):
        """Algorithm 2: counters tick; a value that has waited L+3 cycles
        without a partner is this set's final result.

        The output bus is a single port, so at most one result is emitted
        per cycle; a second register at threshold holds until the next cycle
        (counters saturate at the threshold).
        """
        emitted = False
        for i in range(self.R):
            if self.reg[i] is None:
                continue
            if self.counter[i] >= self.L + 3:
                if emitted:
                    continue  # bus busy: hold at threshold
                emitted = True
                set_idx = self.reg_owner[i]
                self.results.append(JugglePACResult(
                    value=self.reg[i], set_index=set_idx, cycle=self.cycle,
                    first_input_cycle=self.first_cycle_of_set[set_idx]))
                self.reg[i] = None
                self.counter[i] = 0
                self.reg_owner[i] = -1
            else:
                self.counter[i] += 1

    # -- public API ----------------------------------------------------------

    def step(self, value=None, start: bool = False):
        """Advance one clock cycle.

        value/start model the paper's input bus: ``value`` is the sample (or
        None for an idle cycle), ``start`` flags the first element of a set.
        """
        issue = None

        if value is not None and start:
            # New set begins. A dangling odd element of the previous set is
            # paired with 0 (Algorithm 1 "Adder <- previous input, 0").
            if self.state == 1 and self.pending is not None:
                issue = (self.pending, self.zero, self.pending_label)
            self.set_count += 1
            self.cur_set = self.set_count - 1
            self.cur_label = self.cur_set % self.R
            self.label_to_set[self.cur_label] = self.cur_set
            self.first_cycle_of_set[self.cur_set] = self.cycle
            self.pending = value
            self.pending_label = self.cur_label
            self.pending_set = self.cur_set
            self.state = 1
        elif value is not None:
            if self.state == 1:
                # state 1: second element of a raw pair -> issue it.
                issue = (self.pending, value, self.pending_label)
                self.pending = None
                self.state = 0
            else:
                # state 0: stash as first-of-pair; adder slot is free.
                self.pending = value
                self.pending_label = self.cur_label
                self.pending_set = self.cur_set
                self.state = 1
        elif self.state == 1 and self.pending is not None:
            # Idle cycle with a dangling first-of-pair: the set has ended
            # (sets are back-to-back within themselves, per Fig. 1), so the
            # odd leftover is paired with 0 — the same action Algorithm 1
            # takes on the next ``start``, just triggered by the gap.
            issue = (self.pending, self.zero, self.pending_label)
            self.pending = None
            self.state = 0

        if issue is None and self.fifo:
            # Free adder slot -> issue a ready PIS pair (Algorithm 1 state 0).
            issue = self.fifo.pop(0)

        if issue is not None:
            self.adder_issue_log.append(
                (self.cycle, issue[0], issue[1], issue[2]))
        out = self.adder.tick(issue)
        if out is not None:
            self._pis_insert(out[0], out[1])
        self._pis_timeout_scan()
        self.cycle += 1

    def run(self, sets: Sequence[Sequence], gaps: Optional[Sequence[int]] = None,
            drain: Optional[int] = None) -> List[JugglePACResult]:
        """Feed ``sets`` back-to-back (or with per-set leading ``gaps``) and
        run until the circuit drains.  Returns results in emission order."""
        gaps = list(gaps) if gaps is not None else [0] * len(sets)
        for s, gap in zip(sets, gaps):
            for _ in range(gap):
                self.step()
            for j, v in enumerate(s):
                self.step(v, start=(j == 0))
        if drain is None:
            drain = 4 * self.L + 16 + max((len(s) for s in sets), default=0)
        target = len(sets)
        guard = 0
        while len(self.results) < target and guard < drain + 10000:
            self.step()
            guard += 1
        return self.results

    # Convenience: is the circuit fully drained?
    @property
    def idle(self) -> bool:
        return (not self.adder.busy and not self.fifo
                and all(r is None for r in self.reg)
                and self.pending is None)


def jugglepac_min_set_size(adder_latency: int, num_registers: int,
                           probe_max: int = 200, trials_per_n: int = 3,
                           num_sets: int = 12) -> int:
    """Empirically determine the minimum set length (paper Table II).

    Smallest n such that ``num_sets`` back-to-back sets of length n (and a
    few jittered variants >= n) all produce correct, in-order results with
    no FIFO overflow.  The paper reports 94/29/18 for R=2/4/8 at L=14.
    """
    def ok(n: int) -> bool:
        for t in range(trials_per_n):
            sizes = [n + ((7 * i + t) % 3) for i in range(num_sets)]
            sets = [[float(i * 1000 + j) for j in range(sz)]
                    for i, sz in enumerate(sizes)]
            pac = JugglePAC(adder_latency, num_registers)
            res = pac.run(sets)
            if pac.fifo_overflows or len(res) != len(sets):
                return False
            for r, (i, s) in zip(res, enumerate(sets)):
                if r.set_index != i or abs(r.value - sum(s)) > 1e-6 * abs(sum(s)):
                    return False
        return True

    lo, hi = 2, probe_max
    if not ok(hi):
        return probe_max + 1
    # first find some failing floor, then binary search the boundary
    while lo < hi:
        mid = (lo + hi) // 2
        if ok(mid):
            hi = mid
        else:
            lo = mid + 1
    return lo


# ---------------------------------------------------------------------------
# INTAC
# ---------------------------------------------------------------------------


@dataclass
class INTACResult:
    value: int
    cycle: int


class INTAC:
    """Cycle-accurate INTAC (paper §III-B, Fig. 4/5, Eq. 1).

    * An N:2 carry-save compressor with feedback accumulates ``inputs_per_cycle``
      new operands per cycle into a redundant (sum, carry) pair with a 1-FA
      critical path (modeled bitwise).
    * When the set ends, the (sum, carry) pair is handed to the resource-shared
      final adder: ``fa_cells`` full-adder cells resolve K bits per cycle from
      the LSB up, operands shifting right by K each cycle (Fig. 5).
    * Latency (cycles from last input to result) follows Eq. (1).

    Bit widths: inputs are ``in_bits`` wide, the accumulator/result ``out_bits``.
    """

    def __init__(self, in_bits: int = 64, out_bits: int = 128,
                 inputs_per_cycle: int = 1, fa_cells: int = 1):
        self.in_bits = in_bits
        self.out_bits = out_bits
        self.N = inputs_per_cycle
        self.K = fa_cells
        self.mask = (1 << out_bits) - 1
        self.reset()

    def reset(self):
        self.s = 0      # carry-save "sum" word
        self.c = 0      # carry-save "carry" word
        self.cycle = 0

    def _csa(self, a: int, b: int, d: int) -> Tuple[int, int]:
        """One row of full adders (3:2 compressor), bit-parallel."""
        s = (a ^ b ^ d) & self.mask
        c = (((a & b) | (a & d) | (b & d)) << 1) & self.mask
        return s, c

    def feed(self, values: Sequence[int]):
        """One clock: compress up to ``inputs_per_cycle`` new values into
        the (s, c) feedback pair via an N:2 compressor tree."""
        assert len(values) <= self.N
        for v in values:
            self.s, self.c = self._csa(self.s, self.c, v & self.mask)
        self.cycle += 1

    def finalize(self) -> INTACResult:
        """Resource-shared final addition: K FA cells per cycle, LSB-first,
        operands in shift registers (Fig. 5)."""
        s, c, carry, out = self.s, self.c, 0, 0
        cycles = 0
        for pos in range(0, self.out_bits, self.K):
            a = s & ((1 << self.K) - 1)
            b = c & ((1 << self.K) - 1)
            total = a + b + carry
            out |= (total & ((1 << self.K) - 1)) << pos
            carry = total >> self.K
            s >>= self.K
            c >>= self.K
            cycles += 1
        self.cycle += cycles + 1          # +1: output register (Fig. 5)
        res = INTACResult(value=out & self.mask, cycle=self.cycle)
        self.s = self.c = 0
        return res

    def accumulate(self, values: Sequence[int]) -> INTACResult:
        """Accumulate a full set and return the resolved result."""
        self.reset()
        for i in range(0, len(values), self.N):
            self.feed(values[i:i + self.N])
        return self.finalize()

    @staticmethod
    def latency_eq1(num_inputs: int, inputs_per_cycle: int, out_bits: int,
                    fa_cells: int, reduced_bits: int = 0) -> int:
        """Paper Eq. (1): Latency = ceil(I/N) + ceil((M-R)/FAs) + 1.

        (The paper's LaTeX transposes N and I; the meaning — set length
        divided by inputs-per-cycle — is unambiguous from Table V.)
        """
        return (math.ceil(num_inputs / inputs_per_cycle)
                + math.ceil((out_bits - reduced_bits) / fa_cells) + 1)

    def min_set_size(self) -> int:
        """Paper §IV-C: minimum set length = ceil((M*inputs)/FAs)."""
        return math.ceil(self.out_bits * self.N / self.K)
