"""JugglePAC as a jit-able ``jax.lax.scan`` state machine.

This is the same cycle-accurate circuit as ``core.circuit.JugglePAC``,
re-expressed with fixed-shape JAX arrays so it can be jit-compiled, vmapped
over parameter sweeps, and property-tested at scale against the Python
golden model.  One scan step == one clock cycle.

State layout (all fixed shapes; L = adder latency, R = PIS registers):
  pipe_v   (L,)  values in flight in the adder pipeline
  pipe_l   (L,)  labels accompanying them (the paper's shift register)
  pipe_en  (L,)  the shift register's inEn bit
  reg_v    (R,)  PIS register file (intermediate results, addressed by label)
  reg_en   (R,)  occupancy
  reg_cnt  (R,)  Algorithm-2 timeout counters
  reg_set  (R,)  which global set index owns the slot
  label_set(R,)  which set index currently owns each label
  fifo_*   (4,)  the 4-slot ready-pair FIFO
  fsm state, pending input register, current set/label counters
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

FIFO_DEPTH = 4


class PacState(NamedTuple):
    pipe_v: jnp.ndarray
    pipe_l: jnp.ndarray
    pipe_en: jnp.ndarray
    reg_v: jnp.ndarray
    reg_en: jnp.ndarray
    reg_cnt: jnp.ndarray
    reg_set: jnp.ndarray
    label_set: jnp.ndarray
    fifo_a: jnp.ndarray
    fifo_b: jnp.ndarray
    fifo_l: jnp.ndarray
    fifo_n: jnp.ndarray      # scalar int32: occupancy
    fsm: jnp.ndarray         # scalar int32: 0 / 1 (pending first-of-pair?)
    pend_v: jnp.ndarray
    pend_l: jnp.ndarray
    cur_set: jnp.ndarray     # scalar int32: index of current set (-1 before any)
    cur_label: jnp.ndarray


def init_state(latency: int, num_registers: int,
               dtype=jnp.float32) -> PacState:
    L, R = latency, num_registers
    z = jnp.zeros
    return PacState(
        pipe_v=z((L,), dtype), pipe_l=z((L,), jnp.int32), pipe_en=z((L,), jnp.bool_),
        reg_v=z((R,), dtype), reg_en=z((R,), jnp.bool_),
        reg_cnt=z((R,), jnp.int32), reg_set=-jnp.ones((R,), jnp.int32),
        label_set=-jnp.ones((R,), jnp.int32),
        fifo_a=z((FIFO_DEPTH,), dtype), fifo_b=z((FIFO_DEPTH,), dtype),
        fifo_l=z((FIFO_DEPTH,), jnp.int32), fifo_n=jnp.int32(0),
        fsm=jnp.int32(0), pend_v=z((), dtype), pend_l=jnp.int32(0),
        cur_set=jnp.int32(-1), cur_label=jnp.int32(0))


def _step(latency: int, num_registers: int, state: PacState,
          inp) -> Tuple[PacState, Tuple]:
    """One clock cycle. ``inp`` = (value f32, start bool, valid bool)."""
    L, R = latency, num_registers
    v, start, valid = inp
    s = state

    is_start = valid & start
    is_cont = valid & ~start
    idle = ~valid

    have_pending = s.fsm == 1

    # --- FSM / input pairing (Algorithm 1) -------------------------------
    # Issue from the input path?
    flush = (is_start | idle) & have_pending          # dangling odd element
    pair = is_cont & have_pending                     # raw input pair
    input_issue = flush | pair

    issue_a = s.pend_v
    issue_b = jnp.where(pair, v, jnp.zeros_like(v))
    issue_l = s.pend_l

    # New-set bookkeeping.
    new_set = jnp.where(is_start, s.cur_set + 1, s.cur_set)
    new_label = jnp.where(is_start, (s.cur_set + 1) % R, s.cur_label)
    label_set = jnp.where(
        is_start, s.label_set.at[new_label].set(new_set, mode="drop"), s.label_set)

    # Pending register update.
    stash = is_start | (is_cont & ~have_pending)
    pend_v = jnp.where(stash, v, s.pend_v)
    pend_l = jnp.where(stash, new_label, s.pend_l)
    fsm = jnp.where(stash, 1, jnp.where(input_issue, 0, s.fsm)).astype(jnp.int32)

    # --- FIFO issue when the adder slot is free ---------------------------
    fifo_issue = (~input_issue) & (s.fifo_n > 0)
    issue_a = jnp.where(fifo_issue, s.fifo_a[0], issue_a)
    issue_b = jnp.where(fifo_issue, s.fifo_b[0], issue_b)
    issue_l = jnp.where(fifo_issue, s.fifo_l[0], issue_l)
    issue_en = input_issue | fifo_issue

    pop = fifo_issue
    fifo_a = jnp.where(pop, jnp.roll(s.fifo_a, -1), s.fifo_a)
    fifo_b = jnp.where(pop, jnp.roll(s.fifo_b, -1), s.fifo_b)
    fifo_l = jnp.where(pop, jnp.roll(s.fifo_l, -1), s.fifo_l)
    fifo_n = s.fifo_n - pop.astype(jnp.int32)

    # --- adder pipeline tick ----------------------------------------------
    out_v = s.pipe_v[L - 1]
    out_l = s.pipe_l[L - 1]
    out_en = s.pipe_en[L - 1]
    pipe_v = jnp.concatenate([jnp.where(issue_en, issue_a + issue_b,
                                        jnp.zeros_like(issue_a))[None],
                              s.pipe_v[:-1]])
    pipe_l = jnp.concatenate([issue_l[None], s.pipe_l[:-1]])
    pipe_en = jnp.concatenate([issue_en[None], s.pipe_en[:-1]])

    # --- PIS insert (pair identification) ---------------------------------
    reg_v, reg_en, reg_cnt, reg_set = s.reg_v, s.reg_en, s.reg_cnt, s.reg_set
    slot_occupied = reg_en[out_l]
    make_pair = out_en & slot_occupied
    store = out_en & ~slot_occupied

    # pair -> FIFO push
    push_idx = jnp.clip(fifo_n, 0, FIFO_DEPTH - 1)
    fifo_a = jnp.where(make_pair, fifo_a.at[push_idx].set(reg_v[out_l], mode="drop"), fifo_a)
    fifo_b = jnp.where(make_pair, fifo_b.at[push_idx].set(out_v, mode="drop"), fifo_b)
    fifo_l = jnp.where(make_pair, fifo_l.at[push_idx].set(out_l, mode="drop"), fifo_l)
    overflow = make_pair & (fifo_n >= FIFO_DEPTH)
    fifo_n = fifo_n + make_pair.astype(jnp.int32)

    reg_v = jnp.where(store, reg_v.at[out_l].set(out_v, mode="drop"), reg_v)
    reg_en = jnp.where(make_pair, reg_en.at[out_l].set(False, mode="drop"),
                       jnp.where(store, reg_en.at[out_l].set(True, mode="drop"), reg_en))
    reg_cnt = jnp.where(out_en, reg_cnt.at[out_l].set(0, mode="drop"), reg_cnt)
    reg_set = jnp.where(store, reg_set.at[out_l].set(label_set[out_l], mode="drop"), reg_set)

    # --- Algorithm 2: timeout scan (single output port) --------------------
    thresh = L + 3
    ready = reg_en & (reg_cnt >= thresh)
    any_ready = jnp.any(ready)
    emit_i = jnp.argmax(ready)          # lowest ready index
    res_v = reg_v[emit_i]
    res_set = reg_set[emit_i]
    res_en = any_ready

    reg_en = jnp.where(any_ready, reg_en.at[emit_i].set(False, mode="drop"), reg_en)
    reg_cnt = jnp.where(any_ready, reg_cnt.at[emit_i].set(0, mode="drop"), reg_cnt)
    reg_set = jnp.where(any_ready, reg_set.at[emit_i].set(-1, mode="drop"), reg_set)
    # saturating increment for occupied, non-emitted registers
    reg_cnt = jnp.where(reg_en, jnp.minimum(reg_cnt + 1, thresh), reg_cnt)

    new_state = PacState(pipe_v, pipe_l, pipe_en, reg_v, reg_en, reg_cnt,
                         reg_set, label_set, fifo_a, fifo_b, fifo_l, fifo_n,
                         fsm, pend_v, pend_l, new_set, new_label)
    return new_state, (res_v, res_set, res_en, overflow)


@partial(jax.jit, static_argnames=("latency", "num_registers"))
def jugglepac_scan(values: jnp.ndarray, starts: jnp.ndarray,
                   valids: jnp.ndarray, *, latency: int = 14,
                   num_registers: int = 4):
    """Run the circuit for ``len(values)`` cycles (pad with valid=False to
    drain).  Returns per-cycle (result, set_index, result_valid, overflow)."""
    state = init_state(latency, num_registers, values.dtype)
    step = partial(_step, latency, num_registers)
    _, outs = jax.lax.scan(step, state,
                           (values, starts.astype(bool), valids.astype(bool)))
    return outs


def run_sets(sets, *, latency: int = 14, num_registers: int = 4,
             drain: int | None = None):
    """Convenience mirror of ``circuit.JugglePAC.run`` for the JAX model."""
    if drain is None:
        drain = 8 * latency + 32 + max((len(s) for s in sets), default=0)
    vals, starts, valids = [], [], []
    for s in sets:
        for j, x in enumerate(s):
            vals.append(x); starts.append(j == 0); valids.append(True)
    vals += [0.0] * drain
    starts += [False] * drain
    valids += [False] * drain
    v = jnp.asarray(vals, jnp.float32)
    st = jnp.asarray(starts)
    en = jnp.asarray(valids)
    res_v, res_set, res_en, ovf = jugglepac_scan(
        v, st, en, latency=latency, num_registers=num_registers)
    res_v, res_set, res_en = map(jax.device_get, (res_v, res_set, res_en))
    out = [(int(si), float(rv), int(cy))
           for cy, (rv, si, re) in enumerate(zip(res_v, res_set, res_en)) if re]
    return out, bool(jax.device_get(ovf).any())
