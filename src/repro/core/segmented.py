"""Segmented streaming reduction — JugglePAC's task, TPU-native.

The paper's problem statement: values arrive as a flat stream partitioned
into back-to-back *variable-length sets*; produce one reduction per set,
in input order, at full throughput, with bounded intermediate storage.

TPU translation: the "stream" is a flat (N, D) array tiled HBM→VMEM in
blocks; the per-cycle serial input becomes a per-grid-step block; the PIS
register file becomes a bounded VMEM accumulator addressed by segment label.

The front door for segmented reductions is ``repro.reduce`` — one call
with accuracy policies (fast/compensated/exact/exact2/procrastinate) and
registered backends (ref/blocked/pallas/shard_map) all executing the
identical block schedule.  This module keeps the scatter-add *math oracle*
(``segment_sum_ref``), the monotone-id utilities, and the flash-partial
combines.

The bounded-storage guarantee (the paper's "2–8 PIS registers" and the
minimum-set-size restriction) appears here as ``max_live_segments``: with
monotone segment ids, a block of B rows can touch at most B+1 segments, and
a segment completes (can be emitted) as soon as a later id appears — the
same argument as the paper's L+3 timeout, with the adder latency L replaced
by the block size B.
"""

from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

# The repo-wide padding sentinel lives in the front door.  This must stay
# a direct submodule import: there IS a load-time cycle (repro.reduce's
# __init__ imports accumulator -> repro.core -> this module), and
# importing the backends submodule resolves it because backends itself
# never touches repro.core, while `from repro.reduce import ...` would
# read the half-initialized package and ImportError.
from repro.reduce.backends import OUT_OF_RANGE_LABEL

from .trees import pairwise_tree_sum  # noqa: F401  (re-export, used widely)


def segment_sum_ref(values: jnp.ndarray, segment_ids: jnp.ndarray,
                    num_segments: int) -> jnp.ndarray:
    """Oracle: scatter-add per segment. values (N, D) or (N,), ids (N,).

    Rows labeled outside [0, num_segments) — e.g. the repo-wide padding
    sentinel ``OUT_OF_RANGE_LABEL`` — are dropped (negative indices would
    otherwise wrap in JAX scatter).
    """
    ids = segment_ids.astype(jnp.int32)
    ok = (ids >= 0) & (ids < num_segments)
    ids = jnp.where(ok, ids, num_segments)      # park invalid rows
    vals = jnp.where(ok.reshape(ok.shape + (1,) * (values.ndim - 1)),
                     values, jnp.zeros((), values.dtype))
    out_shape = (num_segments + 1,) + values.shape[1:]
    return jnp.zeros(out_shape, values.dtype).at[ids].add(
        vals, mode="drop")[:num_segments]


def segment_count_ref(segment_ids: jnp.ndarray, num_segments: int,
                      valid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    w = jnp.ones_like(segment_ids, jnp.float32)
    if valid is not None:
        w = w * valid.astype(jnp.float32)
    # detlint: ok[DET006] counts deliberately ride the same impl as the
    # sums (one bitwise story); every caller bounds N well under 2^24
    return segment_sum_ref(w, segment_ids, num_segments)


def segment_mean(values, segment_ids, num_segments, *,
                 impl=segment_sum_ref, valid: Optional[jnp.ndarray] = None,
                 eps: float = 1e-9):
    """Per-segment mean; sums *and counts* go through ``impl``.

    ``impl`` is any segment-sum with the ``(values, ids, num_segments)``
    contract (the ref oracle, ``repro.reduce`` backends via shim, the
    pallas wrapper...).  ``valid`` masks rows out of both numerator and
    denominator by relabeling them ``OUT_OF_RANGE_LABEL``.
    """
    ids = segment_ids.astype(jnp.int32)
    if valid is not None:
        ids = jnp.where(valid, ids, jnp.int32(OUT_OF_RANGE_LABEL))
    s = impl(values, ids, num_segments)
    c = impl(jnp.ones(ids.shape, jnp.float32), ids, num_segments)
    c = jnp.maximum(c.astype(jnp.float32), eps)
    return s / c.reshape((num_segments,) + (1,) * (s.ndim - 1))


def segments_from_lengths(lengths: jnp.ndarray, total: int) -> jnp.ndarray:
    """Build a monotone segment-id vector from per-set lengths.

    ``lengths`` (S,) with sum == total -> ids (total,).  The inverse of the
    paper's `start` bit: start[i] = ids[i] != ids[i-1].
    """
    starts = jnp.cumsum(lengths)[:-1]
    ids = jnp.zeros((total,), jnp.int32).at[starts].add(1, mode="drop")
    return jnp.cumsum(ids)


def max_live_segments(block_size: int) -> int:
    """Bounded-storage bound: with monotone ids, one block overlaps at most
    block_size + 1 segments — the analogue of the paper's PIS sizing rule."""
    return block_size + 1


def streaming_logsumexp_combine(m1, l1, m2, l2):
    """Associative combine for streaming softmax denominators.

    The flash-decode partial states (max m, sum-of-exp l) combine exactly like
    JugglePAC partial sums: non-associative in fp, so we fix the pairing tree.
    """
    m = jnp.maximum(m1, m2)
    l = l1 * jnp.exp(m1 - m) + l2 * jnp.exp(m2 - m)
    return m, l


def flash_partial_combine(m1, l1, o1, m2, l2, o2):
    """Combine two flash-attention partial (max, denom, weighted-out) triples."""
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)
    a2 = jnp.exp(m2 - m)
    l = l1 * a1 + l2 * a2
    o = o1 * a1[..., None] + o2 * a2[..., None]
    return m, l, o


def combine_flash_partials_tree(m, l, o, axis: int = 0):
    """Fixed pairwise-tree combine of stacked flash partials along ``axis``.

    This is the cross-block / cross-device "state 0" of the decode path: each
    KV shard produces one partial; partials are juggled pairwise in a fixed
    tree so the result is independent of arrival order and bitwise
    reproducible across shardings.
    """
    m = jnp.moveaxis(m, axis, 0)
    l = jnp.moveaxis(l, axis, 0)
    o = jnp.moveaxis(o, axis, 0)
    n = m.shape[0]
    while n > 1:
        half = n // 2
        cm, cl, co = flash_partial_combine(
            m[0:2 * half:2], l[0:2 * half:2], o[0:2 * half:2],
            m[1:2 * half:2], l[1:2 * half:2], o[1:2 * half:2])
        if n % 2:
            m = jnp.concatenate([cm, m[n - 1:n]], 0)
            l = jnp.concatenate([cl, l[n - 1:n]], 0)
            o = jnp.concatenate([co, o[n - 1:n]], 0)
        else:
            m, l, o = cm, cl, co
        n = cm.shape[0] + (1 if n % 2 else 0)
    return m[0], l[0], o[0]
