"""Segmented streaming reduction — JugglePAC's task, TPU-native.

The paper's problem statement: values arrive as a flat stream partitioned
into back-to-back *variable-length sets*; produce one reduction per set,
in input order, at full throughput, with bounded intermediate storage.

TPU translation: the "stream" is a flat (N, D) array tiled HBM→VMEM in
blocks; the per-cycle serial input becomes a per-grid-step block; the PIS
register file becomes a bounded VMEM accumulator addressed by segment label.
Three implementations share one contract:

  * ``segment_sum_ref``     — pure-jnp oracle (scatter-add).
  * ``segment_sum_blocked`` — pure-JAX streaming version: ``lax.scan`` over
    blocks, each block contributes a one-hot matmul (MXU-shaped) into the
    running output.  This mirrors the circuit: blocks = cycles, the running
    (S, D) accumulator = the PIS registers, in-order emission by construction.
  * ``kernels.jugglepac_segsum`` — the Pallas TPU kernel (same schedule,
    explicit BlockSpec/VMEM tiling).

The bounded-storage guarantee (the paper's "2–8 PIS registers" and the
minimum-set-size restriction) appears here as ``max_live_segments``: with
monotone segment ids, a block of B rows can touch at most B+1 segments, and
a segment completes (can be emitted) as soon as a later id appears — the
same argument as the paper's L+3 timeout, with the adder latency L replaced
by the block size B.
"""

from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from .trees import pairwise_tree_sum


def segment_sum_ref(values: jnp.ndarray, segment_ids: jnp.ndarray,
                    num_segments: int) -> jnp.ndarray:
    """Oracle: scatter-add per segment. values (N, D) or (N,), ids (N,)."""
    out_shape = (num_segments,) + values.shape[1:]
    return jnp.zeros(out_shape, values.dtype).at[segment_ids].add(values)


def segment_count_ref(segment_ids: jnp.ndarray, num_segments: int,
                      valid: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    w = jnp.ones_like(segment_ids, jnp.float32)
    if valid is not None:
        w = w * valid.astype(jnp.float32)
    return jnp.zeros((num_segments,), jnp.float32).at[segment_ids].add(w)


@partial(jax.jit, static_argnames=("num_segments", "block_size"))
def segment_sum_blocked(values: jnp.ndarray, segment_ids: jnp.ndarray,
                        num_segments: int, block_size: int = 512) -> jnp.ndarray:
    """Streaming blocked segmented sum (the software JugglePAC).

    Each scan step consumes one (B, D) block and performs a one-hot matmul
    (S×B)·(B×D) — the MXU-friendly form of "pair everything in this block by
    label" — accumulated into the (S, D) running output.  Works for
    arbitrary (not only monotone) segment ids; `num_segments` is the label
    space, i.e. the paper's register-file size.
    """
    squeeze = values.ndim == 1
    if squeeze:
        values = values[:, None]
    n, d = values.shape
    nb = -(-n // block_size)
    pad = nb * block_size - n
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        # padded rows point at an out-of-range label -> one-hot row of zeros
        segment_ids = jnp.pad(segment_ids, (0, pad),
                              constant_values=num_segments)
    vb = values.reshape(nb, block_size, d)
    ib = segment_ids.reshape(nb, block_size)

    def step(acc, blk):
        v, ids = blk
        onehot = (ids[:, None] == jnp.arange(num_segments)[None, :])
        contrib = jnp.einsum("bs,bd->sd", onehot.astype(v.dtype), v)
        return acc + contrib, None

    acc0 = jnp.zeros((num_segments, d), values.dtype)
    acc, _ = jax.lax.scan(step, acc0, (vb, ib))
    return acc[:, 0] if squeeze else acc


def segment_mean(values, segment_ids, num_segments, *,
                 impl=segment_sum_ref, eps: float = 1e-9):
    s = impl(values, segment_ids, num_segments)
    c = segment_count_ref(segment_ids, num_segments)
    c = jnp.maximum(c, eps)
    return s / c.reshape((num_segments,) + (1,) * (s.ndim - 1))


def segments_from_lengths(lengths: jnp.ndarray, total: int) -> jnp.ndarray:
    """Build a monotone segment-id vector from per-set lengths.

    ``lengths`` (S,) with sum == total -> ids (total,).  The inverse of the
    paper's `start` bit: start[i] = ids[i] != ids[i-1].
    """
    starts = jnp.cumsum(lengths)[:-1]
    ids = jnp.zeros((total,), jnp.int32).at[starts].add(1)
    return jnp.cumsum(ids)


def max_live_segments(block_size: int) -> int:
    """Bounded-storage bound: with monotone ids, one block overlaps at most
    block_size + 1 segments — the analogue of the paper's PIS sizing rule."""
    return block_size + 1


def streaming_logsumexp_combine(m1, l1, m2, l2):
    """Associative combine for streaming softmax denominators.

    The flash-decode partial states (max m, sum-of-exp l) combine exactly like
    JugglePAC partial sums: non-associative in fp, so we fix the pairing tree.
    """
    m = jnp.maximum(m1, m2)
    l = l1 * jnp.exp(m1 - m) + l2 * jnp.exp(m2 - m)
    return m, l


def flash_partial_combine(m1, l1, o1, m2, l2, o2):
    """Combine two flash-attention partial (max, denom, weighted-out) triples."""
    m = jnp.maximum(m1, m2)
    a1 = jnp.exp(m1 - m)
    a2 = jnp.exp(m2 - m)
    l = l1 * a1 + l2 * a2
    o = o1 * a1[..., None] + o2 * a2[..., None]
    return m, l, o


def combine_flash_partials_tree(m, l, o, axis: int = 0):
    """Fixed pairwise-tree combine of stacked flash partials along ``axis``.

    This is the cross-block / cross-device "state 0" of the decode path: each
    KV shard produces one partial; partials are juggled pairwise in a fixed
    tree so the result is independent of arrival order and bitwise
    reproducible across shardings.
    """
    m = jnp.moveaxis(m, axis, 0)
    l = jnp.moveaxis(l, axis, 0)
    o = jnp.moveaxis(o, axis, 0)
    n = m.shape[0]
    while n > 1:
        half = n // 2
        cm, cl, co = flash_partial_combine(
            m[0:2 * half:2], l[0:2 * half:2], o[0:2 * half:2],
            m[1:2 * half:2], l[1:2 * half:2], o[1:2 * half:2])
        if n % 2:
            m = jnp.concatenate([cm, m[n - 1:n]], 0)
            l = jnp.concatenate([cl, l[n - 1:n]], 0)
            o = jnp.concatenate([co, o[n - 1:n]], 0)
        else:
            m, l, o = cm, cl, co
        n = cm.shape[0] + (1 if n % 2 else 0)
    return m[0], l[0], o[0]
