"""GradientJuggler — streaming pairwise-tree accumulation with bounded slots.

The software twin of JugglePAC's PIS: when microbatch gradients arrive one
per scan step, accumulate them with a *binary-counter* pairing tree instead
of a serial ``+=``:

    step 1:  slots = [g1]
    step 2:  slots = [g1+g2]            (carry to level 1)
    step 3:  slots = [g1+g2, g3]
    step 4:  slots = [(g1+g2)+(g3+g4)]  (carry chain)

This reproduces the Fig. 2 accumulation tree exactly: level-0 insertions are
FSM state 1 (pair raw inputs), carry-chain combines are state 0 (pair
partials), and the slot array is the PIS register file — ``num_slots`` =
ceil(log2 n) registers bound the live storage, the paper's "2–8 registers"
area argument translated to memory footprint (log n live gradient copies vs
n for a naive tree, 1 for serial).

Why bother vs serial ``+=``: the pairing tree's rounding-error growth is
O(log n) instead of O(n) — the paper's numerical motivation — and the fixed
schedule makes gradient accumulation bitwise independent of how microbatches
are grouped, which combines with ``intac_psum`` to give fully deterministic
distributed training.
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp


class JugglerState(NamedTuple):
    slots: object        # pytree of (K, *leaf_shape) stacked slot arrays
    occupancy: jnp.ndarray  # (K,) bool
    count: jnp.ndarray      # scalar int32: number of items pushed


def juggler_init(grad_template, num_slots: int) -> JugglerState:
    """``num_slots`` must be >= ceil(log2(num_pushes))."""
    slots = jax.tree.map(
        lambda g: jnp.zeros((num_slots,) + g.shape, g.dtype), grad_template)
    return JugglerState(slots, jnp.zeros((num_slots,), bool), jnp.int32(0))


def juggler_push(state: JugglerState, grad) -> JugglerState:
    """Insert one gradient; resolve the binary carry chain.

    The insertion level is the number of trailing occupied slots (they are
    all merged into the incoming value, lowest level first — a fixed order).
    """
    k = state.occupancy.shape[0]
    lvl = jnp.argmin(state.occupancy)        # first free slot
    # all slots below `lvl` are occupied (binary-counter invariant)
    lvl = jnp.where(jnp.all(state.occupancy), k, lvl)  # overflow guard

    def merge_leaf(slot_arr, g):
        def body(i, c):
            return jnp.where(i < lvl, slot_arr[i] + c, c)
        carry = jax.lax.fori_loop(0, k, body, g)
        mask = (jnp.arange(k) == lvl)
        mask = mask.reshape((k,) + (1,) * g.ndim)
        return jnp.where(mask, carry[None], slot_arr)

    new_slots = jax.tree.map(merge_leaf, state.slots, grad)
    idx = jnp.arange(k)
    new_occ = (idx == lvl) | (state.occupancy & (idx > lvl))
    return JugglerState(new_slots, new_occ, state.count + 1)


def juggler_finalize(state: JugglerState, *, mean: bool = False):
    """Fold remaining slots low->high (fixed order); optionally average."""
    k = state.occupancy.shape[0]

    def fold_leaf(slot_arr):
        def body(i, c):
            return jnp.where(state.occupancy[i], c + slot_arr[i], c)
        return jax.lax.fori_loop(0, k, body,
                                 jnp.zeros(slot_arr.shape[1:], slot_arr.dtype))

    total = jax.tree.map(fold_leaf, state.slots)
    if mean:
        denom = jnp.maximum(state.count, 1).astype(jnp.float32)
        total = jax.tree.map(lambda t: t / denom.astype(t.dtype), total)
    return total


def num_slots_for(num_microbatches: int) -> int:
    k = 0
    while (1 << k) < max(num_microbatches, 1):
        k += 1
    return max(k, 1) + 1  # +1 headroom for the final carry
