"""Fixed pairing-tree reduction schedules.

JugglePAC's central numerical idea is that a pipelined accumulator *must*
re-order additions, so the re-ordering should follow a fixed, shallow tree
(Fig. 2): level 1 pairs adjacent raw inputs (FSM state 1), higher levels pair
partial results (FSM state 0 via the PIS).  On TPU we keep exactly that
contract:

  * ``pairwise_tree_sum``        — log-depth balanced tree over an axis, with a
                                   *shape-independent* schedule: the pairing
                                   pattern depends only on element count, never
                                   on sharding, so results are bitwise
                                   reproducible across device layouts.
  * ``tree_combine``             — same, for an arbitrary associative combine
                                   (the paper: "any multi-cycle operator").
  * ``TreeAccumulator`` (juggler.py) uses the streaming binary-counter variant.

Compared with ``jnp.sum`` (whose reduction order is compiler-chosen), the
fixed tree trades nothing on TPU — XLA lowers it to the same vector adds —
but pins the addition order, which is the paper's "produce ordered,
reproducible results despite re-ordered additions" requirement.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp


def tree_combine(x: jnp.ndarray, axis: int,
                 combine: Callable[[jnp.ndarray, jnp.ndarray], jnp.ndarray],
                 pad_value=0.0) -> jnp.ndarray:
    """Reduce ``axis`` with a fixed balanced pairing tree.

    Odd remainders at each level pass through untouched — exactly JugglePAC's
    "pair the dangling element with the identity" move, except we can skip
    the +0 entirely in software.
    """
    x = jnp.moveaxis(x, axis, 0)
    n = x.shape[0]
    if n == 0:
        raise ValueError("cannot tree-reduce an empty axis")
    while n > 1:
        half = n // 2
        paired = combine(x[0:2 * half:2], x[1:2 * half:2])
        if n % 2:
            x = jnp.concatenate([paired, x[n - 1:n]], axis=0)
        else:
            x = paired
        n = paired.shape[0] + (1 if n % 2 else 0)
    return x[0]


def pairwise_tree_sum(x: jnp.ndarray, axis: int = 0) -> jnp.ndarray:
    """Deterministic log-depth pairwise summation (Fig. 2 tree)."""
    return tree_combine(x, axis, lambda a, b: a + b)


def pairwise_tree_sum_pytree(trees, combine=None):
    """Pairwise-tree reduce a *list of pytrees* (e.g. microbatch gradients)."""
    combine = combine or (lambda a, b: jax.tree.map(jnp.add, a, b))
    items = list(trees)
    if not items:
        raise ValueError("empty list")
    while len(items) > 1:
        nxt = [combine(items[i], items[i + 1])
               for i in range(0, len(items) - 1, 2)]
        if len(items) % 2:
            nxt.append(items[-1])
        items = nxt
    return items[0]


def tree_depth(n: int) -> int:
    """Depth of the fixed pairing tree for n leaves = ceil(log2 n).

    The paper's error motivation: serial accumulation has an O(n) worst-case
    rounding-error growth; the pairing tree's is O(log n)."""
    d = 0
    while (1 << d) < n:
        d += 1
    return d
