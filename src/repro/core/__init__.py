"""repro.core — the paper's contribution as composable JAX modules.

The public front door for reductions is ``repro.reduce`` (one call,
accuracy policies, registered backends, the streaming Accumulator
protocol); this package holds the primitives it is built from.

Faithful layer:
  circuit.JugglePAC / circuit.INTAC      cycle-accurate simulators
  circuit_jax.jugglepac_scan             the same FSM as a lax.scan

Production (TPU-native) layer:
  trees        fixed pairing-tree reduction schedules
  segmented    segmented-reduction math oracle + flash-partial combines
               (the blocked schedule itself lives in repro.reduce.backends)
  intac        exact integer-domain accumulation — limbs, exponent bins —
               + deterministic / compressed collectives (surfaced as
               reduce policies)
  juggler      bounded-slot streaming gradient accumulation (surfaced as
               repro.reduce.TreeAccumulator)
"""

from . import circuit, circuit_jax, intac, juggler, segmented, trees  # noqa: F401
from .circuit import INTAC, JugglePAC, jugglepac_min_set_size  # noqa: F401
from .intac import (bin_psum, compressed_psum_mean,  # noqa: F401
                    compressed_psum_mean_tree, intac_psum, intac_psum2,
                    intac_psum3, intac_sum, limb3_finalize, limb3_init,
                    limb3_merge_across, limb_add, limb_add3, limb_finalize,
                    limb_init, limb_merge, limb_merge3, limb_split3,
                    limbs_canonical, limbs_resolve3)
from .juggler import (juggler_finalize, juggler_init,  # noqa: F401
                      juggler_push, num_slots_for)
from .segmented import (combine_flash_partials_tree, flash_partial_combine,  # noqa: F401
                        segment_mean, segment_sum_ref,
                        segments_from_lengths)
from .trees import pairwise_tree_sum, pairwise_tree_sum_pytree, tree_combine  # noqa: F401
