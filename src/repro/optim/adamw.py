"""AdamW + schedules, built here (no optax dependency).

Optimizer state is a pytree congruent with params, so the FSDP/TP parameter
shardings apply verbatim to the moments.  Gradient clipping uses the fixed
pairing-tree global-norm reduction (deterministic across layouts) and the
moments are kept in f32 regardless of param dtype.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.trees import pairwise_tree_sum


def _leaf_sumsq(x, policy: str, width: int = 1024):
    """One leaf's sum of squares through the ``repro.reduce`` front door.

    The flat leaf folds as an (n/width, width)-blocked ``op="sumsq"``
    stream (zero-padding is exact: 0^2 contributes nothing in any tier)
    and the (width,) partials fold once more under the same policy —
    for the integer tiers the result is bitwise independent of backend
    and block size, which is what makes the global norm a deterministic
    whole-model property rather than an XLA-reduction accident.
    """
    from repro import reduce as _reduce
    xf = x.astype(jnp.float32).ravel()
    n = xf.shape[0]
    w = max(1, min(n, width))
    pad = (-n) % w
    if pad:
        xf = jnp.pad(xf, (0, pad))
    partial = _reduce.reduce(xf.reshape(-1, w), op="sumsq", policy=policy)
    return _reduce.reduce(partial, policy=policy)


class AdamWState(NamedTuple):
    mu: object           # pytree, f32
    nu: object           # pytree, f32
    count: jnp.ndarray   # scalar int32


def init(params) -> AdamWState:
    f32zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(mu=jax.tree.map(f32zeros, params),
                      nu=jax.tree.map(f32zeros, params),
                      count=jnp.zeros((), jnp.int32))


def global_norm(tree, *, policy: Optional[str] = None) -> jnp.ndarray:
    """Deterministic global norm: per-leaf sum-of-squares combined with a
    fixed pairing tree (leaf order is canonical tree order).

    ``policy`` (an accuracy-tier name) instead routes both stages —
    per-leaf ``op="sumsq"`` and the cross-leaf combine — through the
    ``repro.reduce`` front door; under an integer tier the squared norm
    is bitwise independent of leaf shapes' internal reduction order.
    ``None`` keeps the legacy XLA-sum path, bit for bit.
    """
    leaves = jax.tree.leaves(tree)
    if not leaves:
        return jnp.float32(0.0)
    if policy is None:
        sq = [jnp.sum(x.astype(jnp.float32) ** 2) for x in leaves]  # detlint: ok[DET001] policy=None legacy path (pairwise tree), bits pinned; global_norm(policy=) is the front door
        return jnp.sqrt(pairwise_tree_sum(jnp.stack(sq), axis=0))
    from repro import reduce as _reduce
    sq = [_leaf_sumsq(x, policy) for x in leaves]
    return jnp.sqrt(_reduce.reduce(jnp.stack(sq), policy=policy))


def clip_by_global_norm(grads, max_norm: float,
                        *, norm_policy: Optional[str] = None):
    g = global_norm(grads, policy=norm_policy)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale), grads), g


def update(grads, state: AdamWState, params, *, lr, b1: float = 0.9,
           b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.1,
           clip_norm: Optional[float] = 1.0,
           norm_policy: Optional[str] = None):
    """Returns (new_params, new_state, grad_norm).  ``norm_policy`` routes
    the clipping global norm through ``repro.reduce`` (None = legacy)."""
    gnorm = jnp.float32(0.0)
    if clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, clip_norm,
                                           norm_policy=norm_policy)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    count = state.count + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        step = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)
        step = step + weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * step
        return p2.astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(new_m, new_v, count), gnorm


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") \
            else jnp.float32(step)
        warm = jnp.minimum(step / max(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * warm * cos
    return lr
