"""AdamW + schedules, built here (no optax dependency).

Optimizer state is a pytree congruent with params, so the FSDP/TP parameter
shardings apply verbatim to the moments.  Gradient clipping uses the fixed
pairing-tree global-norm reduction (deterministic across layouts) and the
moments are kept in f32 regardless of param dtype.
"""

from __future__ import annotations

from typing import Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.core.trees import pairwise_tree_sum


class AdamWState(NamedTuple):
    mu: object           # pytree, f32
    nu: object           # pytree, f32
    count: jnp.ndarray   # scalar int32


def init(params) -> AdamWState:
    f32zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(mu=jax.tree.map(f32zeros, params),
                      nu=jax.tree.map(f32zeros, params),
                      count=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jnp.ndarray:
    """Deterministic global norm: per-leaf sum-of-squares combined with a
    fixed pairing tree (leaf order is canonical tree order)."""
    sq = [jnp.sum(x.astype(jnp.float32) ** 2) for x in jax.tree.leaves(tree)]
    if not sq:
        return jnp.float32(0.0)
    return jnp.sqrt(pairwise_tree_sum(jnp.stack(sq), axis=0))


def clip_by_global_norm(grads, max_norm: float):
    g = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale), grads), g


def update(grads, state: AdamWState, params, *, lr, b1: float = 0.9,
           b2: float = 0.95, eps: float = 1e-8, weight_decay: float = 0.1,
           clip_norm: Optional[float] = 1.0):
    """Returns (new_params, new_state, grad_norm)."""
    gnorm = jnp.float32(0.0)
    if clip_norm is not None:
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
    else:
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)

    count = state.count + 1
    c1 = 1.0 - b1 ** count.astype(jnp.float32)
    c2 = 1.0 - b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = b1 * m + (1 - b1) * g
        v2 = b2 * v + (1 - b2) * g * g
        step = (m2 / c1) / (jnp.sqrt(v2 / c2) + eps)
        step = step + weight_decay * p.astype(jnp.float32)
        p2 = p.astype(jnp.float32) - lr * step
        return p2.astype(p.dtype), m2, v2

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_m = tdef.flatten_up_to(state.mu)
    flat_v = tdef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = tdef.unflatten([o[0] for o in out])
    new_m = tdef.unflatten([o[1] for o in out])
    new_v = tdef.unflatten([o[2] for o in out])
    return new_p, AdamWState(new_m, new_v, count), gnorm


def cosine_schedule(base_lr: float, warmup: int, total: int,
                    min_ratio: float = 0.1) -> Callable:
    def lr(step):
        step = step.astype(jnp.float32) if hasattr(step, "astype") \
            else jnp.float32(step)
        warm = jnp.minimum(step / max(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = min_ratio + (1 - min_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return base_lr * warm * cos
    return lr
