"""Checkpointing: msgpack+zstd pytree snapshots with restart semantics.

Design for the fault-tolerance story (multi-thousand-node deployments):

  * atomic:      write to ``step_K.tmp`` then rename — a crash mid-write
                 never corrupts the latest checkpoint;
  * addressable: one file per host-shard (``shard_{host}of{H}``); each host
                 writes only the leaves (or leaf-chunks) it owns, so
                 checkpoint bandwidth scales with the fleet;
  * restartable: ``latest_step()`` + the data pipeline's skip-to-step give
                 exact-resume; optimizer state and the data cursor are part
                 of the snapshot;
  * elastic:     restore() reads the *logical* (unsharded) tree and lets
                 jax.device_put re-shard — restarting on a smaller/larger
                 mesh (elastic scaling) is a re-shard, not a re-format;
  * retention:   keep the newest ``keep`` checkpoints, delete older ones.

Format: msgpack map {path: {dtype, shape, raw(zstd, or zlib when
zstandard is unavailable — restore sniffs the frame magic)}} + a small
json manifest.  No orbax dependency — this is the substrate, built here.
"""

from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:                                    # optional dep: fall back to zlib
    import zstandard as zstd
except ImportError:
    zstd = None
import zlib

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"


def _compress(raw: bytes) -> bytes:
    if zstd is not None:
        return zstd.ZstdCompressor(level=3).compress(raw)
    return zlib.compress(raw, 6)


def _decompress(blob: bytes) -> bytes:
    """Codec-agnostic restore: sniff the zstd frame magic, else zlib.

    Lets a host with zstandard read zlib checkpoints and vice versa fail
    loudly (reading a zstd checkpoint without zstandard raises ImportError
    with a clear message rather than corrupting)."""
    if blob[:4] == _ZSTD_MAGIC:
        if zstd is None:
            raise ImportError("checkpoint was written with zstd but "
                              "zstandard is not installed")
        return zstd.ZstdDecompressor().decompress(blob)
    return zlib.decompress(blob)


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_set(tree, key: str, value):
    """Rebuild is done via unflatten over the original treedef instead."""
    raise NotImplementedError


def save(ckpt_dir: str, step: int, tree, *, host_id: int = 0,
         num_hosts: int = 1, keep: int = 3, extra: Optional[dict] = None):
    """Snapshot ``tree`` at ``step``.  Each host writes its shard file."""
    d = Path(ckpt_dir)
    tmp = d / f"step_{step:08d}.tmp"
    final = d / f"step_{step:08d}"
    (tmp if host_id == 0 else tmp).mkdir(parents=True, exist_ok=True)

    payload = {}
    for i, (key, leaf) in enumerate(sorted(_flatten(tree).items())):
        if i % num_hosts != host_id:
            continue                      # leaf-level host sharding
        arr = np.asarray(jax.device_get(leaf))
        payload[key] = {
            "dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": _compress(arr.tobytes()),
        }
    shard_file = tmp / f"shard_{host_id:05d}of{num_hosts:05d}.msgpack"
    with open(shard_file, "wb") as f:
        f.write(msgpack.packb(payload, use_bin_type=True))

    if host_id == 0:
        manifest = {"step": step, "num_hosts": num_hosts,
                    "time": time.time(), "extra": extra or {}}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        # barrier point in a real multi-host run; single-host: rename now
        os.replace(tmp, final)
        _retain(d, keep)
    return str(final)


def _retain(d: Path, keep: int):
    steps = sorted(p for p in d.iterdir()
                   if p.is_dir() and p.name.startswith("step_")
                   and not p.name.endswith(".tmp"))
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in d.iterdir()
             if p.is_dir() and p.name.startswith("step_")
             and not p.name.endswith(".tmp")
             and (p / "manifest.json").exists()]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_tree, *,
            shardings=None) -> Any:
    """Restore into the structure of ``like_tree``.

    ``shardings``: optional pytree of NamedSharding — leaves are placed
    directly onto the (possibly different — elastic restart) mesh.
    """
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    raw = {}
    for shard_file in sorted(d.glob("shard_*.msgpack")):
        with open(shard_file, "rb") as f:
            raw.update(msgpack.unpackb(f.read(), raw=False))

    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(
        like_tree)
    shard_flat = (None if shardings is None else
                  [s for _, s in
                   jax.tree_util.tree_flatten_with_path(shardings)[0]])
    out = []
    for i, (path, leaf) in enumerate(leaves_with_paths):
        key = "/".join(str(p) for p in path)
        if key not in raw:
            raise KeyError(f"checkpoint missing leaf {key}")
        ent = raw[key]
        arr = np.frombuffer(_decompress(ent["data"]),
                            dtype=ent["dtype"]).reshape(ent["shape"])
        if shard_flat is not None:
            out.append(jax.device_put(arr, shard_flat[i]))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest


def save_every(step: int, interval: int) -> bool:
    return interval > 0 and step > 0 and step % interval == 0
