"""Checkpointing: msgpack+zstd pytree snapshots with restart semantics.

Design for the fault-tolerance story (multi-thousand-node deployments):

  * atomic:      write to ``step_K.tmp`` then rename — a crash mid-write
                 never corrupts the latest checkpoint;
  * addressable: one file per host-shard (``shard_{host}of{H}``); each host
                 writes only the leaves (or leaf-chunks) it owns, so
                 checkpoint bandwidth scales with the fleet;
  * restartable: ``latest_step()`` + the data pipeline's skip-to-step give
                 exact-resume; optimizer state and the data cursor are part
                 of the snapshot;
  * elastic:     restore() reads the *logical* (unsharded) tree and lets
                 jax.device_put re-shard — restarting on a smaller/larger
                 mesh (elastic scaling) is a re-shard, not a re-format;
  * integrity:   every shard file carries a CRC32 sidecar (whole-file and
                 per-leaf, over the compressed blobs); ``restore`` verifies
                 both before a single byte is decoded, and every
                 availability/corruption failure surfaces as a structured
                 ``CheckpointError`` naming the step and path;
  * recovery:    ``restore_latest_valid`` walks steps newest-first,
                 retries transient read failures a bounded number of
                 times, and falls back past corrupt/truncated checkpoints
                 to the newest one that verifies;
  * retention:   keep the newest ``keep`` checkpoints, delete older ones.

Format (v2): msgpack map {path: {dtype, shape, raw(zstd, or zlib when
zstandard is unavailable — restore sniffs the frame magic)}} + a
``.crc.json`` sidecar per shard + a small json manifest.  Sidecar-less
(v1) checkpoints still restore — decode errors are caught either way;
they just lose the cheap pre-decode verification.  No orbax dependency —
this is the substrate, built here.
"""

from __future__ import annotations

import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Optional

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:                                    # optional dep: fall back to zlib
    import zstandard as zstd
except ImportError:
    zstd = None
import zlib

_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"

#: sidecar/manifest format with CRC32 integrity records
FORMAT_VERSION = 2


class CheckpointError(Exception):
    """A checkpoint could not be read back faithfully.

    Raised by ``restore`` for every availability or integrity failure —
    missing/corrupt manifest, missing shard files, CRC mismatch,
    truncated or undecodable blobs — always naming the step and path so
    the caller (or the operator reading the traceback) knows exactly
    which artifact is bad.  ``step`` and ``path`` are also carried as
    attributes for programmatic handling (``restore_latest_valid`` uses
    them to fall back to an older step).
    """

    def __init__(self, message: str, *, step: Optional[int] = None,
                 path=None):
        self.step = step
        self.path = None if path is None else str(path)
        where = ""
        if step is not None:
            where += f" step {step}"
        if path is not None:
            where += f" at {path}"
        super().__init__(f"checkpoint{where}: {message}")


def _crc(blob: bytes) -> int:
    return zlib.crc32(blob) & 0xFFFFFFFF


def _compress(raw: bytes) -> bytes:
    if zstd is not None:
        return zstd.ZstdCompressor(level=3).compress(raw)
    return zlib.compress(raw, 6)


def _decompress(blob: bytes) -> bytes:
    """Codec-agnostic restore: sniff the zstd frame magic, else zlib.

    Lets a host with zstandard read zlib checkpoints and vice versa fail
    loudly (reading a zstd checkpoint without zstandard raises ImportError
    with a clear message rather than corrupting)."""
    if blob[:4] == _ZSTD_MAGIC:
        if zstd is None:
            raise ImportError("checkpoint was written with zstd but "
                              "zstandard is not installed")
        return zstd.ZstdDecompressor().decompress(blob)
    return zlib.decompress(blob)


def _flatten(tree) -> dict:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(p) for p in path)
        flat[key] = leaf
    return flat


def _path_set(tree, key: str, value):
    """Rebuild is done via unflatten over the original treedef instead."""
    raise NotImplementedError


def save(ckpt_dir: str, step: int, tree, *, host_id: int = 0,
         num_hosts: int = 1, keep: int = 3, extra: Optional[dict] = None):
    """Snapshot ``tree`` at ``step``.  Each host writes its shard file."""
    d = Path(ckpt_dir)
    tmp = d / f"step_{step:08d}.tmp"
    final = d / f"step_{step:08d}"
    (tmp if host_id == 0 else tmp).mkdir(parents=True, exist_ok=True)

    payload = {}
    for i, (key, leaf) in enumerate(sorted(_flatten(tree).items())):
        if i % num_hosts != host_id:
            continue                      # leaf-level host sharding
        arr = np.asarray(jax.device_get(leaf))
        payload[key] = {
            "dtype": str(arr.dtype), "shape": list(arr.shape),
            "data": _compress(arr.tobytes()),
        }
    stem = f"shard_{host_id:05d}of{num_hosts:05d}"
    blob = msgpack.packb(payload, use_bin_type=True)
    with open(tmp / f"{stem}.msgpack", "wb") as f:
        f.write(blob)
    # integrity sidecar: whole-file CRC32 plus one per compressed leaf
    # blob, so restore can verify before decoding a single byte and name
    # the exact leaf a bit flip landed in
    sidecar = {"format": FORMAT_VERSION, "file_crc32": _crc(blob),
               "leaves": {k: _crc(v["data"]) for k, v in payload.items()}}
    (tmp / f"{stem}.crc.json").write_text(json.dumps(sidecar))

    if host_id == 0:
        manifest = {"step": step, "num_hosts": num_hosts,
                    "format": FORMAT_VERSION,
                    "time": time.time(), "extra": extra or {}}
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        # barrier point in a real multi-host run; single-host: rename now
        os.replace(tmp, final)
        _retain(d, keep)
    return str(final)


def _retain(d: Path, keep: int):
    steps = sorted(p for p in d.iterdir()
                   if p.is_dir() and p.name.startswith("step_")
                   and not p.name.endswith(".tmp"))
    for p in steps[:-keep]:
        shutil.rmtree(p, ignore_errors=True)


def latest_step(ckpt_dir: str) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = [int(p.name.split("_")[1]) for p in d.iterdir()
             if p.is_dir() and p.name.startswith("step_")
             and not p.name.endswith(".tmp")
             and (p / "manifest.json").exists()]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like_tree, *,
            shardings=None) -> Any:
    """Restore into the structure of ``like_tree``.

    ``shardings``: optional pytree of NamedSharding — leaves are placed
    directly onto the (possibly different — elastic restart) mesh.

    Integrity: when a ``.crc.json`` sidecar is present (format v2), the
    whole shard file and every compressed leaf blob are CRC32-verified
    before decoding.  Every availability/corruption failure — absent or
    corrupt manifest, no shard files, CRC mismatch, truncated msgpack,
    undecodable blob — raises ``CheckpointError`` naming the step and
    path.  A leaf present in ``like_tree`` but absent from the snapshot
    still raises ``KeyError`` (a structure mismatch, not corruption).
    """
    d = Path(ckpt_dir) / f"step_{step:08d}"
    try:
        manifest = json.loads((d / "manifest.json").read_text())
    except FileNotFoundError as e:
        raise CheckpointError("manifest.json is missing (no such step, or "
                              "a partially-written snapshot)",
                              step=step, path=d) from e
    except (json.JSONDecodeError, UnicodeDecodeError) as e:
        raise CheckpointError(f"manifest.json is corrupt ({e})",
                              step=step, path=d) from e
    shard_files = sorted(d.glob("shard_*.msgpack"))
    if not shard_files:
        raise CheckpointError("no shard files", step=step, path=d)
    raw = {}
    for shard_file in shard_files:
        blob = shard_file.read_bytes()
        sidecar_file = shard_file.with_name(
            shard_file.name[: -len(".msgpack")] + ".crc.json")
        sidecar = None
        if sidecar_file.exists():             # v1 snapshots have none
            try:
                sidecar = json.loads(sidecar_file.read_text())
            except (json.JSONDecodeError, UnicodeDecodeError) as e:
                raise CheckpointError(f"integrity sidecar is corrupt ({e})",
                                      step=step, path=sidecar_file) from e
            got = _crc(blob)
            if got != sidecar["file_crc32"]:
                raise CheckpointError(
                    f"shard file CRC32 {got:#010x} does not match the "
                    f"recorded {sidecar['file_crc32']:#010x} (bit flip or "
                    f"truncation)", step=step, path=shard_file)
        try:
            part = msgpack.unpackb(blob, raw=False)
        except Exception as e:
            raise CheckpointError(f"shard is truncated or undecodable "
                                  f"({type(e).__name__}: {e})",
                                  step=step, path=shard_file) from e
        if sidecar is not None:
            for key, ent in part.items():
                want = sidecar["leaves"].get(key)
                if want is not None and _crc(ent["data"]) != want:
                    raise CheckpointError(
                        f"leaf {key!r} CRC32 mismatch (bit flip in the "
                        f"compressed blob)", step=step, path=shard_file)
        raw.update(part)

    leaves_with_paths, treedef = jax.tree_util.tree_flatten_with_path(
        like_tree)
    shard_flat = (None if shardings is None else
                  [s for _, s in
                   jax.tree_util.tree_flatten_with_path(shardings)[0]])
    out = []
    for i, (path, leaf) in enumerate(leaves_with_paths):
        key = "/".join(str(p) for p in path)
        if key not in raw:
            raise KeyError(f"checkpoint missing leaf {key}")
        ent = raw[key]
        try:
            buf = _decompress(ent["data"])
        except ImportError:
            raise                      # zstd frame, zstandard missing
        except Exception as e:
            raise CheckpointError(f"leaf {key!r} failed to decompress "
                                  f"({type(e).__name__}: {e})",
                                  step=step, path=d) from e
        arr = np.frombuffer(buf, dtype=ent["dtype"]).reshape(ent["shape"])
        if shard_flat is not None:
            out.append(jax.device_put(arr, shard_flat[i]))
        else:
            out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out), manifest


def restore_latest_valid(ckpt_dir: str, like_tree, *, shardings=None,
                         retries: int = 2):
    """Restore the newest checkpoint that verifies, falling back past
    corrupt ones.

    Walks the available steps newest-first.  A transient read failure
    (``OSError``) is retried up to ``retries`` times before the step is
    written off; a ``CheckpointError`` (CRC mismatch, truncation, missing
    manifest) skips straight to the next-older step.  Returns
    ``(tree, manifest, step)``, or ``None`` when the directory holds no
    snapshots at all; raises ``CheckpointError`` when snapshots exist but
    none verifies (restoring silently from nothing would be worse than
    crashing).
    """
    d = Path(ckpt_dir)
    steps: list = []
    if d.exists():
        steps = sorted((int(p.name.split("_")[1]) for p in d.iterdir()
                        if p.is_dir() and p.name.startswith("step_")
                        and not p.name.endswith(".tmp")), reverse=True)
    if not steps:
        return None
    failures = []
    for step in steps:
        attempt = 0
        while True:
            try:
                tree, manifest = restore(ckpt_dir, step, like_tree,
                                         shardings=shardings)
                return tree, manifest, step
            except CheckpointError as e:
                failures.append(f"step {step}: {e}")
                break
            except OSError as e:       # transient read failure: retry
                attempt += 1
                if attempt > retries:
                    failures.append(f"step {step}: {type(e).__name__}: {e}")
                    break
                time.sleep(0.05 * attempt)
    raise CheckpointError(
        "no valid checkpoint among steps "
        f"{steps}; " + "; ".join(failures), path=d)


def save_every(step: int, interval: int) -> bool:
    return interval > 0 and step > 0 and step % interval == 0
