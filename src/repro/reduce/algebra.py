"""Reduction algebra: the op registry above the accuracy policies.

JugglePAC's circuit reduces *whatever* the datapath feeds it — the
schedule never cares that a block row is a raw sample, a weighted
sample, or a squared one.  This module makes that true of the repo's
front door: ``reduce(op=...)`` is no longer a hard-coded ``sum|mean``
pair but a registry of ``ReduceOp`` instances, each declaring two pure
row-local hooks around the one block schedule:

  * ``pre(values, weights=, coeffs=)`` — map the raw (N, D) stream to
    the (N, components*D) stream the schedule actually folds.  Running
    it *above* the policy layer is the whole design: the transformed
    rows flow through ``Policy.prepare`` / ``prepare_ctx`` /
    ``to_domain`` unchanged, so every tier weights **in its own
    domain** — ``fast`` multiplies in f32, while the integer tiers
    (exact / exact2 / procrastinate) size their quantization scale from
    the *weighted* magnitudes and fold exact integer images of the
    weighted rows.  Every downstream guarantee (cross-backend bitwise
    per policy, shard-count invariance for integer carries, the
    ``on_overflow="degrade"`` chunking, status flags) is inherited, not
    re-proved, because downstream only ever sees a wider sum.
  * ``post(summed, counts)`` — finalize the per-segment sums into the
    op's result (mean's divide, moments' mean/var resolve).  ``counts``
    is the exact int32 in-range row count per segment (only materialized
    when ``needs_count``).

``components`` is the op's domain-width multiplier: ``moments`` folds a
``[v | v*v]`` double-width stream through one schedule pass — a
multi-component carry in the same sense as exact2's limb planes, and
the planner/kernel budgets (``plan_program``, the pallas supertile
sizing) see the widened width automatically.

Time-index weightings (``op="poly"``, FIR taps via ``fir_weights``) are
the cascaded-accumulator construction of arXiv 2509.15069 done as a
``pre``: ``k`` chained plain accumulators realize binomial time-index
weights (``cascade_weights``; the streaming form is
``repro.reduce.CascadeAccumulator``), and any degree-(k-1) polynomial
weighting is a fixed linear combination of those ``k`` stages
(``cascade_poly_coeffs``).

Registering a new op:

>>> @register_op
... class _NegSum(ReduceOp):
...     name = "negsum"
...     def pre(self, values, *, weights=None, coeffs=None):
...         return -values.astype(jnp.float32)
>>> get_op("negsum").name
'negsum'
>>> del REDUCE_OPS["negsum"]                    # keep the doctest pure
"""

from __future__ import annotations

import math
from typing import Dict, Optional, Sequence

import jax.numpy as jnp
import numpy as np

#: name -> registered ``ReduceOp`` instance
REDUCE_OPS: Dict[str, "ReduceOp"] = {}


def register_op(cls):
    """Class decorator: instantiate and register a ``ReduceOp``."""
    op = cls()
    if not op.name or op.name == "?":
        raise ValueError(f"ReduceOp subclass {cls.__name__} must set a name")
    if op.name in REDUCE_OPS:
        raise ValueError(f"reduce op {op.name!r} is already registered")
    REDUCE_OPS[op.name] = op
    return cls


def get_op(name: str) -> "ReduceOp":
    try:
        return REDUCE_OPS[name]
    except KeyError:
        raise ValueError(f"unknown reduce op {name!r}; registered ops: "
                         f"{sorted(REDUCE_OPS)}") from None


class ReduceOp:
    """One entry of the reduction algebra.

    Class attributes declare the op's static shape so ``reduce`` can
    validate eagerly and the planner can size domains:

    * ``components`` — width multiplier of the folded stream (``pre``
      returns (N, components*D)); ``post`` receives the per-segment
      (S, components*D) sums.
    * ``takes_weights`` / ``requires_weights`` — whether ``weights=``
      is accepted / mandatory.
    * ``takes_coeffs`` / ``requires_coeffs`` — same for the static
      ``coeffs`` tuple (rides in ``ReduceSpec``, so it is jit-static).
    * ``needs_count`` — ``post`` wants the exact per-segment in-range
      row counts (int32, (S, 1)); ops that don't ask don't pay for the
      scatter-add.

    Both hooks must be row-local (``pre``) / segment-local (``post``):
    that is what lets every executor — ref, blocked, the pallas kernel,
    shard_map at any device count, and the degrade chunker — run the
    transformed stream through the unmodified block schedule.
    """

    name: str = "?"
    components: int = 1
    takes_weights: bool = False
    requires_weights: bool = False
    takes_coeffs: bool = False
    requires_coeffs: bool = False
    needs_count: bool = False

    def pre(self, values, *, weights=None, coeffs=None):
        """(N, D) raw rows -> (N, components*D) rows to fold."""
        return values

    def post(self, summed, counts):
        """(S, components*D) sums (+ (S, 1) counts) -> op result."""
        return summed


def _weighted(values, weights):
    return values.astype(jnp.float32) * weights.astype(jnp.float32)[:, None]


@register_op
class SumOp(ReduceOp):
    """Plain segmented sum — ``pre`` is the identity (no dtype cast, so
    the pre-algebra behavior is preserved bit for bit)."""

    name = "sum"


@register_op
class MeanOp(ReduceOp):
    """Segmented mean over in-range rows (exact integer counts)."""

    name = "mean"
    needs_count = True

    def post(self, summed, counts):
        return summed / jnp.maximum(counts, 1).astype(jnp.float32)


@register_op
class WeightedSumOp(ReduceOp):
    """sum_i w_i * v_i with per-row weights, folded in every tier's own
    domain.  All-ones weights are a bitwise identity (IEEE ``x * 1.0``),
    so ``weighted_sum(w=1)`` equals ``op="sum"`` bit for bit on f32
    input under every policy — the algebra's anchor law."""

    name = "weighted_sum"
    takes_weights = True
    requires_weights = True

    def pre(self, values, *, weights=None, coeffs=None):
        return _weighted(values, weights)


@register_op
class SumsqOp(ReduceOp):
    """sum_i v_i^2 — the global-norm / second-moment primitive."""

    name = "sumsq"

    def pre(self, values, *, weights=None, coeffs=None):
        vf = values.astype(jnp.float32)
        return vf * vf


@register_op
class MomentsOp(ReduceOp):
    """Running (mean, var) per segment via one double-width pass.

    ``pre`` widens each row to ``[v | v*v]`` — a two-component carry in
    the same sense as exact2's limb planes — and ``post`` resolves
    ``mean = s1/c`` and ``var = max(s2/c - mean^2, 0)``.  Under an exact
    tier both running sums are exact, so the variance inherits the
    shift-robustness of the sums themselves; the clamp guards the
    float-tier cancellation case (``var`` is mathematically >= 0).

    Result shape grows a leading statistic axis: (S, 2, D) segmented,
    (2, D) whole-stream, (2,) for 1-D input.
    """

    name = "moments"
    components = 2
    needs_count = True

    def pre(self, values, *, weights=None, coeffs=None):
        vf = values.astype(jnp.float32)
        return jnp.concatenate([vf, vf * vf], axis=1)

    def post(self, summed, counts):
        d = summed.shape[1] // 2
        c = jnp.maximum(counts, 1).astype(jnp.float32)
        m1 = summed[:, :d] / c
        m2 = summed[:, d:] / c
        var = jnp.maximum(m2 - m1 * m1, 0.0)
        return jnp.stack([m1, var], axis=1)


@register_op
class PolyOp(ReduceOp):
    """Polynomial time-index weighting: sum_i p(i) * v_i with
    ``p(i) = coeffs[0] + coeffs[1]*i + ...`` over the stream's global
    row index — the weighting a cascade of plain accumulators realizes
    (arXiv 2509.15069; see ``cascade_poly_coeffs``).  ``coeffs`` is
    static (it rides in ``ReduceSpec``), the weights are computed in f32
    by Horner's rule."""

    name = "poly"
    takes_coeffs = True
    requires_coeffs = True

    def pre(self, values, *, weights=None, coeffs=None):
        return _weighted(values, poly_weights(values.shape[0], coeffs))


def poly_weights(n: int, coeffs: Sequence[float]) -> jnp.ndarray:
    """The (n,) f32 weight vector ``w_i = p(i)`` for the polynomial with
    ascending ``coeffs`` (Horner in f32).

    >>> [float(v) for v in poly_weights(4, (1.0, 2.0))]
    [1.0, 3.0, 5.0, 7.0]
    """
    i = jnp.arange(n, dtype=jnp.float32)  # detlint: ok[DET006] time-index weights are float by definition; max_terms bounds n <= 2^24 where the grid is exact
    w = jnp.zeros((n,), jnp.float32)
    for c in reversed(tuple(coeffs)):
        w = w * i + jnp.float32(c)
    return w


def fir_weights(n: int, taps: Sequence[float]) -> jnp.ndarray:
    """Weights that make ``weighted_sum`` emit one FIR output:
    ``y[n-1] = sum_k taps[k] * x[n-1-k]`` (newest sample gets tap 0 —
    the constant-coefficient transversal-filter form).

    >>> [float(v) for v in fir_weights(4, (0.5, 0.25))]
    [0.0, 0.0, 0.25, 0.5]
    """
    w = np.zeros(n, np.float32)
    for k, t in enumerate(taps):
        if n - 1 - k >= 0:
            w[n - 1 - k] = t
    return jnp.asarray(w)


def cascade_weights(n: int, depth: int) -> jnp.ndarray:
    """Time-index weights realized by ``depth`` chained plain
    accumulators over an n-element stream (arXiv 2509.15069): after the
    last push, stage k (1-based) holds ``sum_i C(n-1-i + k-1, k-1) x_i``
    — row ``k-1`` of the returned (depth, n) f32 array.

    >>> np.asarray(cascade_weights(4, 2)).tolist()
    [[1.0, 1.0, 1.0, 1.0], [4.0, 3.0, 2.0, 1.0]]
    """
    rows = [[math.comb(n - 1 - i + k - 1, k - 1) for i in range(n)]
            for k in range(1, depth + 1)]
    return jnp.asarray(rows, jnp.float32)


def cascade_poly_coeffs(coeffs: Sequence[float], n: int) -> tuple:
    """Stage-combination weights for the cascaded-FIR construction.

    Returns ``alpha`` (one float per cascade stage, ``len(coeffs)``
    stages) such that ``sum_k alpha[k] * stage_{k+1}`` equals the direct
    ``op="poly"`` weighting ``p(i) = coeffs[0] + coeffs[1]*i + ...`` on
    an n-element stream: stage k's weights are a degree-(k-1) polynomial
    in the row index with nonzero leading coefficient, so the first
    ``deg`` stages span exactly the degree-(deg-1) polynomials and the
    (deg, deg) change of basis below is invertible.  Solved in f64 on
    the first ``deg`` row indices (both sides are degree-(deg-1)
    polynomials, so agreeing there is agreeing everywhere).

    >>> alpha = cascade_poly_coeffs((0.0, 1.0), 5)   # p(i) = i
    >>> w = sum(a * np.asarray(cascade_weights(5, 2), np.float64)[k]
    ...         for k, a in enumerate(alpha))
    >>> w.tolist()
    [0.0, 1.0, 2.0, 3.0, 4.0]
    """
    deg = len(coeffs)
    if deg == 0:
        return ()
    if n < deg:
        raise ValueError(f"need n >= {deg} stream elements to pin a "
                         f"degree-{deg - 1} weighting, got n={n}")
    basis = np.zeros((deg, deg), np.float64)      # [sample i, stage k]
    target = np.zeros(deg, np.float64)
    for i in range(deg):
        for k in range(1, deg + 1):
            basis[i, k - 1] = math.comb(n - 1 - i + k - 1, k - 1)
        target[i] = sum(c * float(i) ** p for p, c in enumerate(coeffs))
    alpha = np.linalg.solve(basis, target)
    return tuple(float(a) for a in alpha)
