"""Accuracy policies — the first-class knob of ``repro.reduce``.

JugglePAC's fixed-pairing argument says *what order* additions happen in;
the policy says *in what domain* they happen.  Five tiers, all sharing the
same block schedule (so a policy swap never changes the data movement):

  * ``fast``          — plain f32 accumulation over the fixed block tree.
    Deterministic (the schedule depends only on shapes), O(log n) error
    growth, zero overhead.
  * ``compensated``   — Kahan/two-sum carried across blocks: the (S, D)
    accumulator travels with an equally-shaped compensation term that
    captures every cross-block rounding error.  ~f64 accuracy at f32 cost.
  * ``exact``         — INTAC: quantize once to a shared power-of-two scale,
    accumulate in int32 (associative => bitwise identical for *any* block
    size, backend, or device layout), dequantize once per reduction — the
    paper's "pay for normalization once per set".  The scale is sized so
    the *whole stream* fits single-limb int32 headroom, so resolution
    shrinks as 1/N: cheap state, but long streams lose precision.
  * ``exact2``        — three-limb int32+residual carry-save
    (``core.intac.Limb3State`` semantics): the per-block contribution
    splits into (hi, lo) limbs — headroom from the second limb instead of
    the scale — while the third limb carries the exactly-captured
    quantization residual ``x - descale(quantize(x, scale), scale)``
    compensated-style.  The integer limbs stay bitwise order/block/
    backend-invariant; the residual limb closes the old dyadic-grid gap,
    so the finalized sum is within 1 ulp of the f64 reference for
    *arbitrary* f32 inputs at any stream length up to 2^24 rows (the
    residual's float fold gives tolerance, not bits, under re-ordering).
  * ``procrastinate`` — exponent-indexed bins after Liguori (arXiv
    2406.05866) / Neal (arXiv 1505.05571): each f32 value splits exactly
    into per-exponent-window integer digits, bins accumulate in int32,
    and *all* rounding procrastinates to one carry-resolve + compensated
    combine in ``finalize``.  Exact to <=1 ulp of the f32 result for any
    stream up to 2^22 rows whose result lands within ~2^24 of the
    largest |value| (the 48-bit window truncates below that, so under
    catastrophic cancellation the bound is absolute — N * 2^-49 of the
    max — not relative), at NUM_BINS x the accumulator state.

The integer tiers' integer state is bitwise order-independent: any block
size, backend, input permutation, or device layout produces identical
bits for ``exact``/``procrastinate`` results and for ``exact2``'s int32
hi/lo limbs (``exact2``'s *finalized float* additionally folds the
residual limb — deterministic for a fixed schedule, ulp-level tolerance
across schedules).

A policy owns five hooks, each pure and shape-polymorphic:

  ``prepare(values, num_terms)``      -> (domain_values, ctx)
  ``contrib(onehot, vals)``           -> one block's contribution: the
                                         (S, D) one-hot matmul(s) mapping
                                         a (B, W) domain block into what
                                         ``update`` folds (policies with a
                                         multi-part domain, e.g. exact2's
                                         quantized + residual halves, run
                                         one dot per part)
  ``init / update``                   -> the per-block carry (a tuple of
                                         ``carry_len`` arrays all backends
                                         thread identically; the pallas
                                         kernel executes ``contrib`` +
                                         ``update`` inside its grid loop)
  ``merge(a, b)``                     -> combine two partial carries
                                         (cross-shard / cross-device); the
                                         combiner the ``shard_map`` backend
                                         folds with (``merge_across`` lifts
                                         it to named-axis collectives)
  ``finalize(carry, ctx)``            -> (S, D) f32

New tiers register with ``@register_policy`` and immediately work on every
schedule-generic backend (``ref``/``blocked``); the ``pallas`` backend
advertises the policies its kernel has been validated for via its
capability flags.  ``update`` must be pure elementwise/jnp ops (it is
traced into the kernel body) and ``init`` must be zeros.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# Direct submodule import (not ``from repro.core import ...``): this
# module loads while repro.core's __init__ may still be mid-execution
# (core.segmented -> reduce.backends -> here), and intac itself imports
# nothing from repro, so the submodule path always resolves.
import repro.core.intac as intac
from repro.core.intac import (choose_scale, dequantize, quantize,  # noqa: F401
                              two_sum)

POLICIES: Dict[str, "Policy"] = {}


def register_policy(cls):
    """Class decorator: instantiate and add to the policy registry.

    The new tier immediately works on every schedule-generic backend
    (``ref``/``blocked``/``shard_map``) — only ``pallas`` gates on its
    validated capability set.

    >>> import jax.numpy as jnp
    >>> import repro
    >>> @register_policy
    ... class _NegatedPolicy(Policy):
    ...     '''Toy tier: accumulate in f32, negate once at finalize.'''
    ...     name = "negated_demo"
    ...     def finalize(self, carry, ctx):
    ...         return -carry[0]
    >>> float(repro.reduce(jnp.arange(4.0), policy="negated_demo"))
    -6.0
    >>> del POLICIES["negated_demo"]          # keep the registry clean
    """
    inst = cls()
    POLICIES[inst.name] = inst
    return cls


def get_policy(name: str) -> "Policy":
    """Look up a registered policy instance by name.

    >>> get_policy("exact2").carry_len
    4
    >>> get_policy("psychic")
    Traceback (most recent call last):
        ...
    ValueError: unknown policy 'psychic'; registered: ['compensated', \
'exact', 'exact2', 'fast', 'procrastinate']
    """
    try:
        return POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown policy {name!r}; registered: "
                         f"{sorted(POLICIES)}") from None


class Policy:
    """Base accuracy policy.  Subclasses set ``name`` and override hooks."""

    name: str = "?"
    #: number of carry arrays threaded through the block schedule
    carry_len: int = 1
    #: dtype the backends accumulate in (drives kernel specialization)
    acc_dtype = jnp.float32
    #: largest schedule block the policy's headroom analysis covers
    #: (None = any); ``reduce`` validates ``block_size`` against it
    max_block_size: Optional[int] = None
    #: largest block *count* the per-block carry headroom covers (None =
    #: any); ``reduce`` validates ceil(n / block_size) against it
    max_blocks: Optional[int] = None
    #: True when ``merge`` is plain elementwise addition, so a cross-device
    #: carry merge may lower to one ``lax.psum`` per carry component (the
    #: integer tiers: associative, any reduction topology gives the same
    #: bits).  False forces the gathered in-order fold (compensated: its
    #: two-sum merge is order-sensitive, so the fold order must be pinned).
    #: Mixed carries (exact2: psum'able integer limbs + an order-pinned
    #: residual pair) override ``merge_across`` instead.
    merge_is_add: bool = True

    @property
    def carry_dtypes(self) -> Tuple:
        """dtype of each carry component; uniform ``acc_dtype`` unless a
        policy mixes domains (exact2: int32 limbs + f32 residual pair)."""
        return (self.acc_dtype,) * self.carry_len

    def prepare(self, values: jnp.ndarray, num_terms: int):
        """Map raw (N, D) values into the accumulation domain.

        Returns (domain_values, ctx); ctx is passed back to ``finalize``.
        The domain may be wider than (N, D) — e.g. per-element digit
        splits — as long as ``finalize`` maps the carry back to (S, D).
        """
        return values.astype(jnp.float32), None

    def contrib(self, onehot: jnp.ndarray, vals: jnp.ndarray):
        """One schedule step: map a (B, S) boolean one-hot and a (B, W)
        domain block to the contribution ``update`` folds.

        Every backend (and the pallas kernel body) builds the same boolean
        one-hot and delegates here, so the dot lowering — and with it the
        cross-backend bitwise contract — is defined once, by the policy.
        """
        return jnp.dot(onehot.astype(vals.dtype).T, vals,
                       preferred_element_type=self.acc_dtype)

    def init(self, num_segments: int, d: int):
        """Zero carry, one (num_segments, d) array per ``carry_dtypes``
        entry; ``d`` is the *domain* width — policies whose carries are
        narrower than their domain (exact2) override."""
        return tuple(jnp.zeros((num_segments, d), dt)
                     for dt in self.carry_dtypes)

    def update(self, carry, contrib):
        return (carry[0] + contrib,)

    def merge(self, a, b):
        """Combine two partial carries (the cross-shard combiner).

        Semantics: ``merge(run(blocks[:k]), run(blocks[k:]))`` must equal
        ``run(blocks)`` — exactly for the integer tiers, to documented
        tolerance for the float tiers.  The default (elementwise add) is
        correct for every policy whose ``update`` is itself an add into
        the carry; order-sensitive carries override it and clear
        ``merge_is_add``.
        """
        return tuple(x + y for x, y in zip(a, b))

    def merge_across(self, carry, axis_names):
        """Merge per-shard carries across mesh axes (inside shard_map).

        The collective face of ``merge``: when ``merge_is_add``, each
        component reduces with one associative ``lax.psum`` (any reduction
        topology, same bits — the integer-tier contract); otherwise the
        carries all-gather and fold strictly in device order with
        ``merge``, pinning the combine schedule the way the block schedule
        pins per-shard order.  Policies with mixed carries (exact2)
        override this with a per-component lowering.
        """
        axes = tuple(axis_names)
        if self.merge_is_add:
            return tuple(jax.lax.psum(c, axes) for c in carry)
        gathered = tuple(jax.lax.all_gather(c, axes, axis=0) for c in carry)
        nshards = gathered[0].shape[0]
        merged = tuple(g[0] for g in gathered)
        for k in range(1, nshards):
            merged = self.merge(merged, tuple(g[k] for g in gathered))
        return merged

    def finalize(self, carry, ctx) -> jnp.ndarray:
        return carry[0]


@register_policy
class FastPolicy(Policy):
    """f32 accumulation over the fixed block tree (the default)."""

    name = "fast"


@register_policy
class CompensatedPolicy(Policy):
    """Kahan/two-sum compensated cross-block accumulation."""

    name = "compensated"
    carry_len = 2
    merge_is_add = False            # two-sum merge is order-sensitive

    def update(self, carry, contrib):
        acc, comp = carry
        s, e = two_sum(acc, contrib)
        return (s, comp + e)

    def merge(self, a, b):
        """Two-sum the partial sums, pool the compensations + the new
        rounding error — the cross-shard analogue of ``update``."""
        s, e = two_sum(a[0], b[0])
        return (s, a[1] + b[1] + e)

    def finalize(self, carry, ctx) -> jnp.ndarray:
        acc, comp = carry
        return acc + comp


@register_policy
class ExactPolicy(Policy):
    """INTAC fixed point: int32 accumulation, one dequantize per reduction.

    ``prepare`` picks a shared power-of-two scale sized so the *entire*
    stream fits int32 headroom (the paper's a-priori bit-width step), so no
    partial sum can overflow anywhere in the schedule.  Integer addition is
    associative — the result is bitwise independent of backend, block size,
    and device layout.  The headroom-from-scale trade means resolution
    shrinks as 1/N; ``exact2``/``procrastinate`` remove that trade.
    """

    name = "exact"
    acc_dtype = jnp.int32

    def prepare(self, values: jnp.ndarray, num_terms: int):
        v = values.astype(jnp.float32)
        scale = choose_scale(jnp.max(jnp.abs(v)), max(num_terms, 1))
        return quantize(v, scale), scale

    def finalize(self, carry, ctx) -> jnp.ndarray:
        return dequantize(carry[0], ctx)


@register_policy
class Exact2Policy(Policy):
    """Three-limb INTAC carry-save: headroom no longer trades against
    resolution, and "exact" means exact off the dyadic grid too.

    The scale is sized by magnitude alone (``QBITS`` bits below int32, so
    a 512-row block contribution cannot overflow), each block's int32
    contribution splits into (hi, lo) limbs on the way into the carry,
    and the third limb carries what quantization rounded away — the
    per-element residual ``x - descale(quantize(x, scale), scale)``,
    captured *exactly* (Dekker/Sterbenz; see ``core.intac.limb_split3``)
    in ``prepare`` and folded compensated-style (``two_sum`` + pooled
    compensation) through the schedule.  ``core.intac.Limb3State``
    semantics threaded through the block schedule: up to 2^24 rows
    accumulate carry-free; ``finalize`` is one ``limbs_resolve3``.

    Guarantee split: the int32 hi/lo limbs are bitwise independent of
    block size, backend, shard count, and input order (associative
    integer adds + canonical carry-resolve); the finalized float — which
    also folds the residual limb — is within 1 ulp of the f64 reference
    for arbitrary f32 inputs, deterministic for a fixed schedule, but
    drifts at the ulp level when the residual fold order changes (block
    size / shard count / permutation).  Old behavior — silently dropping
    sub-quantum bits of non-dyadic inputs — was a defect, not a contract.
    """

    name = "exact2"
    #: (hi, lo) int32 limbs + (res, comp) compensated f32 residual pair
    carry_len = 4
    acc_dtype = jnp.int32
    #: per-value quantization bits: block contribs stay below int32 for
    #: blocks up to 2^(30-QBITS) = 512 rows
    QBITS = 21
    max_block_size = 1 << (30 - QBITS)
    #: limb headroom: every block adds one lo remainder < 2^15 and one
    #: hi part <= 2^15 to the carries, so the *block count* — not the row
    #: count — is what the int32 limb sums bound: 2^16 blocks is the hard
    #: ceiling; 2^15 keeps a 2x margin (2^24 rows at the max block size,
    #: proportionally fewer for smaller blocks — both guards enforced).
    #: The residual limb adds no bound of its own: per-element residuals
    #: are below half a quantum, so the f32 fold cannot overflow.
    max_blocks = 1 << (30 - intac.LIMB_SHIFT)
    MAX_TERMS = max_block_size * max_blocks
    #: the residual pair merges through an order-pinned two_sum fold;
    #: the integer limbs still psum — see ``merge_across``
    merge_is_add = False

    @property
    def carry_dtypes(self):
        return (jnp.int32, jnp.int32, jnp.float32, jnp.float32)

    def prepare(self, values: jnp.ndarray, num_terms: int):
        if num_terms > self.MAX_TERMS:
            raise ValueError(
                f"exact2: {num_terms} rows exceed the two-limb headroom "
                f"bound ({self.MAX_TERMS}); split the stream and merge "
                f"with core.intac.limb_merge3")
        v = values.astype(jnp.float32)
        scale = choose_scale(jnp.max(jnp.abs(v)), 1, qbits=self.QBITS)
        q = quantize(v, scale)
        res = v - dequantize(q, scale)        # exact: Dekker/Sterbenz
        # one (N, 2D) f32 domain: quantized half | residual half.  The
        # quantized values are below 2^QBITS = 2^21 in magnitude, so the
        # f32 round-trip back to int32 in ``contrib`` is exact.
        return jnp.concatenate([q.astype(jnp.float32), res], axis=1), scale

    def contrib(self, onehot: jnp.ndarray, vals: jnp.ndarray):
        """Two dots per block: the quantized half in exact int32, the
        residual half in f32 (the same dot lowering on every backend)."""
        d = vals.shape[1] // 2
        ci = jnp.dot(onehot.astype(jnp.int32).T,
                     vals[:, :d].astype(jnp.int32),
                     preferred_element_type=jnp.int32)
        cr = jnp.dot(onehot.astype(jnp.float32).T, vals[:, d:],
                     preferred_element_type=jnp.float32)
        return (ci, cr)

    def init(self, num_segments: int, d: int):
        # d is the (N, 2D) domain width: carries are (S, D)
        z = jnp.zeros((num_segments, d // 2), jnp.int32)
        r = jnp.zeros((num_segments, d // 2), jnp.float32)
        return (z, z, r, r)

    def update(self, carry, contrib):
        hi, lo, res, comp = carry
        ci, cr = contrib
        chi, clo = intac.limb_split(ci)
        s, e = two_sum(res, cr)
        return (hi + chi, lo + clo, s, comp + e)

    def merge(self, a, b):
        """Integer limbs add exactly (any order, same bits); the residual
        pair merges through ``two_sum`` with pooled compensation."""
        s, e = two_sum(a[2], b[2])
        return (a[0] + b[0], a[1] + b[1], s, a[3] + b[3] + e)

    def merge_across(self, carry, axis_names):
        """Mixed lowering: one associative int32 psum per integer limb
        (bitwise identical to the single-device schedule at any shard
        count), and an all-gather + strict device-order two_sum fold for
        the residual pair (deterministic; tolerance, not bits) — the one
        shared implementation in ``core.intac.limb3_merge_across``."""
        return intac.limb3_merge_across(*carry, axis_names)

    def finalize(self, carry, ctx) -> jnp.ndarray:
        hi, lo, res, comp = carry
        return intac.limbs_resolve3(hi, lo, res, ctx, comp=comp)


@register_policy
class ProcrastinatePolicy(Policy):
    """Exponent-indexed bin accumulation (Liguori/Neal procrastination).

    ``prepare`` splits every f32 value — exactly — into
    ``intac.NUM_BINS`` signed integer digits of a fixed-point window
    anchored at the stream's maximum exponent, laid out digit-major along
    the feature axis, so the one-hot block matmul accumulates all bins at
    once and the carry is a single (S, NUM_BINS*D) int32 array.  Integer
    bin adds are associative (bitwise order-independent); all rounding
    happens once, in ``finalize``'s carry-resolve + compensated combine.
    Exact to <=1 ulp of the f32 result for arbitrary f32 data up to
    ``intac.BIN_MAX_TERMS`` rows — provided the result is not
    cancellation-dominated: values below max|x| * 2^-24 truncate (once,
    per element) at the window's 2^-48-of-max quantum, so when large
    terms cancel to a tiny residual the error is bounded absolutely
    (N * 2^-49 of the max), not relatively.
    """

    name = "procrastinate"
    acc_dtype = jnp.int32

    def prepare(self, values: jnp.ndarray, num_terms: int):
        if num_terms > intac.BIN_MAX_TERMS:
            raise ValueError(
                f"procrastinate: {num_terms} rows exceed the per-bin "
                f"headroom bound ({intac.BIN_MAX_TERMS}); split the "
                f"stream and add the bin carries")
        v = values.astype(jnp.float32)
        n, d = v.shape
        e_ref = intac.bin_ref_exponent(jnp.max(jnp.abs(v)))
        digits = intac.bin_split(v, e_ref)           # (NB, N, D)
        domain = jnp.moveaxis(digits, 0, 1).reshape(n, intac.NUM_BINS * d)
        return domain, e_ref

    def finalize(self, carry, ctx) -> jnp.ndarray:
        c = carry[0]                                 # (S, NB*D) int32
        s, wd = c.shape
        bins = jnp.moveaxis(c.reshape(s, intac.NUM_BINS,
                                      wd // intac.NUM_BINS), 1, 0)
        return intac.bin_combine(bins, ctx)
