"""Accuracy policies — the first-class knob of ``repro.reduce``.

JugglePAC's fixed-pairing argument says *what order* additions happen in;
the policy says *in what domain* they happen.  Three tiers, all sharing the
same block schedule (so a policy swap never changes the data movement):

  * ``fast``         — plain f32 accumulation over the fixed block tree.
    Deterministic (the schedule depends only on shapes), O(log n) error
    growth, zero overhead.
  * ``compensated``  — Kahan/two-sum carried across blocks: the (S, D)
    accumulator travels with an equally-shaped compensation term that
    captures every cross-block rounding error.  ~f64 accuracy at f32 cost.
  * ``exact``        — INTAC: quantize once to a shared power-of-two scale,
    accumulate in int32 (associative => bitwise identical for *any* block
    size, backend, or device layout), dequantize once per reduction — the
    paper's "pay for normalization once per set".

A policy owns three hooks, each pure and shape-polymorphic:

  ``prepare(values, num_terms)``      -> (domain_values, ctx)
  ``init / update``                   -> the per-block carry (a tuple of
                                         (S, D) arrays all backends thread
                                         identically; the pallas backend
                                         bakes ``update`` into its kernel)
  ``finalize(carry, ctx)``            -> (S, D) f32

New tiers (e.g. Neal superaccumulators, exponent-indexed procrastination)
register with ``@register_policy`` and immediately work on the ``ref`` and
``blocked`` backends; the ``pallas`` backend advertises the policies its
kernels implement via its capability flags.
"""

from __future__ import annotations

from typing import Dict, Tuple

import jax.numpy as jnp

from repro.core.intac import choose_scale, dequantize, quantize

POLICIES: Dict[str, "Policy"] = {}


def register_policy(cls):
    """Class decorator: instantiate and add to the policy registry."""
    inst = cls()
    POLICIES[inst.name] = inst
    return cls


def get_policy(name: str) -> "Policy":
    try:
        return POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown policy {name!r}; registered: "
                         f"{sorted(POLICIES)}") from None


def two_sum(a: jnp.ndarray, b: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Knuth two-sum: s = fl(a+b) and the exact rounding error e.

    a + b == s + e exactly, with no magnitude precondition.  The backends
    must execute these six ops in this order — the error term is the whole
    point, so the expression must never be algebraically simplified.
    """
    s = a + b
    bp = s - a
    e = (a - (s - bp)) + (b - bp)
    return s, e


class Policy:
    """Base accuracy policy.  Subclasses set ``name`` and override hooks."""

    name: str = "?"
    #: number of carry arrays threaded through the block schedule
    carry_len: int = 1
    #: dtype the backends accumulate in (drives kernel specialization)
    acc_dtype = jnp.float32

    def prepare(self, values: jnp.ndarray, num_terms: int):
        """Map raw (N, D) values into the accumulation domain.

        Returns (domain_values, ctx); ctx is passed back to ``finalize``.
        """
        return values.astype(jnp.float32), None

    def init(self, num_segments: int, d: int):
        return (jnp.zeros((num_segments, d), self.acc_dtype),)

    def update(self, carry, contrib):
        return (carry[0] + contrib,)

    def finalize(self, carry, ctx) -> jnp.ndarray:
        return carry[0]


@register_policy
class FastPolicy(Policy):
    """f32 accumulation over the fixed block tree (the default)."""

    name = "fast"


@register_policy
class CompensatedPolicy(Policy):
    """Kahan/two-sum compensated cross-block accumulation."""

    name = "compensated"
    carry_len = 2

    def init(self, num_segments: int, d: int):
        z = jnp.zeros((num_segments, d), jnp.float32)
        return (z, z)

    def update(self, carry, contrib):
        acc, comp = carry
        s, e = two_sum(acc, contrib)
        return (s, comp + e)

    def finalize(self, carry, ctx) -> jnp.ndarray:
        acc, comp = carry
        return acc + comp


@register_policy
class ExactPolicy(Policy):
    """INTAC fixed point: int32 accumulation, one dequantize per reduction.

    ``prepare`` picks a shared power-of-two scale sized so the *entire*
    stream fits int32 headroom (the paper's a-priori bit-width step), so no
    partial sum can overflow anywhere in the schedule.  Integer addition is
    associative — the result is bitwise independent of backend, block size,
    and device layout.
    """

    name = "exact"
    acc_dtype = jnp.int32

    def prepare(self, values: jnp.ndarray, num_terms: int):
        v = values.astype(jnp.float32)
        scale = choose_scale(jnp.max(jnp.abs(v)), max(num_terms, 1))
        return quantize(v, scale), scale

    def init(self, num_segments: int, d: int):
        return (jnp.zeros((num_segments, d), jnp.int32),)

    def finalize(self, carry, ctx) -> jnp.ndarray:
        return dequantize(carry[0], ctx)
