"""Accuracy policies — the first-class knob of ``repro.reduce``.

JugglePAC's fixed-pairing argument says *what order* additions happen in;
the policy says *in what domain* they happen.  (A third layer, the
reduction algebra of ``algebra.py``, says *what is being summed*: ops
like ``weighted_sum``/``moments`` transform rows *before* ``prepare``
sees them, so the integer tiers quantize — and therefore weight — in
their own exact domain, and an op's extra components simply widen the
``domain_width`` every policy already parameterizes over.)  Five tiers,
all sharing the same block schedule (so a policy swap never changes the
data movement):

  * ``fast``          — plain f32 accumulation over the fixed block tree.
    Deterministic (the schedule depends only on shapes), O(log n) error
    growth, zero overhead.
  * ``compensated``   — Kahan/two-sum carried across blocks: the (S, D)
    accumulator travels with an equally-shaped compensation term that
    captures every cross-block rounding error.  ~f64 accuracy at f32 cost.
  * ``exact``         — INTAC: quantize once to a shared power-of-two scale,
    accumulate in int32 (associative => bitwise identical for *any* block
    size, backend, or device layout), dequantize once per reduction — the
    paper's "pay for normalization once per set".  The scale is sized so
    the *whole stream* fits single-limb int32 headroom, so resolution
    shrinks as 1/N: cheap state, but long streams lose precision.
  * ``exact2``        — three-limb all-integer carry-save: the per-block
    contribution splits into (hi, lo) limbs — headroom from the second
    limb instead of the scale — while the third limb carries the
    exactly-captured quantization residual
    ``x - descale(quantize(x, scale), scale)`` as per-element integer
    digit bins (a small superaccumulator, Neal arXiv 1505.05571, at the
    quantum-anchored ``intac.RES_BIN_BITS`` window).  Every carry
    component is an associatively-added int32 array, so the *finalized
    float* — not just the limbs — is bitwise invariant across block
    size, backend, shard count, mesh shape, and input permutation, and
    within 1 ulp of the f64 reference for *arbitrary* f32 inputs at any
    stream length up to 2^24 rows.
  * ``procrastinate`` — exponent-indexed bins after Liguori (arXiv
    2406.05866) / Neal (arXiv 1505.05571): each f32 value splits exactly
    into per-exponent-window integer digits, bins accumulate in int32,
    and *all* rounding procrastinates to one carry-resolve + compensated
    combine in ``finalize``.  Exact to <=1 ulp of the f32 result for any
    stream up to 2^22 rows whose result lands within ~2^24 of the
    largest |value| (the 48-bit window truncates below that, so under
    catastrophic cancellation the bound is absolute — N * 2^-49 of the
    max — not relative), at NUM_BINS x the accumulator state.

The integer tiers are bitwise order-independent end to end: any block
size, backend, input permutation, or device layout produces identical
bits for the ``exact``, ``exact2``, and ``procrastinate`` *results* (all
of their carry state is associatively-added int32, canonicalized once at
finalize).  The integer tiers also carry saturation guard rails: carry
updates run through ``intac.wrap_add`` and pool wrap events into an
overflow counter surfaced via ``carry_status`` (the
``ReduceStatus.saturated`` flag of ``reduce(..., with_status=True)``) —
within the documented ``max_block_size``/``max_blocks``/``max_terms``
bounds the flags provably cannot trip; they are the defense-in-depth
layer for direct ``backend.run`` callers and future tiers.

A policy declares a *staged block-program*, each hook pure and
shape-polymorphic:

  ``prepare_ctx(max_abs, num_terms)`` -> ctx: the finalize context as a
                                         pure function of global stream
                                         statistics (quantization scale,
                                         exponent-window anchor) — shards
                                         that agree on the stats agree on
                                         the grid
  ``to_domain(values, ctx)``          -> elementwise map of raw (N, D)
                                         rows into the accumulation
                                         domain; runs *per shard* on the
                                         distributed path (the stream
                                         never materializes its domain
                                         form on one device)
  ``prepare(values, num_terms)``      -> (domain_values, ctx): the
                                         single-device composition of the
                                         two stages above
  ``contrib(onehot, vals)``           -> the gather stage, dot form: the
                                         (S, W) one-hot matmul(s) mapping
                                         a (B, W) domain block into what
                                         ``update`` folds (policies with a
                                         multi-part domain, e.g. exact2's
                                         quantized + residual halves, run
                                         one dot per part)
  ``contrib_lanes(ids, vals, S)``     -> the gather stage, lane form:
                                         PhasedAccu-style per-lane
                                         scatter-add partial sums folded
                                         in lane order — bitwise equal to
                                         the dot for integer domains
                                         (associativity), a different
                                         rounding order for float ones
  ``init / update``                   -> the carry-update stage (a tuple
                                         of ``carry_len`` arrays all
                                         backends thread identically; the
                                         pallas kernel executes the
                                         gather + update stages inside
                                         its grid loop)
  ``stage_costs(...)``                -> declared per-block byte/flop
                                         hints for the gather (memory-
                                         bound) and update (compute-
                                         bound) stages, consumed by
                                         ``plan_program`` and the
                                         roofline tooling
  ``merge(a, b)``                     -> combine two partial carries
                                         (cross-shard / cross-device); the
                                         combiner the ``shard_map`` backend
                                         folds with (``merge_across`` lifts
                                         it to named-axis collectives,
                                         fusing same-dtype components into
                                         one batched psum)
  ``finalize(carry, ctx)``            -> (S, D) f32

New tiers register with ``@register_policy`` and immediately work on every
schedule-generic backend (``ref``/``blocked``); the ``pallas`` backend
advertises the policies its kernel has been validated for via its
capability flags.  ``update`` must be pure elementwise/jnp ops (it is
traced into the kernel body) and ``init`` must be zeros.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

# Direct submodule import (not ``from repro.core import ...``): this
# module loads while repro.core's __init__ may still be mid-execution
# (core.segmented -> reduce.backends -> here), and intac itself imports
# nothing from repro, so the submodule path always resolves.
import repro.core.intac as intac
from repro.core.intac import (choose_scale, dequantize, quantize,  # noqa: F401
                              two_sum)

POLICIES: Dict[str, "Policy"] = {}

#: lanes the generic lane-parallel contrib splits a block into (the
#: PhasedAccu phase count; each lane owns a contiguous row slice)
LANES_DEFAULT = 4


def fused_psum(arrays, axis_names):
    """One batched ``psum`` per dtype instead of one per array.

    Components of the same dtype ravel-concatenate, reduce in a single
    collective, and split back.  ``psum`` is elementwise, so the fused
    form is bitwise identical to per-component psums — it only collapses
    k collective launches (exact2's four carry components, a gradient
    pytree's many leaves) into one per dtype, which is what keeps the
    shard_map merge off the scaling-critical path.
    """
    arrays = tuple(arrays)
    axes = tuple(axis_names)
    by_dtype: Dict = {}
    for i, a in enumerate(arrays):
        by_dtype.setdefault(jnp.dtype(a.dtype), []).append(i)
    out = [None] * len(arrays)
    for idxs in by_dtype.values():
        if len(idxs) == 1:
            i = idxs[0]
            out[i] = jax.lax.psum(arrays[i], axes)
            continue
        flat = jnp.concatenate([arrays[i].ravel() for i in idxs])
        summed = jax.lax.psum(flat, axes)
        off = 0
        for i in idxs:
            size = arrays[i].size
            out[i] = summed[off:off + size].reshape(arrays[i].shape)
            off += size
    return tuple(out)


def register_policy(cls):
    """Class decorator: instantiate and add to the policy registry.

    The new tier immediately works on every schedule-generic backend
    (``ref``/``blocked``/``shard_map``) — only ``pallas`` gates on its
    validated capability set.

    >>> import jax.numpy as jnp
    >>> import repro
    >>> @register_policy
    ... class _NegatedPolicy(Policy):
    ...     '''Toy tier: accumulate in f32, negate once at finalize.'''
    ...     name = "negated_demo"
    ...     def finalize(self, carry, ctx):
    ...         return -carry[0]
    >>> float(repro.reduce(jnp.arange(4.0), policy="negated_demo"))
    -6.0
    >>> del POLICIES["negated_demo"]          # keep the registry clean
    """
    inst = cls()
    POLICIES[inst.name] = inst
    return cls


def get_policy(name: str) -> "Policy":
    """Look up a registered policy instance by name.

    >>> get_policy("exact2").carry_len
    4
    >>> get_policy("psychic")
    Traceback (most recent call last):
        ...
    ValueError: unknown policy 'psychic'; registered: ['compensated', \
'exact', 'exact2', 'fast', 'procrastinate']
    """
    try:
        return POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown policy {name!r}; registered: "
                         f"{sorted(POLICIES)}") from None


class Policy:
    """Base accuracy policy.  Subclasses set ``name`` and override hooks."""

    name: str = "?"
    #: number of carry arrays threaded through the block schedule
    carry_len: int = 1
    #: dtype the backends accumulate in (drives kernel specialization)
    acc_dtype = jnp.float32
    #: largest schedule block the policy's headroom analysis covers
    #: (None = any); ``reduce`` validates ``block_size`` against it
    max_block_size: Optional[int] = None
    #: largest block *count* the per-block carry headroom covers (None =
    #: any); ``reduce`` validates ceil(n / block_size) against it
    max_blocks: Optional[int] = None
    #: largest total row count the carry headroom covers (None = any);
    #: ``prepare`` raises past it, and ``reduce(..., on_overflow=
    #: "degrade")`` chunks the stream at this bound instead
    max_terms: Optional[int] = None
    #: the next-stronger tier ``reduce(..., on_overflow="degrade")``
    #: re-runs through when this tier reports saturation (None = no
    #: stronger tier; saturation then raises)
    escalation: Optional[str] = None
    #: True when ``merge`` is plain elementwise addition, so a cross-device
    #: carry merge may lower to one batched ``lax.psum`` per carry *dtype*
    #: (the integer tiers: associative, any reduction topology gives the
    #: same bits).  False forces the gathered in-order fold (compensated:
    #: its two-sum merge is order-sensitive, so the fold order is pinned).
    merge_is_add: bool = True
    #: True when ``prepare_ctx`` consumes the stream's max-|value|
    #: statistic (the integer tiers size their scale / window anchor from
    #: it); False lets ``prepare`` skip the max-reduce entirely.
    needs_max_stat: bool = False
    #: rough elementwise-op count of one ``update`` per carry element —
    #: the compute-stage weight in ``stage_costs`` (fast: one add;
    #: compensated: a two_sum; the integer tiers: limb/bin wrap_adds).
    update_ops_per_elem: int = 1

    @property
    def carry_dtypes(self) -> Tuple:
        """dtype of each carry component; uniform ``acc_dtype`` unless a
        policy mixes domains (exact2: int32 limbs + f32 residual pair)."""
        return (self.acc_dtype,) * self.carry_len

    def domain_width(self, d: int) -> int:
        """Column count of the accumulation domain for raw width ``d``
        (exact2/procrastinate widen by their digit-plane count)."""
        return d

    def prepare_ctx(self, max_abs, num_terms: int):
        """Stage 0a: global statistics -> the finalize context.

        A pure function of the stream's max-|value| statistic (``None``
        unless ``needs_max_stat``) and the static row count, so any two
        executors handed the same statistics build the identical context
        — the property that lets the shard_map backend run ``to_domain``
        per shard against one globally-computed ctx and stay bitwise.
        Eagerly raises on streams beyond the tier's headroom bounds.
        """
        return None

    def to_domain(self, values: jnp.ndarray, ctx):
        """Stage 0b: elementwise map of raw (N, D) rows into the
        accumulation domain under a fixed ``ctx``.

        Row-local by contract (no cross-row reductions), so the
        distributed path may apply it shard-by-shard: ``to_domain`` of a
        row slice equals the row slice of ``to_domain`` — bit for bit.
        The domain may be wider than (N, D) — e.g. per-element digit
        splits — as long as ``finalize`` maps the carry back to (S, D).
        """
        return values.astype(jnp.float32)

    def prepare(self, values: jnp.ndarray, num_terms: int, *,
                shared_max=None):
        """Map raw (N, D) values into the accumulation domain.

        Returns (domain_values, ctx); ctx is passed back to ``finalize``.
        The single-device composition of the two staged hooks:
        ``prepare_ctx`` (global statistics -> ctx) then ``to_domain``
        (elementwise).  ``shared_max`` overrides the local max-|value|
        statistic the integer tiers size their scale / window anchor
        from — collectives (``elastic_reduce_mean``) pass a pmax-shared
        global so every shard prepares on the identical grid.
        """
        v = values.astype(jnp.float32)
        m = None
        if self.needs_max_stat:
            m = jnp.max(jnp.abs(v)) if shared_max is None else shared_max
        ctx = self.prepare_ctx(m, num_terms)
        return self.to_domain(v, ctx), ctx

    def contrib(self, onehot: jnp.ndarray, vals: jnp.ndarray):
        """One schedule step: map a (B, S) boolean one-hot and a (B, W)
        domain block to the contribution ``update`` folds.

        Every backend (and the pallas kernel body) builds the same boolean
        one-hot and delegates here, so the dot lowering — and with it the
        cross-backend bitwise contract — is defined once, by the policy.
        """
        return jnp.dot(onehot.astype(vals.dtype).T, vals,
                       preferred_element_type=self.acc_dtype)

    def contrib_lanes(self, ids: jnp.ndarray, vals: jnp.ndarray,
                      num_segments: int, *, seg_offset: int = 0,
                      lanes: int = LANES_DEFAULT):
        """The gather stage in lane form: segment-local per-lane partial
        sums (artiq ``PhasedAccu``), folded strictly in lane order.

        The block's rows split into ``lanes`` contiguous slices; each lane
        scatter-adds its rows into its own (S+1, W) partial (sentinel /
        out-of-tile labels park on the scratch row), and the partials fold
        lane 0 -> lane ``lanes-1``.  Per segment this is the same multiset
        of additions as the one-hot dot, so for integer ``acc_dtype`` the
        result is **bitwise equal** to ``contrib`` (integer addition is
        associative) while skipping the (B, S, W) dot flops — the win when
        the matmul is memory-bound (large S).  For float domains it is a
        *different rounding order* (like the shard_map fast merge):
        explicit opt-in only, never auto-selected.
        """
        b = ids.shape[0]
        v = vals.astype(self.acc_dtype)
        local = ids.reshape(b) - seg_offset
        safe = jnp.where((local >= 0) & (local < num_segments),
                         local, num_segments)
        nl = max(1, min(int(lanes), b))
        bounds = [(k * b) // nl for k in range(nl + 1)]
        total = None
        # detlint: ok[DET002] lane partials: integer domains add
        # associatively (exact); float lanes are the fast tier's
        # documented tolerance (docs/policies.md)
        for k in range(nl):
            lo, hi = bounds[k], bounds[k + 1]
            part = jnp.zeros((num_segments + 1, v.shape[1]),
                             self.acc_dtype).at[safe[lo:hi]].add(
                                 v[lo:hi], mode="drop")
            total = part if total is None else total + part
        return total[:num_segments]

    def stage_costs(self, block_size: int, domain_width: int,
                    num_segments: int, *, contrib: str = "dot") -> Dict:
        """Declared per-block cost hints for the two schedule stages.

        Returns ``{"contrib": {...}, "update": {...}}`` with ``bytes``,
        ``flops``, and the declared ``bound`` ("memory" for the gather /
        contrib stage, "compute" for the carry update) — what
        ``plan_program`` sizes its contrib-mode crossover from and what
        ``benchmarks/roofline.py`` projects onto the hardware roofline.
        Estimates, not measurements: one multiply-add per dot cell, one
        add per scatter cell, ``update_ops_per_elem`` per carry element.
        """
        b, w, s = block_size, domain_width, num_segments
        acc_bytes = jnp.dtype(self.acc_dtype).itemsize
        in_bytes = b * w * 4 + b * 4              # values tile + ids tile
        if contrib == "lanes":
            gather = {"bytes": float(in_bytes + (s + 1) * w * acc_bytes),
                      "flops": float(b * w), "bound": "memory"}
        else:
            gather = {"bytes": float(in_bytes + s * w * acc_bytes),
                      "flops": float(2.0 * b * s * w), "bound": "memory"}
        update = {"bytes": float(2 * self.carry_len * s * w * acc_bytes),
                  "flops": float(self.update_ops_per_elem
                                 * self.carry_len * s * w),
                  "bound": "compute"}
        return {"contrib": gather, "update": update}

    def init(self, num_segments: int, d: int):
        """Zero carry, one (num_segments, d) array per ``carry_dtypes``
        entry; ``d`` is the *domain* width — policies whose carries are
        narrower than their domain (exact2) override."""
        return tuple(jnp.zeros((num_segments, d), dt)
                     for dt in self.carry_dtypes)

    def update(self, carry, contrib):
        return (carry[0] + contrib,)

    def merge(self, a, b):
        """Combine two partial carries (the cross-shard combiner).

        Semantics: ``merge(run(blocks[:k]), run(blocks[k:]))`` must equal
        ``run(blocks)`` — exactly for the integer tiers, to documented
        tolerance for the float tiers.  The default (elementwise add) is
        correct for every policy whose ``update`` is itself an add into
        the carry; order-sensitive carries override it and clear
        ``merge_is_add``.
        """
        return tuple(x + y for x, y in zip(a, b))

    def merge_across(self, carry, axis_names):
        """Merge per-shard carries across mesh axes (inside shard_map).

        The collective face of ``merge``: when ``merge_is_add``, the
        components reduce with one *fused* associative ``lax.psum`` per
        carry dtype (``fused_psum`` — any reduction topology, same bits as
        per-component psums: the integer-tier contract, at one collective
        launch instead of ``carry_len``); otherwise the carries all-gather
        and fold strictly in device order with ``merge``, pinning the
        combine schedule the way the block schedule pins per-shard order.
        """
        axes = tuple(axis_names)
        if self.merge_is_add:
            return fused_psum(carry, axes)
        gathered = tuple(jax.lax.all_gather(c, axes, axis=0) for c in carry)
        nshards = gathered[0].shape[0]
        merged = tuple(g[0] for g in gathered)
        # detlint: ok[DET002] strict device-order merge is the contract:
        # merge chains are two_sum data-dependent or integer-exact
        for k in range(1, nshards):
            merged = self.merge(merged, tuple(g[k] for g in gathered))
        return merged

    def carry_status(self, carry):
        """Saturation guard rail: a scalar bool (True = some integer
        carry wrapped int32 and the result is not trustworthy), or None
        for tiers with no overflow mode (float carries, or a-priori
        scale sizing like ``exact``).  Cheap and jittable — the flags
        are threaded through the carry by ``update``/``merge``, so
        reading them costs one reduction."""
        return None

    def finalize(self, carry, ctx) -> jnp.ndarray:
        return carry[0]


@register_policy
class FastPolicy(Policy):
    """f32 accumulation over the fixed block tree (the default)."""

    name = "fast"


@register_policy
class CompensatedPolicy(Policy):
    """Kahan/two-sum compensated cross-block accumulation."""

    name = "compensated"
    carry_len = 2
    merge_is_add = False            # two-sum merge is order-sensitive
    update_ops_per_elem = 6         # one two_sum + the compensation add

    def update(self, carry, contrib):
        acc, comp = carry
        s, e = two_sum(acc, contrib)
        return (s, comp + e)

    def merge(self, a, b):
        """Two-sum the partial sums, pool the compensations + the new
        rounding error — the cross-shard analogue of ``update``."""
        s, e = two_sum(a[0], b[0])
        return (s, a[1] + b[1] + e)

    def finalize(self, carry, ctx) -> jnp.ndarray:
        acc, comp = carry
        return acc + comp


@register_policy
class ExactPolicy(Policy):
    """INTAC fixed point: int32 accumulation, one dequantize per reduction.

    ``prepare`` picks a shared power-of-two scale sized so the *entire*
    stream fits int32 headroom (the paper's a-priori bit-width step), so no
    partial sum can overflow anywhere in the schedule.  Integer addition is
    associative — the result is bitwise independent of backend, block size,
    and device layout.  The headroom-from-scale trade means resolution
    shrinks as 1/N; ``exact2``/``procrastinate`` remove that trade.
    """

    name = "exact"
    acc_dtype = jnp.int32
    needs_max_stat = True
    #: at saturation (possible only for direct backend.run misuse — the
    #: scale sizing makes overflow unreachable through ``reduce``), the
    #: two-limb tier removes the headroom-vs-resolution trade entirely
    escalation = "exact2"

    def prepare_ctx(self, max_abs, num_terms: int):
        return choose_scale(max_abs, max(num_terms, 1))

    def to_domain(self, values: jnp.ndarray, ctx):
        return quantize(values.astype(jnp.float32), ctx)

    def finalize(self, carry, ctx) -> jnp.ndarray:
        return dequantize(carry[0], ctx)


@register_policy
class Exact2Policy(Policy):
    """Three-limb all-integer INTAC carry-save: headroom no longer trades
    against resolution, "exact" means exact off the dyadic grid too, and
    the finalized float is bitwise invariant at any topology.

    The scale is sized by magnitude alone (``QBITS`` bits below int32, so
    a 512-row block contribution cannot overflow), each block's int32
    contribution splits into (hi, lo) limbs on the way into the carry,
    and the third limb carries what quantization rounded away — the
    per-element residual ``x - descale(quantize(x, scale), scale)``,
    captured *exactly* (Dekker/Sterbenz; see ``core.intac.limb_split3``)
    in ``prepare`` and immediately re-split into
    ``intac.RES_NUM_BINS`` integer digits of the quantum-anchored
    ``intac.RES_BIN_BITS`` superaccumulator window (Neal, arXiv
    1505.05571; the same bin machinery as the procrastinate tier).  All
    three limbs are then associatively-added int32 state: one int32 dot
    per block, up to 2^24 rows carry-free, and ``finalize`` is one
    ``limbs_resolve3_binned`` — a pure function of the canonical integer
    totals and the scale.

    Guarantee: the finalized float — not merely the hi/lo limbs — is
    bitwise independent of block size, backend, shard count, mesh shape,
    and input order, and within 1 ulp of the f64 reference for arbitrary
    f32 inputs (per-element residual truncation below the 49-bit window
    is <= max|x| * 2^-71 per element).  This is what makes elastic
    resume bit-identical: checkpoint on 2 devices, resume on 8, same
    bits.  Saturation guard rail: carry adds run through
    ``intac.wrap_add`` and pool wrap events into the ``ovf`` carry
    (``carry_status`` / ``ReduceStatus.saturated``) — unreachable within
    the enforced row/block bounds, exact at the int32 edge beyond them.
    """

    name = "exact2"
    #: (hi, lo) int32 limbs + binned int32 residual digits + ovf counter
    carry_len = 4
    acc_dtype = jnp.int32
    #: per-value quantization bits: block contribs stay below int32 for
    #: blocks up to 2^(30-QBITS) = 512 rows
    QBITS = 21
    max_block_size = 1 << (30 - QBITS)
    #: limb headroom: every block adds one lo remainder < 2^15, one hi
    #: part <= 2^15, and residual digits <= 2^15 (512 rows x 64 max per
    #: digit) to the carries, so the *block count* — not the row count —
    #: is what the int32 carry sums bound: 2^16 blocks is the hard
    #: ceiling; 2^15 keeps a 2x margin (2^24 rows at the max block size,
    #: proportionally fewer for smaller blocks — both guards enforced).
    max_blocks = 1 << (30 - intac.LIMB_SHIFT)
    MAX_TERMS = max_block_size * max_blocks
    max_terms = MAX_TERMS
    #: past saturation (unreachable through ``reduce``'s bounds), the
    #: procrastinate tier's per-element digits have magnitude-independent
    #: headroom
    escalation = "procrastinate"
    #: every carry component — limbs, residual bins, overflow counter —
    #: adds associatively, so cross-device merges are one int32 psum per
    #: component: bitwise identical at any shard count or mesh shape
    merge_is_add = True

    needs_max_stat = True
    #: two wrap_adds per limb element + the wrap-event pooling
    update_ops_per_elem = 4

    #: domain layout: [q | digit bin 0 | ... | digit bin RES_NUM_BINS-1]
    _PARTS = 1 + intac.RES_NUM_BINS

    @property
    def carry_dtypes(self):
        return (jnp.int32,) * self.carry_len

    def domain_width(self, d: int) -> int:
        return self._PARTS * d

    def prepare_ctx(self, max_abs, num_terms: int):
        if num_terms > self.MAX_TERMS:
            raise ValueError(
                f"exact2: {num_terms} rows exceed the two-limb headroom "
                f"bound ({self.MAX_TERMS}); split the stream and merge "
                f"with core.intac.limb_merge3")
        return choose_scale(max_abs, 1, qbits=self.QBITS)

    def to_domain(self, values: jnp.ndarray, ctx):
        v = values.astype(jnp.float32)
        n, d = v.shape
        scale = ctx
        q = quantize(v, scale)
        res = v - dequantize(q, scale)        # exact: Dekker/Sterbenz
        # the residual in quantum units: |res * scale| <= 1/2, and the
        # power-of-two multiply is exact, so the digit split below loses
        # nothing above the 49-bit window
        digits = intac.bin_split(res * scale, 0, bits=intac.RES_BIN_BITS,
                                 num=intac.RES_NUM_BINS)   # (NB, N, D)
        # one (N, (1+NB)*D) f32 domain: quantized part | digit planes.
        # Every column holds an integer below 2^QBITS (q) or 2^6
        # (digits), so the f32 round-trip back to int32 in ``contrib``
        # is exact and a single int32 dot covers the whole domain.
        planes = jnp.moveaxis(digits, 0, 1).reshape(
            n, intac.RES_NUM_BINS * d)
        return jnp.concatenate([q.astype(jnp.float32), planes], axis=1)

    def contrib(self, onehot: jnp.ndarray, vals: jnp.ndarray):
        """One int32 dot per block over the whole quantized+digits
        domain (the same dot lowering on every backend)."""
        return jnp.dot(onehot.astype(jnp.int32).T, vals.astype(jnp.int32),
                       preferred_element_type=jnp.int32)

    def init(self, num_segments: int, d: int):
        # d is the (N, (1+NB)*D) domain width: limb carries are (S, D)
        dd = d // self._PARTS
        z = jnp.zeros((num_segments, dd), jnp.int32)
        rb = jnp.zeros((num_segments, intac.RES_NUM_BINS * dd), jnp.int32)
        return (z, z, rb, z)

    def update(self, carry, contrib):
        hi, lo, rbins, ovf = carry
        dd = hi.shape[1]
        chi, clo = intac.limb_split(contrib[:, :dd])
        nhi, w1 = intac.wrap_add(hi, chi)
        nlo, w2 = intac.wrap_add(lo, clo)
        nrb, w3 = intac.wrap_add(rbins, contrib[:, dd:])
        wb = w1.astype(jnp.int32) + w2.astype(jnp.int32)
        # detlint: ok[DET002] int32 wrap-flag adds: associative, exact
        for k in range(intac.RES_NUM_BINS):
            wb = wb + w3[:, k * dd:(k + 1) * dd].astype(jnp.int32)
        return (nhi, nlo, nrb, ovf + wb)

    def carry_status(self, carry):
        return jnp.any(carry[3] != 0)

    def finalize(self, carry, ctx) -> jnp.ndarray:
        hi, lo, rbins, _ovf = carry
        s, wd = rbins.shape
        bins = jnp.moveaxis(rbins.reshape(s, intac.RES_NUM_BINS,
                                          wd // intac.RES_NUM_BINS), 1, 0)
        return intac.limbs_resolve3_binned(hi, lo, bins, ctx)


@register_policy
class ProcrastinatePolicy(Policy):
    """Exponent-indexed bin accumulation (Liguori/Neal procrastination).

    ``prepare`` splits every f32 value — exactly — into
    ``intac.NUM_BINS`` signed integer digits of a fixed-point window
    anchored at the stream's maximum exponent, laid out digit-major along
    the feature axis, so the one-hot block matmul accumulates all bins at
    once and the carry is a single (S, NUM_BINS*D) int32 array.  Integer
    bin adds are associative (bitwise order-independent); all rounding
    happens once, in ``finalize``'s carry-resolve + compensated combine.
    Exact to <=1 ulp of the f32 result for arbitrary f32 data up to
    ``intac.BIN_MAX_TERMS`` rows — provided the result is not
    cancellation-dominated: values below max|x| * 2^-24 truncate (once,
    per element) at the window's 2^-48-of-max quantum, so when large
    terms cancel to a tiny residual the error is bounded absolutely
    (N * 2^-49 of the max), not relatively.
    """

    name = "procrastinate"
    #: bin digits + the wrap-event overflow counter
    carry_len = 2
    acc_dtype = jnp.int32
    max_terms = intac.BIN_MAX_TERMS
    needs_max_stat = True
    #: one wrap_add per bin element + the wrap-event pooling
    update_ops_per_elem = 3

    def domain_width(self, d: int) -> int:
        return intac.NUM_BINS * d

    def prepare_ctx(self, max_abs, num_terms: int):
        if num_terms > intac.BIN_MAX_TERMS:
            raise ValueError(
                f"procrastinate: {num_terms} rows exceed the per-bin "
                f"headroom bound ({intac.BIN_MAX_TERMS}); split the "
                f"stream and add the bin carries")
        return intac.bin_ref_exponent(max_abs)

    def to_domain(self, values: jnp.ndarray, ctx):
        v = values.astype(jnp.float32)
        n, d = v.shape
        digits = intac.bin_split(v, ctx)             # (NB, N, D)
        return jnp.moveaxis(digits, 0, 1).reshape(n, intac.NUM_BINS * d)

    def init(self, num_segments: int, d: int):
        # d is the (N, NB*D) domain width: the ovf counter is (S, D)
        return (jnp.zeros((num_segments, d), jnp.int32),
                jnp.zeros((num_segments, d // intac.NUM_BINS), jnp.int32))

    def update(self, carry, contrib):
        bins, ovf = carry
        nb, w = intac.wrap_add(bins, contrib)
        dd = ovf.shape[1]
        wb = jnp.zeros_like(ovf)
        # detlint: ok[DET002] int32 wrap-flag adds: associative, exact
        for k in range(intac.NUM_BINS):
            wb = wb + w[:, k * dd:(k + 1) * dd].astype(jnp.int32)
        return (nb, ovf + wb)

    def carry_status(self, carry):
        return jnp.any(carry[1] != 0)

    def finalize(self, carry, ctx) -> jnp.ndarray:
        c = carry[0]                                 # (S, NB*D) int32
        s, wd = c.shape
        bins = jnp.moveaxis(c.reshape(s, intac.NUM_BINS,
                                      wd // intac.NUM_BINS), 1, 0)
        return intac.bin_combine(bins, ctx)
