"""Accuracy policies — the first-class knob of ``repro.reduce``.

JugglePAC's fixed-pairing argument says *what order* additions happen in;
the policy says *in what domain* they happen.  Five tiers, all sharing the
same block schedule (so a policy swap never changes the data movement):

  * ``fast``          — plain f32 accumulation over the fixed block tree.
    Deterministic (the schedule depends only on shapes), O(log n) error
    growth, zero overhead.
  * ``compensated``   — Kahan/two-sum carried across blocks: the (S, D)
    accumulator travels with an equally-shaped compensation term that
    captures every cross-block rounding error.  ~f64 accuracy at f32 cost.
  * ``exact``         — INTAC: quantize once to a shared power-of-two scale,
    accumulate in int32 (associative => bitwise identical for *any* block
    size, backend, or device layout), dequantize once per reduction — the
    paper's "pay for normalization once per set".  The scale is sized so
    the *whole stream* fits single-limb int32 headroom, so resolution
    shrinks as 1/N: cheap state, but long streams lose precision.
  * ``exact2``        — two-limb int32 carry-save (``core.intac.LimbState``
    semantics): the per-block contribution splits into (hi, lo) limbs, so
    headroom comes from the second limb instead of the scale.  Resolution
    is fixed at ~2^-21 of max |x| for any stream length up to 2^24 rows —
    exact at any N for values on the scale's dyadic grid.
  * ``procrastinate`` — exponent-indexed bins after Liguori (arXiv
    2406.05866) / Neal (arXiv 1505.05571): each f32 value splits exactly
    into per-exponent-window integer digits, bins accumulate in int32,
    and *all* rounding procrastinates to one carry-resolve + compensated
    combine in ``finalize``.  Exact to <=1 ulp of the f32 result for any
    stream up to 2^22 rows whose result lands within ~2^24 of the
    largest |value| (the 48-bit window truncates below that, so under
    catastrophic cancellation the bound is absolute — N * 2^-49 of the
    max — not relative), at NUM_BINS x the accumulator state.

The three integer tiers are bitwise order-independent: any block size,
backend, input permutation, or device layout produces identical bits.

A policy owns four hooks, each pure and shape-polymorphic:

  ``prepare(values, num_terms)``      -> (domain_values, ctx)
  ``init / update``                   -> the per-block carry (a tuple of
                                         ``carry_len`` arrays all backends
                                         thread identically; the pallas
                                         kernel executes ``update`` inside
                                         its grid loop)
  ``merge(a, b)``                     -> combine two partial carries
                                         (cross-shard / cross-device); the
                                         associative combiner the
                                         ``shard_map`` backend folds with
  ``finalize(carry, ctx)``            -> (S, D) f32

New tiers register with ``@register_policy`` and immediately work on every
schedule-generic backend (``ref``/``blocked``); the ``pallas`` backend
advertises the policies its kernel has been validated for via its
capability flags.  ``update`` must be pure elementwise/jnp ops (it is
traced into the kernel body) and ``init`` must be zeros.
"""

from __future__ import annotations

from typing import Dict, Optional

import jax.numpy as jnp

# Direct submodule import (not ``from repro.core import ...``): this
# module loads while repro.core's __init__ may still be mid-execution
# (core.segmented -> reduce.backends -> here), and intac itself imports
# nothing from repro, so the submodule path always resolves.
import repro.core.intac as intac
from repro.core.intac import (choose_scale, dequantize, quantize,  # noqa: F401
                              two_sum)

POLICIES: Dict[str, "Policy"] = {}


def register_policy(cls):
    """Class decorator: instantiate and add to the policy registry.

    The new tier immediately works on every schedule-generic backend
    (``ref``/``blocked``/``shard_map``) — only ``pallas`` gates on its
    validated capability set.

    >>> import jax.numpy as jnp
    >>> import repro
    >>> @register_policy
    ... class _NegatedPolicy(Policy):
    ...     '''Toy tier: accumulate in f32, negate once at finalize.'''
    ...     name = "negated_demo"
    ...     def finalize(self, carry, ctx):
    ...         return -carry[0]
    >>> float(repro.reduce(jnp.arange(4.0), policy="negated_demo"))
    -6.0
    >>> del POLICIES["negated_demo"]          # keep the registry clean
    """
    inst = cls()
    POLICIES[inst.name] = inst
    return cls


def get_policy(name: str) -> "Policy":
    """Look up a registered policy instance by name.

    >>> get_policy("exact2").carry_len
    2
    >>> get_policy("psychic")
    Traceback (most recent call last):
        ...
    ValueError: unknown policy 'psychic'; registered: ['compensated', \
'exact', 'exact2', 'fast', 'procrastinate']
    """
    try:
        return POLICIES[name]
    except KeyError:
        raise ValueError(f"unknown policy {name!r}; registered: "
                         f"{sorted(POLICIES)}") from None


class Policy:
    """Base accuracy policy.  Subclasses set ``name`` and override hooks."""

    name: str = "?"
    #: number of carry arrays threaded through the block schedule
    carry_len: int = 1
    #: dtype the backends accumulate in (drives kernel specialization)
    acc_dtype = jnp.float32
    #: largest schedule block the policy's headroom analysis covers
    #: (None = any); ``reduce`` validates ``block_size`` against it
    max_block_size: Optional[int] = None
    #: largest block *count* the per-block carry headroom covers (None =
    #: any); ``reduce`` validates ceil(n / block_size) against it
    max_blocks: Optional[int] = None
    #: True when ``merge`` is plain elementwise addition, so a cross-device
    #: carry merge may lower to one ``lax.psum`` per carry component (the
    #: integer tiers: associative, any reduction topology gives the same
    #: bits).  False forces the gathered in-order fold (compensated: its
    #: two-sum merge is order-sensitive, so the fold order must be pinned).
    merge_is_add: bool = True

    def prepare(self, values: jnp.ndarray, num_terms: int):
        """Map raw (N, D) values into the accumulation domain.

        Returns (domain_values, ctx); ctx is passed back to ``finalize``.
        The domain may be wider than (N, D) — e.g. per-element digit
        splits — as long as ``finalize`` maps the carry back to (S, D).
        """
        return values.astype(jnp.float32), None

    def init(self, num_segments: int, d: int):
        return (jnp.zeros((num_segments, d), self.acc_dtype),)

    def update(self, carry, contrib):
        return (carry[0] + contrib,)

    def merge(self, a, b):
        """Combine two partial carries (the cross-shard combiner).

        Semantics: ``merge(run(blocks[:k]), run(blocks[k:]))`` must equal
        ``run(blocks)`` — exactly for the integer tiers, to documented
        tolerance for the float tiers.  The default (elementwise add) is
        correct for every policy whose ``update`` is itself an add into
        the carry; order-sensitive carries override it and clear
        ``merge_is_add``.
        """
        return tuple(x + y for x, y in zip(a, b))

    def finalize(self, carry, ctx) -> jnp.ndarray:
        return carry[0]


@register_policy
class FastPolicy(Policy):
    """f32 accumulation over the fixed block tree (the default)."""

    name = "fast"


@register_policy
class CompensatedPolicy(Policy):
    """Kahan/two-sum compensated cross-block accumulation."""

    name = "compensated"
    carry_len = 2
    merge_is_add = False            # two-sum merge is order-sensitive

    def init(self, num_segments: int, d: int):
        z = jnp.zeros((num_segments, d), jnp.float32)
        return (z, z)

    def update(self, carry, contrib):
        acc, comp = carry
        s, e = two_sum(acc, contrib)
        return (s, comp + e)

    def merge(self, a, b):
        """Two-sum the partial sums, pool the compensations + the new
        rounding error — the cross-shard analogue of ``update``."""
        s, e = two_sum(a[0], b[0])
        return (s, a[1] + b[1] + e)

    def finalize(self, carry, ctx) -> jnp.ndarray:
        acc, comp = carry
        return acc + comp


@register_policy
class ExactPolicy(Policy):
    """INTAC fixed point: int32 accumulation, one dequantize per reduction.

    ``prepare`` picks a shared power-of-two scale sized so the *entire*
    stream fits int32 headroom (the paper's a-priori bit-width step), so no
    partial sum can overflow anywhere in the schedule.  Integer addition is
    associative — the result is bitwise independent of backend, block size,
    and device layout.  The headroom-from-scale trade means resolution
    shrinks as 1/N; ``exact2``/``procrastinate`` remove that trade.
    """

    name = "exact"
    acc_dtype = jnp.int32

    def prepare(self, values: jnp.ndarray, num_terms: int):
        v = values.astype(jnp.float32)
        scale = choose_scale(jnp.max(jnp.abs(v)), max(num_terms, 1))
        return quantize(v, scale), scale

    def init(self, num_segments: int, d: int):
        return (jnp.zeros((num_segments, d), jnp.int32),)

    def finalize(self, carry, ctx) -> jnp.ndarray:
        return dequantize(carry[0], ctx)


@register_policy
class Exact2Policy(Policy):
    """Two-limb INTAC carry-save: headroom no longer trades against
    resolution.

    The scale is sized by magnitude alone (``QBITS`` bits below int32, so
    a 512-row block contribution cannot overflow), and each block's int32
    contribution splits into (hi, lo) limbs on the way into the carry —
    ``core.intac.LimbState`` semantics threaded through the block
    schedule.  Up to 2^24 rows accumulate carry-free; ``finalize`` is one
    ``limbs_resolve`` whose integer canonicalization makes the result
    bitwise independent of block size, backend, and input order.
    """

    name = "exact2"
    carry_len = 2
    acc_dtype = jnp.int32
    #: per-value quantization bits: block contribs stay below int32 for
    #: blocks up to 2^(30-QBITS) = 512 rows
    QBITS = 21
    max_block_size = 1 << (30 - QBITS)
    #: limb headroom: every block adds one lo remainder < 2^15 and one
    #: hi part <= 2^15 to the carries, so the *block count* — not the row
    #: count — is what the int32 limb sums bound: 2^16 blocks is the hard
    #: ceiling; 2^15 keeps a 2x margin (2^24 rows at the max block size,
    #: proportionally fewer for smaller blocks — both guards enforced).
    max_blocks = 1 << (30 - intac.LIMB_SHIFT)
    MAX_TERMS = max_block_size * max_blocks

    def prepare(self, values: jnp.ndarray, num_terms: int):
        if num_terms > self.MAX_TERMS:
            raise ValueError(
                f"exact2: {num_terms} rows exceed the two-limb headroom "
                f"bound ({self.MAX_TERMS}); split the stream and merge "
                f"with core.intac.limb_merge")
        v = values.astype(jnp.float32)
        scale = choose_scale(jnp.max(jnp.abs(v)), 1, qbits=self.QBITS)
        return quantize(v, scale), scale

    def init(self, num_segments: int, d: int):
        z = jnp.zeros((num_segments, d), jnp.int32)
        return (z, z)

    def update(self, carry, contrib):
        hi, lo = carry
        chi, clo = intac.limb_split(contrib)
        return (hi + chi, lo + clo)

    def finalize(self, carry, ctx) -> jnp.ndarray:
        hi, lo = carry
        return intac.limbs_resolve(hi, lo, ctx)


@register_policy
class ProcrastinatePolicy(Policy):
    """Exponent-indexed bin accumulation (Liguori/Neal procrastination).

    ``prepare`` splits every f32 value — exactly — into
    ``intac.NUM_BINS`` signed integer digits of a fixed-point window
    anchored at the stream's maximum exponent, laid out digit-major along
    the feature axis, so the one-hot block matmul accumulates all bins at
    once and the carry is a single (S, NUM_BINS*D) int32 array.  Integer
    bin adds are associative (bitwise order-independent); all rounding
    happens once, in ``finalize``'s carry-resolve + compensated combine.
    Exact to <=1 ulp of the f32 result for arbitrary f32 data up to
    ``intac.BIN_MAX_TERMS`` rows — provided the result is not
    cancellation-dominated: values below max|x| * 2^-24 truncate (once,
    per element) at the window's 2^-48-of-max quantum, so when large
    terms cancel to a tiny residual the error is bounded absolutely
    (N * 2^-49 of the max), not relatively.
    """

    name = "procrastinate"
    acc_dtype = jnp.int32

    def prepare(self, values: jnp.ndarray, num_terms: int):
        if num_terms > intac.BIN_MAX_TERMS:
            raise ValueError(
                f"procrastinate: {num_terms} rows exceed the per-bin "
                f"headroom bound ({intac.BIN_MAX_TERMS}); split the "
                f"stream and add the bin carries")
        v = values.astype(jnp.float32)
        n, d = v.shape
        e_ref = intac.bin_ref_exponent(jnp.max(jnp.abs(v)))
        digits = intac.bin_split(v, e_ref)           # (NB, N, D)
        domain = jnp.moveaxis(digits, 0, 1).reshape(n, intac.NUM_BINS * d)
        return domain, e_ref

    def finalize(self, carry, ctx) -> jnp.ndarray:
        c = carry[0]                                 # (S, NB*D) int32
        s, wd = c.shape
        bins = jnp.moveaxis(c.reshape(s, intac.NUM_BINS,
                                      wd // intac.NUM_BINS), 1, 0)
        return intac.bin_combine(bins, ctx)
