"""The staged block-program: what a backend actually executes.

JugglePAC's thesis is that a fixed schedule plus *overlap* keeps the
adder busy; this module is where the repo's schedule stops being an
implicit convention buried in each backend and becomes a declared,
pipelineable program.  A ``BlockProgram`` names, per schedule block, the
two stages every executor runs:

  * **contrib** (the gather stage, memory-bound) — map a (B, W) domain
    tile + its (B,) labels into the (S, W) per-block contribution, in one
    of two forms the policy declares:

      - ``"dot"``   — the one-hot matmul ``onehot(ids).T @ vals``
        (``Policy.contrib``): MXU-friendly, but its flops grow with
        B*S*W, so at large label counts it drowns in work the scatter
        form skips;
      - ``"lanes"`` — PhasedAccu-style per-lane scatter-add partial sums
        folded in lane order (``Policy.contrib_lanes``): O(B*W) adds.
        **Bitwise equal to the dot for integer domains** (associative
        int32 addition — same multiset of adds per segment), a different
        rounding order for float domains, so float tiers only run it on
        explicit opt-in.

  * **update** (the carry stage, compute-bound) — fold the contribution
    into the policy carry (``Policy.update``), strictly in stream order.

Because the stages are declared — with per-block byte/flop cost hints
from ``Policy.stage_costs`` — executors know what to overlap: the pallas
kernel prefetches block i+1's tiles while ``update`` folds block i
(see ``kernels/jugglepac_segsum.py``), and ``plan_program`` picks the
contrib form from the cost model instead of hard-coding the matmul.

``plan_program(policy, ...)`` is the one planner: every backend executes
whatever program it returns, so the contrib-mode decision — like the
block schedule itself — is made once, above the executor.
"""

from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from .policy import LANES_DEFAULT, Policy, get_policy

#: contrib-mode crossover: below this label count the one-hot dot wins
#: (it is one dense MXU op); at and above it the dot's B*S*W flops cost
#: more than the scatter's B*W adds even off-accelerator.  Measured on
#: the int32 tiers (W=128, B=512) the crossover sits near S~16-24; 32 is
#: the conservative side of it.
LANE_MIN_SEGMENTS = 32


@dataclasses.dataclass(frozen=True)
class BlockStage:
    """One declared stage of the per-block program.

    ``bound`` is the stage's declared roofline regime ("memory" for the
    gather/contrib stage, "compute" for the carry update); ``bytes`` and
    ``flops`` are the per-block cost hints from ``Policy.stage_costs``.
    """

    name: str
    bound: str
    bytes: float
    flops: float


@dataclasses.dataclass(frozen=True)
class BlockProgram:
    """A planned, staged execution of the block schedule — frozen and
    hashable, so it rides through jit static args like ``ReduceSpec``.

    ``contrib`` is the resolved gather form ("dot" | "lanes"); ``stages``
    carries the declared cost hints for this (policy, shape) pair.  The
    program never changes *what* is computed for integer-domain policies
    (both contrib forms produce bitwise-identical contributions there) —
    it changes how the same schedule maps onto the hardware.
    """

    policy: str
    contrib: str                      # "dot" | "lanes"
    lanes: int
    block_size: int
    num_segments: int
    domain_width: int
    stages: Tuple[BlockStage, ...]
    #: the algebra op this plan serves ("sum" unless the front door says
    #: otherwise).  The op's cost is already folded into the stage hints
    #: — its ``pre`` widens ``domain_width`` by ``components`` (moments'
    #: [v | v*v] planes double every byte/flop figure below), which is
    #: exactly how the kernel's supertile sizing sees it too — so the
    #: field is the planner's provenance record for roofline/debug
    #: output, never a behavioral switch.
    op: str = "sum"

    def stage(self, name: str) -> BlockStage:
        for s in self.stages:
            if s.name == name:
                return s
        raise KeyError(f"block program has no stage {name!r}; "
                       f"stages: {[s.name for s in self.stages]}")


def plan_program(policy, *, num_segments: int, domain_width: int,
                 block_size: int = 512, contrib: str = "auto",
                 lanes: int = LANES_DEFAULT, op: str = "sum") -> BlockProgram:
    """Plan the staged block-program for one (policy, shape) pair.

    ``contrib="auto"`` applies the cost model: integer-domain policies
    switch to the lane-parallel scatter form once ``num_segments``
    crosses ``LANE_MIN_SEGMENTS`` (where the one-hot dot's B*S*W flops
    make it the slower *and* still memory-bound stage) — a pure
    performance decision, bitwise-invisible by associativity.  Float
    tiers always plan the dot under "auto"; ``contrib="lanes"`` forces
    the lane form anywhere (for float domains that is a documented
    rounding-order change, exactly like the shard_map fast merge).

    >>> prog = plan_program(get_policy("exact2"), num_segments=64,
    ...                     domain_width=128, block_size=512)
    >>> prog.contrib, prog.stage("contrib").bound
    ('lanes', 'memory')
    >>> plan_program(get_policy("fast"), num_segments=64,
    ...              domain_width=16).contrib
    'dot'
    """
    if isinstance(policy, str):
        policy = get_policy(policy)
    if contrib not in ("auto", "dot", "lanes"):
        raise ValueError(f"contrib must be 'auto', 'dot', or 'lanes', "
                         f"got {contrib!r}")
    if contrib == "auto":
        integer_domain = jnp.issubdtype(policy.acc_dtype, jnp.integer)
        contrib = ("lanes" if integer_domain
                   and num_segments >= LANE_MIN_SEGMENTS else "dot")
    costs = policy.stage_costs(block_size, domain_width, num_segments,
                               contrib=contrib)
    stages = tuple(BlockStage(name=name, bound=c["bound"],
                              bytes=c["bytes"], flops=c["flops"])
                   for name, c in costs.items())
    return BlockProgram(policy=policy.name, contrib=contrib,
                        lanes=int(lanes), block_size=int(block_size),
                        num_segments=int(num_segments),
                        domain_width=int(domain_width), stages=stages,
                        op=str(op))


def block_contrib(vals, ids, num_segments: int, policy: Policy,
                  program: BlockProgram = None, *, seg_offset: int = 0):
    """Execute the program's gather stage for one (B, W) block.

    The one shared implementation behind ref, blocked, and the pallas
    kernel body: with no program (or ``contrib="dot"``) it builds the
    (B, S) boolean one-hot exactly the way the kernel does — ids as a
    (B, 1) column against a (1, S) label row — and delegates the dot
    lowering to ``policy.contrib``; with ``contrib="lanes"`` it runs the
    policy's lane-parallel scatter form instead.  Keeping both forms
    here, written once, is what makes the cross-backend bitwise contract
    hold per (policy, program) rather than per backend.
    """
    if program is not None and program.contrib == "lanes":
        return policy.contrib_lanes(ids, vals, num_segments,
                                    seg_offset=seg_offset,
                                    lanes=program.lanes)
    # broadcasted_iota, not arange: this exact line also runs inside the
    # pallas kernel body, where 1-D iota does not lower on TPU
    labels = jax.lax.broadcasted_iota(
        jnp.int32, (1, num_segments), 1) + seg_offset
    return policy.contrib(ids[:, None] == labels, vals)
