"""The front door: ``repro.reduce(...)`` and ``ReduceSpec``.

One call for every reduction in the repo — segmented or whole-stream,
any registered op of the reduction algebra (``sum`` / ``mean`` /
``weighted_sum`` / ``sumsq`` / ``moments`` / ``poly`` — see
``repro.reduce.algebra``), any accuracy policy, any executor:

    from repro import reduce
    out = reduce(values)                                   # (N, D) -> (D,)
    out = reduce(values, segment_ids=ids, num_segments=8)  # -> (8, D)
    out = reduce(values, segment_ids=ids, num_segments=8,
                 op="mean", policy="exact", backend="pallas")
    out = reduce(values, op="weighted_sum", weights=w, policy="exact2")

The paper's contract is preserved end to end: one in-order result per
variable-length set, a fixed pairing schedule (results depend only on
shapes, never on the executor), bounded accumulator state.

``ReduceSpec`` captures everything static about a reduction (op, policy,
backend, block size) in one frozen, hashable value — build it once, reuse
it across calls and jit boundaries, and the dispatch cache keys on it
directly.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from ..core import intac
from .algebra import get_op
from .backends import (OUT_OF_RANGE_LABEL, ambient_mesh, default_mesh,
                       get_backend, mask_out_of_range, select_backend)
from .policy import get_policy
from .program import plan_program


@dataclasses.dataclass(frozen=True)
class ReduceSpec:
    """Static description of a reduction — hashable, so jit-cache-friendly.

    ``backend=None`` means auto-select (shard_map under a multi-device
    mesh, TPU kernel on TPU, scanned blocks elsewhere); ``interpret=None``
    lets the pallas backend decide.  Build one spec, reuse it across calls
    and jit boundaries:

    >>> spec = ReduceSpec(op="mean", policy="exact2", backend="blocked")
    >>> spec.replace(op="sum").op
    'sum'
    >>> spec == ReduceSpec(op="mean", policy="exact2", backend="blocked")
    True
    """

    op: str = "sum"                   # any op in algebra.REDUCE_OPS
    policy: str = "fast"              # any registered policy name
    backend: Optional[str] = None
    block_size: int = 512
    interpret: Optional[bool] = None
    #: static coefficients for coefficient-taking ops (``op="poly"``'s
    #: ascending polynomial); a tuple so the spec stays hashable and the
    #: weights trace as constants under jit
    coeffs: Optional[tuple] = None
    #: gather-stage form of the staged block-program: "auto" lets
    #: ``plan_program``'s cost model pick (lane-parallel scatter for
    #: integer tiers at large label counts — bitwise-invisible by
    #: associativity; the one-hot dot otherwise), "dot"/"lanes" force a
    #: form.  "lanes" on a float tier is a documented rounding-order
    #: change (like the shard_map fast merge), never auto-selected.
    contrib: str = "auto"

    def __post_init__(self):
        op = get_op(self.op)                         # validate eagerly
        if self.coeffs is not None:
            if not op.takes_coeffs:
                raise ValueError(f"op {self.op!r} takes no coeffs")
            object.__setattr__(self, "coeffs",
                               tuple(float(c) for c in self.coeffs))
        if self.contrib not in ("auto", "dot", "lanes"):
            raise ValueError(f"contrib must be 'auto', 'dot', or 'lanes', "
                             f"got {self.contrib!r}")
        get_policy(self.policy)                      # validate eagerly
        if self.backend is not None:
            get_backend(self.backend)

    def replace(self, **kw) -> "ReduceSpec":
        return dataclasses.replace(self, **kw)


class ReduceStatus(NamedTuple):
    """Guard-rail flags for one reduction, returned by
    ``reduce(..., with_status=True)``.

    All fields are scalar jax arrays (jit-friendly; force with ``bool()``/
    ``int()`` only outside traced code):

    * ``nonfinite`` — True iff any *kept* row (in-range segment label)
      carried a NaN/Inf payload.  Sentinel-dropped rows are zeroed before
      any tier sees them, so their payloads can never poison a result —
      and never trip this flag.
    * ``saturated`` — True iff the policy's integer carry wrapped (int32
      limb saturation, procrastinate bin overflow).  Within the eager
      bounds ``reduce`` enforces (``max_terms`` / ``max_blocks``) the
      headroom analysis makes this impossible; it exists as defense in
      depth for direct ``backend.run`` callers and for escalation in
      ``on_overflow="degrade"``.
    * ``degraded`` — True iff ``on_overflow="degrade"`` re-planned the
      reduction (chunked the stream, or escalated to a stronger tier).
    * ``kept_rows`` — int32 count of in-range rows that entered the sum.

    The contract: ``saturated`` is False whenever the finalized value is
    the canonical one, and trips exactly when an int32 carry component
    wrapped (see the boundary tests in ``tests/test_core.py``).
    """

    nonfinite: jnp.ndarray
    saturated: jnp.ndarray
    degraded: jnp.ndarray
    kept_rows: jnp.ndarray


def _status_false() -> ReduceStatus:
    return ReduceStatus(jnp.asarray(False), jnp.asarray(False),
                        jnp.asarray(False), jnp.asarray(0, jnp.int32))


@functools.partial(jax.jit, static_argnames=("spec", "num_segments",
                                             "segmented", "squeeze_d",
                                             "mesh", "axis_names",
                                             "with_status"))
def _dispatch(values, segment_ids, *, spec: ReduceSpec, num_segments: int,
              segmented: bool, squeeze_d: bool, mesh=None, axis_names=None,
              with_status: bool = False):
    policy = get_policy(spec.policy)
    op_ = get_op(spec.op)
    # values arrive already transformed by the op's ``pre`` (``reduce``
    # ran it before the jit boundary), so ``d`` here is the op-widened
    # stream width (components * raw D) and everything below — domain
    # planning, the kernels, the shard merges — is op-agnostic.
    n, d = values.shape
    # ``reduce`` resolved backend=None before the jit boundary, so specs
    # arriving here are concrete; keep the fallback for direct callers.
    backend = (get_backend(spec.backend) if spec.backend is not None
               else select_backend(policy))
    if not backend.supports(policy):
        raise ValueError(f"backend {backend.name!r} does not implement "
                         f"policy {policy.name!r} "
                         f"(capabilities: {sorted(backend.policies)})")
    if policy.max_block_size and spec.block_size > policy.max_block_size:
        raise ValueError(
            f"policy {policy.name!r} admits blocks of at most "
            f"{policy.max_block_size} rows (its integer-headroom bound); "
            f"got block_size={spec.block_size}")
    nb = -(-n // spec.block_size)
    if policy.max_blocks and nb > policy.max_blocks:
        raise ValueError(
            f"policy {policy.name!r} admits at most {policy.max_blocks} "
            f"schedule blocks (its per-block carry headroom), but "
            f"{n} rows at block_size={spec.block_size} need {nb}; "
            f"raise block_size or split the stream")

    status = _status_false() if with_status else None
    if n == 0:
        # empty stream: identity on every backend (the pallas grid cannot
        # be empty, and exact's max-abs pass needs at least one row)
        out = jnp.zeros((num_segments, d), jnp.float32)
    else:
        segment_ids = mask_out_of_range(segment_ids, num_segments)
        # zero dropped rows' payloads too: the one-hot schedule ignores
        # them regardless, but policy.prepare must not see them (e.g. the
        # exact policy sizes its quantization scale from max |value| — a
        # huge sentinel-labeled row would poison the scale for kept rows)
        values = jnp.where((segment_ids >= 0)[:, None], values,
                           jnp.zeros((), values.dtype))
        if with_status:
            # post-mask, so a NaN/Inf in a *dropped* row never trips the
            # flag (it provably never enters any tier either)
            status = status._replace(
                nonfinite=jnp.logical_not(jnp.all(jnp.isfinite(values))),
                kept_rows=jnp.sum((segment_ids >= 0).astype(jnp.int32)))
        run_kw = ({"mesh": mesh, "axis_names": axis_names}
                  if backend.distributed else {})
        if backend.staged:
            # plan the staged block-program once, above the executor: the
            # contrib mode (one-hot dot vs lane-parallel scatter) and the
            # stage cost hints are a (policy, shape) decision, not a
            # backend one
            run_kw["program"] = plan_program(
                policy, num_segments=num_segments,
                domain_width=policy.domain_width(d),
                block_size=spec.block_size, contrib=spec.contrib,
                op=spec.op)
        if backend.staged and backend.distributed:
            # the staged distributed path: compute only the global
            # statistic here (one max-reduce), hand the *raw* rows to the
            # backend, and let every shard run the elementwise
            # ``to_domain`` on its own slice against the shared ctx —
            # bit-identical to whole-stream prepare (to_domain is
            # row-local), but the expensive digitization parallelizes and
            # the narrow raw rows are what crosses the sharding boundary.
            v32 = values.astype(jnp.float32)
            m = (jnp.max(jnp.abs(v32)) if policy.needs_max_stat else None)
            ctx = policy.prepare_ctx(m, n)
            prep = () if ctx is None else (ctx,)

            def _to_domain(v, *p):
                return policy.to_domain(v, p[0] if p else None)

            carry = backend.run(v32, segment_ids, num_segments,
                                policy=policy, block_size=spec.block_size,
                                interpret=spec.interpret,
                                to_domain=_to_domain, prep_state=prep,
                                **run_kw)
        else:
            domain, ctx = policy.prepare(values, n)
            carry = backend.run(domain, segment_ids, num_segments,
                                policy=policy, block_size=spec.block_size,
                                interpret=spec.interpret, **run_kw)
        if with_status:
            sat = policy.carry_status(carry)
            if sat is not None:
                status = status._replace(saturated=sat)
        out = policy.finalize(carry, ctx)            # (S, D) f32

    cnt = None
    if op_.needs_count:
        if n > 0:
            # Counts: exact integers, so a single scatter-add is bitwise-
            # identical to running the block schedule again at a fraction
            # of the cost, and backend-independent by construction.
            # Accumulate in int32 — an f32 count buffer silently saturates
            # at 2^24 (adding 1.0 to 16777216.0 is a no-op) — and cast
            # once for the divide.  segment_ids is already sentinel-
            # masked; park dropped rows on a scratch row.
            ids_safe = jnp.where(segment_ids >= 0, segment_ids,
                                 num_segments)
            cnt = jnp.zeros((num_segments + 1, 1), jnp.int32) \
                .at[ids_safe].add(1, mode="drop")[:num_segments]   # (S, 1)
        else:
            cnt = jnp.zeros((num_segments, 1), jnp.int32)
    out = op_.post(out, cnt)

    if not segmented:
        out = out[0]
    if squeeze_d:
        out = out[..., 0]
    return (out, status) if with_status else out


def _chunk_limit(policy, block_size: int) -> int:
    """Largest block-aligned row count that satisfies every eager headroom
    bound of ``policy`` at this ``block_size``."""
    limit = policy.max_terms
    if policy.max_blocks:
        cap = policy.max_blocks * block_size
        limit = cap if limit is None else min(limit, cap)
    return max(block_size, (limit // block_size) * block_size)


def _reduce_degrade(values, segment_ids, *, spec: ReduceSpec,
                    num_segments: int, segmented: bool, squeeze_d: bool,
                    mesh, axis_names):
    """The ``on_overflow="degrade"`` planner (eager only).

    Streams beyond the policy's headroom bounds are split into bound-sized
    chunks in stream order; chunk sums are folded with a compensated
    (two_sum) accumulator, so the degraded result stays within ulp-level
    error of the unchunked one.  A tripped saturation flag escalates the
    whole reduction to ``policy.escalation`` (the next-stronger tier).
    Returns ``(out, ReduceStatus)``.
    """
    policy = get_policy(spec.policy)
    op_ = get_op(spec.op)        # values already carry the op's ``pre``
    n, d = values.shape
    nb = -(-n // spec.block_size)
    over = bool((policy.max_terms is not None and n > policy.max_terms)
                or (policy.max_blocks and nb > policy.max_blocks))
    sum_spec = spec.replace(op="sum")
    run = functools.partial(_dispatch, spec=sum_spec,
                            num_segments=num_segments, segmented=True,
                            squeeze_d=False, mesh=mesh,
                            axis_names=axis_names, with_status=True)
    degraded = over
    if over:
        chunk = _chunk_limit(policy, spec.block_size)
        acc = jnp.zeros((num_segments, d), jnp.float32)
        comp = jnp.zeros_like(acc)
        status = _status_false()
        # detlint: ok[DET002] eager-only degrade fold: runs outside jit
        # at dispatch boundaries, XLA never sees the cross-chunk chain
        for i in range(0, n, chunk):
            part, st = run(values[i:i + chunk], segment_ids[i:i + chunk])
            acc, err = intac.two_sum(acc, part)
            comp = comp + err
            status = ReduceStatus(
                jnp.logical_or(status.nonfinite, st.nonfinite),
                jnp.logical_or(status.saturated, st.saturated),
                status.degraded, status.kept_rows + st.kept_rows)
        out = acc + comp
    else:
        out, status = run(values, segment_ids)

    if bool(status.saturated):
        if policy.escalation is None:
            raise OverflowError(
                f"policy {policy.name!r} saturated an int32 carry and has "
                f"no stronger tier to escalate to; split the stream")
        out, status = _reduce_degrade(
            values, segment_ids, spec=spec.replace(policy=policy.escalation),
            num_segments=num_segments, segmented=segmented,
            squeeze_d=squeeze_d, mesh=mesh, axis_names=axis_names)
        return out, status._replace(degraded=jnp.asarray(True))
    cnt = None
    if op_.needs_count:
        if n > 0:
            # same exact-integer count scheme as _dispatch, over the full
            # stream (bitwise independent of the chunking)
            mids = mask_out_of_range(segment_ids, num_segments)
            ids_safe = jnp.where(mids >= 0, mids, num_segments)
            cnt = jnp.zeros((num_segments + 1, 1), jnp.int32) \
                .at[ids_safe].add(1, mode="drop")[:num_segments]
        else:
            cnt = jnp.zeros((num_segments, 1), jnp.int32)
    out = op_.post(out, cnt)

    status = status._replace(
        degraded=jnp.logical_or(status.degraded, jnp.asarray(degraded)))
    if not segmented:
        out = out[0]
    if squeeze_d:
        out = out[..., 0]
    return out, status


def reduce(values, *, segment_ids=None, num_segments: Optional[int] = None,
           op: str = "sum", policy: str = "fast",
           backend: Optional[str] = None, block_size: int = 512,
           contrib: str = "auto",
           interpret: Optional[bool] = None,
           weights=None, coeffs=None,
           mesh=None, axis_names=None,
           spec: Optional[ReduceSpec] = None,
           with_status: bool = False,
           on_overflow: str = "raise") -> jnp.ndarray:
    """Reduce a value stream, optionally partitioned into labeled sets.

    Args:
      values: (N,) or (N, D) array; any float dtype (accumulation is f32
        or exact int32 per ``policy``; the result is f32).
      segment_ids: optional (N,) int labels.  Rows labeled outside
        [0, num_segments) — including the repo-wide padding sentinel
        ``OUT_OF_RANGE_LABEL`` — are dropped from sums *and* counts.
      num_segments: static label-space size; required with ``segment_ids``.
      op: any op of the reduction algebra (``repro.reduce.algebra``) —
        "sum", "mean" (counts only in-range rows), "weighted_sum"
        (requires ``weights``), "sumsq", "moments" (per-segment
        (mean, var) via one double-width pass; adds a leading size-2
        statistic axis to the result), or "poly" (requires ``coeffs``;
        time-index polynomial weighting).  The op's row-local ``pre``
        runs before dispatch, so every accuracy tier folds the
        transformed rows in its own domain and every backend/shard/
        degrade guarantee applies unchanged.
      policy: accuracy tier — "fast", "compensated", "exact", "exact2",
        or "procrastinate" (see ``repro.reduce.policy`` for the ladder).
      backend: executor — "ref", "blocked", "pallas", "shard_map", or
        None to auto-select (shard_map under a multi-device mesh, the
        TPU kernel on TPU, blocked elsewhere).
      block_size: rows per schedule block (the paper's cycle granularity).
      contrib: gather-stage form for the staged block-program — "auto"
        (default: the planner's cost model, which picks the lane-parallel
        scatter for integer-domain tiers at large label counts, a
        bitwise-invisible swap), "dot" (always the one-hot matmul), or
        "lanes" (force the scatter form; for float tiers this is a
        documented rounding-order change).  See ``repro.reduce.program``.
      interpret: force/forbid pallas interpret mode (None = auto).
      weights: (N,) or (N, 1) per-row weights for weight-taking ops
        (``op="weighted_sum"``).  Applied row-locally before dispatch;
        sentinel-labeled rows drop out exactly as their values do.
      coeffs: ascending polynomial coefficients for coefficient-taking
        ops (``op="poly"``); static — becomes ``ReduceSpec.coeffs``.
      mesh: the device mesh for a distributed backend; None uses the
        ambient ``with mesh:`` context, else one flat axis over every
        visible device.  Rejected for single-device backends.  Note the
        ambient mesh only steers *auto-selection* for top-level (eager)
        calls — inside jit/shard_map-traced code pass ``mesh=`` (or
        ``backend="shard_map"``) explicitly; see ``select_backend``.
      axis_names: mesh axes to shard the stream over (default: all of
        the mesh's axes); only meaningful with a distributed backend.
      spec: a prebuilt ``ReduceSpec``; overrides the per-call knobs above
        (``mesh``/``axis_names`` are environment, not spec, and still
        apply).
      with_status: also return a ``ReduceStatus`` (NaN/Inf in kept rows,
        int32 carry saturation, degradation, kept-row count).  Static, so
        ``False`` (the default) costs the hot path nothing.
      on_overflow: "raise" (default) rejects streams beyond the policy's
        integer-headroom bounds with an eager ``ValueError``; "degrade"
        re-plans instead — over-bound streams are chunked and folded with
        a compensated accumulator, and a saturated carry escalates to the
        policy's next-stronger tier (``Policy.escalation``).  Degradation
        is eager-only (it inspects runtime flags), and is reported via
        ``ReduceStatus.degraded``.

    Returns:
      f32 array: (num_segments, D) / (num_segments,) when segmented,
      (D,) / scalar otherwise.  With ``with_status=True``, a tuple
      ``(result, ReduceStatus)``.

    >>> import jax.numpy as jnp
    >>> from repro.reduce import reduce
    >>> float(reduce(jnp.arange(4.0)))                       # whole stream
    6.0
    >>> out = reduce(jnp.arange(6.0),                        # three sets
    ...              segment_ids=jnp.asarray([0, 0, 1, 1, 1, 2]),
    ...              num_segments=3)
    >>> [float(v) for v in out]
    [1.0, 9.0, 5.0]
    >>> float(reduce(jnp.arange(6.0), policy="exact2",       # multi-device
    ...              backend="shard_map"))
    15.0
    >>> out, status = reduce(jnp.arange(4.0), policy="exact2",
    ...                      with_status=True)
    >>> (float(out), bool(status.nonfinite), bool(status.saturated),
    ...  int(status.kept_rows))
    (6.0, False, False, 4)
    >>> float(reduce(jnp.asarray([1.0, 2.0, 3.0]), op="weighted_sum",
    ...              weights=jnp.asarray([1.0, 0.5, 2.0]),
    ...              policy="exact2"))                    # 1 + 1 + 6
    8.0
    >>> mv = reduce(jnp.asarray([1.0, 3.0]), op="moments")  # (mean, var)
    >>> [float(v) for v in mv]
    [2.0, 1.0]
    >>> float(reduce(jnp.ones(4), op="poly", coeffs=(0.0, 1.0)))  # sum i
    6.0
    """
    if on_overflow not in ("raise", "degrade"):
        raise ValueError(f"on_overflow must be 'raise' or 'degrade', "
                         f"got {on_overflow!r}")
    if spec is None:
        spec = ReduceSpec(op=op, policy=policy, backend=backend,
                          block_size=block_size, contrib=contrib,
                          interpret=interpret, coeffs=coeffs)
    elif coeffs is not None and spec.coeffs is None:
        spec = spec.replace(coeffs=coeffs)
    # Resolve auto-selection and the mesh *before* the jit boundary: the
    # dispatch cache keys on the concrete (spec, mesh, axis_names), so an
    # activated-then-deactivated ambient mesh can never serve a stale
    # cached executor choice.
    pol = get_policy(spec.policy)
    auto = spec.backend is None
    bk = (select_backend(pol, mesh=mesh) if auto
          else get_backend(spec.backend))
    spec = spec if spec.backend == bk.name else spec.replace(backend=bk.name)
    if bk.distributed:
        if mesh is None:
            mesh = ambient_mesh() or default_mesh()
        if axis_names is not None:
            axis_names = tuple(axis_names)
    elif auto:
        # auto-selection declined the mesh (single device, or unsupported
        # policy): run the local backend.  A 1-device mesh dropping to the
        # local path is the intended "scale if useful" contract, but
        # explicit axis_names state distributed intent — refuse rather
        # than silently reduce on one device.
        if axis_names is not None:
            raise ValueError(
                "axis_names was given but backend auto-selection chose a "
                "single-device executor (no multi-device mesh in reach); "
                "pass backend='shard_map' and/or a multi-device mesh")
        mesh = None
    elif mesh is not None or axis_names is not None:
        raise ValueError(f"backend {bk.name!r} is single-device; mesh/"
                         f"axis_names only apply to distributed backends "
                         f"(e.g. 'shard_map')")
    values = jnp.asarray(values)
    if values.ndim not in (1, 2):
        raise ValueError(f"values must be (N,) or (N, D), "
                         f"got shape {values.shape}")
    squeeze_d = values.ndim == 1
    if squeeze_d:
        values = values[:, None]

    # The algebra's one interception point: run the op's row-local
    # ``pre`` here, above the jit boundary and above every executor, so
    # the dispatch/degrade/shard machinery below only ever sees a plain
    # (possibly wider) sum of the transformed rows.
    op_ = get_op(spec.op)
    if op_.requires_weights and weights is None:
        raise ValueError(f"op {spec.op!r} requires per-row weights=")
    if weights is not None and not op_.takes_weights:
        raise ValueError(f"op {spec.op!r} takes no weights")
    if op_.requires_coeffs and spec.coeffs is None:
        raise ValueError(f"op {spec.op!r} requires coeffs=")
    if weights is not None:
        weights = jnp.asarray(weights)
        if weights.ndim == 2 and weights.shape[-1] == 1:
            weights = weights[:, 0]
        if weights.ndim != 1 or weights.shape[0] != values.shape[0]:
            raise ValueError(
                f"weights must be (N,) or (N, 1) matching values' "
                f"N={values.shape[0]}, got shape {weights.shape}")
    values = op_.pre(values, weights=weights, coeffs=spec.coeffs)

    segmented = segment_ids is not None
    if segmented:
        if num_segments is None:
            raise ValueError("num_segments (static int) is required with "
                             "segment_ids")
        segment_ids = jnp.asarray(segment_ids)
    else:
        if num_segments is not None:
            raise ValueError("num_segments was given without segment_ids; "
                             "pass both for a segmented reduction")
        num_segments = 1
        segment_ids = jnp.zeros((values.shape[0],), jnp.int32)

    if on_overflow == "degrade":
        if isinstance(values, jax.core.Tracer):
            raise ValueError(
                "on_overflow='degrade' re-plans the reduction from runtime "
                "flags and is eager-only; call reduce outside jit, or keep "
                "on_overflow='raise'")
        out, status = _reduce_degrade(
            values, segment_ids, spec=spec, num_segments=int(num_segments),
            segmented=segmented, squeeze_d=squeeze_d, mesh=mesh,
            axis_names=axis_names)
        return (out, status) if with_status else out
    return _dispatch(values, segment_ids, spec=spec,
                     num_segments=int(num_segments), segmented=segmented,
                     squeeze_d=squeeze_d, mesh=mesh, axis_names=axis_names,
                     with_status=with_status)
