"""The front door: ``repro.reduce(...)`` and ``ReduceSpec``.

One call for every reduction in the repo — segmented or whole-stream,
sum or mean, any accuracy policy, any executor:

    from repro import reduce
    out = reduce(values)                                   # (N, D) -> (D,)
    out = reduce(values, segment_ids=ids, num_segments=8)  # -> (8, D)
    out = reduce(values, segment_ids=ids, num_segments=8,
                 op="mean", policy="exact", backend="pallas")

The paper's contract is preserved end to end: one in-order result per
variable-length set, a fixed pairing schedule (results depend only on
shapes, never on the executor), bounded accumulator state.

``ReduceSpec`` captures everything static about a reduction (op, policy,
backend, block size) in one frozen, hashable value — build it once, reuse
it across calls and jit boundaries, and the dispatch cache keys on it
directly.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp

from .backends import (OUT_OF_RANGE_LABEL, ambient_mesh, default_mesh,
                       get_backend, mask_out_of_range, select_backend)
from .policy import get_policy


@dataclasses.dataclass(frozen=True)
class ReduceSpec:
    """Static description of a reduction — hashable, so jit-cache-friendly.

    ``backend=None`` means auto-select (shard_map under a multi-device
    mesh, TPU kernel on TPU, scanned blocks elsewhere); ``interpret=None``
    lets the pallas backend decide.  Build one spec, reuse it across calls
    and jit boundaries:

    >>> spec = ReduceSpec(op="mean", policy="exact2", backend="blocked")
    >>> spec.replace(op="sum").op
    'sum'
    >>> spec == ReduceSpec(op="mean", policy="exact2", backend="blocked")
    True
    """

    op: str = "sum"                   # "sum" | "mean"
    policy: str = "fast"              # any registered policy name
    backend: Optional[str] = None
    block_size: int = 512
    interpret: Optional[bool] = None

    def __post_init__(self):
        if self.op not in ("sum", "mean"):
            raise ValueError(f"op must be 'sum' or 'mean', got {self.op!r}")
        get_policy(self.policy)                      # validate eagerly
        if self.backend is not None:
            get_backend(self.backend)

    def replace(self, **kw) -> "ReduceSpec":
        return dataclasses.replace(self, **kw)


@functools.partial(jax.jit, static_argnames=("spec", "num_segments",
                                             "segmented", "squeeze_d",
                                             "mesh", "axis_names"))
def _dispatch(values, segment_ids, *, spec: ReduceSpec, num_segments: int,
              segmented: bool, squeeze_d: bool, mesh=None, axis_names=None):
    policy = get_policy(spec.policy)
    n, d = values.shape
    # ``reduce`` resolved backend=None before the jit boundary, so specs
    # arriving here are concrete; keep the fallback for direct callers.
    backend = (get_backend(spec.backend) if spec.backend is not None
               else select_backend(policy))
    if not backend.supports(policy):
        raise ValueError(f"backend {backend.name!r} does not implement "
                         f"policy {policy.name!r} "
                         f"(capabilities: {sorted(backend.policies)})")
    if policy.max_block_size and spec.block_size > policy.max_block_size:
        raise ValueError(
            f"policy {policy.name!r} admits blocks of at most "
            f"{policy.max_block_size} rows (its integer-headroom bound); "
            f"got block_size={spec.block_size}")
    nb = -(-n // spec.block_size)
    if policy.max_blocks and nb > policy.max_blocks:
        raise ValueError(
            f"policy {policy.name!r} admits at most {policy.max_blocks} "
            f"schedule blocks (its per-block carry headroom), but "
            f"{n} rows at block_size={spec.block_size} need {nb}; "
            f"raise block_size or split the stream")

    if n == 0:
        # empty stream: identity on every backend (the pallas grid cannot
        # be empty, and exact's max-abs pass needs at least one row)
        out = jnp.zeros((num_segments, d), jnp.float32)
    else:
        segment_ids = mask_out_of_range(segment_ids, num_segments)
        # zero dropped rows' payloads too: the one-hot schedule ignores
        # them regardless, but policy.prepare must not see them (e.g. the
        # exact policy sizes its quantization scale from max |value| — a
        # huge sentinel-labeled row would poison the scale for kept rows)
        values = jnp.where((segment_ids >= 0)[:, None], values,
                           jnp.zeros((), values.dtype))
        domain, ctx = policy.prepare(values, n)
        run_kw = ({"mesh": mesh, "axis_names": axis_names}
                  if backend.distributed else {})
        carry = backend.run(domain, segment_ids, num_segments,
                            policy=policy, block_size=spec.block_size,
                            interpret=spec.interpret, **run_kw)
        out = policy.finalize(carry, ctx)            # (S, D) f32

    if spec.op == "mean" and n > 0:
        # Counts: exact integers, so a single scatter-add is bitwise-
        # identical to running the block schedule again at a fraction of
        # the cost, and backend-independent by construction.  Accumulate
        # in int32 — an f32 count buffer silently saturates at 2^24
        # (adding 1.0 to 16777216.0 is a no-op) — and cast once for the
        # divide.  segment_ids is already sentinel-masked; park dropped
        # rows on a scratch row.
        ids_safe = jnp.where(segment_ids >= 0, segment_ids, num_segments)
        cnt = jnp.zeros((num_segments + 1, 1), jnp.int32) \
            .at[ids_safe].add(1)[:num_segments]            # (S, 1)
        out = out / jnp.maximum(cnt, 1).astype(jnp.float32)

    if not segmented:
        out = out[0]
    if squeeze_d:
        out = out[..., 0]
    return out


def reduce(values, *, segment_ids=None, num_segments: Optional[int] = None,
           op: str = "sum", policy: str = "fast",
           backend: Optional[str] = None, block_size: int = 512,
           interpret: Optional[bool] = None,
           mesh=None, axis_names=None,
           spec: Optional[ReduceSpec] = None) -> jnp.ndarray:
    """Reduce a value stream, optionally partitioned into labeled sets.

    Args:
      values: (N,) or (N, D) array; any float dtype (accumulation is f32
        or exact int32 per ``policy``; the result is f32).
      segment_ids: optional (N,) int labels.  Rows labeled outside
        [0, num_segments) — including the repo-wide padding sentinel
        ``OUT_OF_RANGE_LABEL`` — are dropped from sums *and* counts.
      num_segments: static label-space size; required with ``segment_ids``.
      op: "sum" or "mean" (mean counts only in-range rows).
      policy: accuracy tier — "fast", "compensated", "exact", "exact2",
        or "procrastinate" (see ``repro.reduce.policy`` for the ladder).
      backend: executor — "ref", "blocked", "pallas", "shard_map", or
        None to auto-select (shard_map under a multi-device mesh, the
        TPU kernel on TPU, blocked elsewhere).
      block_size: rows per schedule block (the paper's cycle granularity).
      interpret: force/forbid pallas interpret mode (None = auto).
      mesh: the device mesh for a distributed backend; None uses the
        ambient ``with mesh:`` context, else one flat axis over every
        visible device.  Rejected for single-device backends.  Note the
        ambient mesh only steers *auto-selection* for top-level (eager)
        calls — inside jit/shard_map-traced code pass ``mesh=`` (or
        ``backend="shard_map"``) explicitly; see ``select_backend``.
      axis_names: mesh axes to shard the stream over (default: all of
        the mesh's axes); only meaningful with a distributed backend.
      spec: a prebuilt ``ReduceSpec``; overrides the per-call knobs above
        (``mesh``/``axis_names`` are environment, not spec, and still
        apply).

    Returns:
      f32 array: (num_segments, D) / (num_segments,) when segmented,
      (D,) / scalar otherwise.

    >>> import jax.numpy as jnp
    >>> from repro.reduce import reduce
    >>> float(reduce(jnp.arange(4.0)))                       # whole stream
    6.0
    >>> out = reduce(jnp.arange(6.0),                        # three sets
    ...              segment_ids=jnp.asarray([0, 0, 1, 1, 1, 2]),
    ...              num_segments=3)
    >>> [float(v) for v in out]
    [1.0, 9.0, 5.0]
    >>> float(reduce(jnp.arange(6.0), policy="exact2",       # multi-device
    ...              backend="shard_map"))
    15.0
    """
    if spec is None:
        spec = ReduceSpec(op=op, policy=policy, backend=backend,
                          block_size=block_size, interpret=interpret)
    # Resolve auto-selection and the mesh *before* the jit boundary: the
    # dispatch cache keys on the concrete (spec, mesh, axis_names), so an
    # activated-then-deactivated ambient mesh can never serve a stale
    # cached executor choice.
    pol = get_policy(spec.policy)
    auto = spec.backend is None
    bk = (select_backend(pol, mesh=mesh) if auto
          else get_backend(spec.backend))
    spec = spec if spec.backend == bk.name else spec.replace(backend=bk.name)
    if bk.distributed:
        if mesh is None:
            mesh = ambient_mesh() or default_mesh()
        if axis_names is not None:
            axis_names = tuple(axis_names)
    elif auto:
        # auto-selection declined the mesh (single device, or unsupported
        # policy): run the local backend.  A 1-device mesh dropping to the
        # local path is the intended "scale if useful" contract, but
        # explicit axis_names state distributed intent — refuse rather
        # than silently reduce on one device.
        if axis_names is not None:
            raise ValueError(
                "axis_names was given but backend auto-selection chose a "
                "single-device executor (no multi-device mesh in reach); "
                "pass backend='shard_map' and/or a multi-device mesh")
        mesh = None
    elif mesh is not None or axis_names is not None:
        raise ValueError(f"backend {bk.name!r} is single-device; mesh/"
                         f"axis_names only apply to distributed backends "
                         f"(e.g. 'shard_map')")
    values = jnp.asarray(values)
    if values.ndim not in (1, 2):
        raise ValueError(f"values must be (N,) or (N, D), "
                         f"got shape {values.shape}")
    squeeze_d = values.ndim == 1
    if squeeze_d:
        values = values[:, None]

    segmented = segment_ids is not None
    if segmented:
        if num_segments is None:
            raise ValueError("num_segments (static int) is required with "
                             "segment_ids")
        segment_ids = jnp.asarray(segment_ids)
    else:
        if num_segments is not None:
            raise ValueError("num_segments was given without segment_ids; "
                             "pass both for a segmented reduction")
        num_segments = 1
        segment_ids = jnp.zeros((values.shape[0],), jnp.int32)

    return _dispatch(values, segment_ids, spec=spec,
                     num_segments=int(num_segments), segmented=segmented,
                     squeeze_d=squeeze_d, mesh=mesh, axis_names=axis_names)
