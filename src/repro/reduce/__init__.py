"""repro.reduce — one front door for every reduction in the repo.

The paper's contribution is a single contract: stream in back-to-back
variable-length sets, emit one in-order result per set with bounded
state.  This package exposes that contract once, with three orthogonal
first-class knobs:

  * **op** (the algebra): ``sum`` / ``mean`` / ``weighted_sum`` /
    ``sumsq`` / ``moments`` / ``poly`` — a registry (``algebra.py``,
    extensible via ``@register_op``) of row-local pre/post hooks around
    the one block schedule, so every op inherits every policy/backend
    guarantee below (see docs/algebra.md).
  * **policy** (accuracy): ``fast`` (f32 fixed pairing tree),
    ``compensated`` (Kahan/two-sum), ``exact`` (INTAC single-limb int32),
    ``exact2`` (integer carry-save limbs + residual-digit superaccumulator:
    full resolution at any N, <=1 ulp of the f64 reference for arbitrary
    f32, all-int32 carry), and ``procrastinate`` (exponent-indexed bins —
    <=1 ulp for arbitrary f32 absent catastrophic cancellation)
    — ``policy.py``, extensible via ``@register_policy``.
  * **backend** (executor): ``ref`` / ``blocked`` / ``pallas`` /
    ``shard_map`` (multi-device) — all run the same block schedule so
    results match bitwise per policy; all-integer carry state (every
    component of exact / exact2 / procrastinate) additionally matches
    bitwise at any shard count, mesh shape, and device permutation —
    ``backends.py``, extensible via ``@register_backend``.

What a backend executes is a *staged block-program* (``program.py``): a
planned (``plan_program``) pair of declared stages per schedule block —
the memory-bound gather/contrib stage (one-hot dot or PhasedAccu-style
lane-parallel scatter, chosen by cost model) and the compute-bound carry
update — with byte/flop hints that tell executors what to overlap (the
pallas kernel double-buffers tiles against the update) and the roofline
tooling what to plot.

Entry points:
  ``reduce(values, segment_ids=..., num_segments=..., op=..., ...)``
      the call — see ``api.py``; ``ReduceSpec`` for reusable static specs.
  ``Accumulator`` protocol (``accumulator.py``)
      streaming init/push/merge/finalize — TreeAccumulator (gradient
      juggler), KahanAccumulator, LimbAccumulator (INTAC), and
      FlashAccumulator (online softmax) compose with lax.scan and trees.
  ``collective_mean`` (``collective.py``)
      the same policy knob for cross-device gradient means;
      ``elastic_reduce_mean`` for the topology-elastic (resume-anywhere)
      global mean.
  ``ReduceStatus`` (``api.py``)
      opt-in guard rails — ``reduce(..., with_status=True)`` reports
      NaN/Inf payloads, int32 carry saturation, degradation, and the
      kept-row count; ``on_overflow="degrade"`` re-plans instead of
      rejecting (see docs/robustness.md).
  ``OUT_OF_RANGE_LABEL``
      the repo-wide padding sentinel: rows so labeled drop out of every
      sum and count, on every backend.
"""

from .accumulator import (Accumulator, BinAccumulator,  # noqa: F401
                          CascadeAccumulator, FlashAccumulator,
                          KahanAccumulator, Limb3Accumulator,
                          LimbAccumulator, TreeAccumulator,
                          accumulate_microbatch_grads, merge_across,
                          merge_tree, reduce_microbatch_grads,
                          scan_accumulate)
from .algebra import (REDUCE_OPS, ReduceOp, cascade_poly_coeffs,  # noqa: F401
                      cascade_weights, fir_weights, get_op, poly_weights,
                      register_op)
from .api import ReduceSpec, ReduceStatus, reduce  # noqa: F401
from .backends import (BACKENDS, Backend, OUT_OF_RANGE_LABEL,  # noqa: F401
                       ambient_mesh, default_mesh, get_backend,
                       mask_out_of_range, register_backend, select_backend,
                       select_local_backend)
from .collective import (COLLECTIVE_POLICIES, collective_mean,  # noqa: F401
                         collective_mean_tree, collective_moments,
                         collective_weighted_mean, elastic_reduce_mean,
                         merge_carry_across)
from .policy import (POLICIES, Policy, fused_psum,  # noqa: F401
                     get_policy, register_policy, two_sum)
from .program import (BlockProgram, BlockStage,  # noqa: F401
                      block_contrib, plan_program)

# Make the module itself callable so ``repro.reduce(values, ...)`` is the
# front door, while ``repro.reduce.ReduceSpec`` etc. keep working.
import sys as _sys


class _CallableModule(_sys.modules[__name__].__class__):
    def __call__(self, *args, **kwargs):
        return reduce(*args, **kwargs)


_sys.modules[__name__].__class__ = _CallableModule

__all__ = [
    "reduce", "ReduceSpec", "ReduceStatus", "OUT_OF_RANGE_LABEL",
    "Policy", "POLICIES", "register_policy", "get_policy", "two_sum",
    "fused_psum",
    "ReduceOp", "REDUCE_OPS", "register_op", "get_op",
    "poly_weights", "fir_weights", "cascade_weights",
    "cascade_poly_coeffs",
    "BlockProgram", "BlockStage", "plan_program", "block_contrib",
    "Backend", "BACKENDS", "register_backend", "get_backend",
    "select_backend", "select_local_backend", "mask_out_of_range",
    "ambient_mesh", "default_mesh",
    "Accumulator", "TreeAccumulator", "KahanAccumulator",
    "LimbAccumulator", "Limb3Accumulator", "BinAccumulator",
    "FlashAccumulator", "CascadeAccumulator",
    "scan_accumulate", "merge_tree", "merge_across",
    "accumulate_microbatch_grads", "reduce_microbatch_grads",
    "collective_mean", "collective_mean_tree", "COLLECTIVE_POLICIES",
    "collective_weighted_mean", "collective_moments",
    "merge_carry_across", "elastic_reduce_mean",
]
