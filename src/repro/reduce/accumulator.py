"""The streaming ``Accumulator`` protocol — one contract for every state
machine in the repo.

JugglePAC is ultimately a streaming accumulator with bounded state; the
repo grew three ad-hoc incarnations of that idea (the gradient juggler's
``JugglerState``, INTAC's ``LimbState``, flash-decode's (m, l, o)
partials), each with its own init/step/merge spelling.  This module gives
them one protocol:

    init(template)      -> state        bounded, pytree-shaped
    push(state, x)      -> state        consume one stream element
    merge(a, b)         -> state        combine two partial streams
                                        (cross-block / cross-device)
    finalize(state)     -> value        the once-per-set "final addition"

Any instance composes with ``lax.scan`` (push is the step function) and
with fixed pairing trees (``merge_tree``), so the same code path handles
microbatch gradients, exact distributed sums, and attention partials.

Instances:
  * ``TreeAccumulator``  — binary-counter pairwise tree (wraps
    ``core.juggler``): O(log n) live state, O(log n) error growth.
  * ``KahanAccumulator`` — (sum, compensation) two-sum pair: O(1) state,
    ~f64 accuracy.
  * ``LimbAccumulator``  — INTAC two-limb int32 carry-save (wraps
    ``core.intac``): exact, order-independent, one rounding at finalize.
  * ``BinAccumulator``   — exponent-indexed "procrastination" bins (wraps
    ``core.intac`` bin_split/combine): exact for any f32 within the
    window, order-independent, all rounding deferred to finalize.
  * ``FlashAccumulator`` — online-softmax (m, l, o) triple (wraps
    ``core.segmented``): the "any multi-cycle operator" clause of the
    paper, instantiated for attention.
"""

from __future__ import annotations

from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import intac, juggler
from .policy import two_sum


@runtime_checkable
class Accumulator(Protocol):
    """Structural protocol: anything with init/push/merge/finalize."""

    def init(self, template) -> Any: ...

    def push(self, state, x) -> Any: ...

    def merge(self, a, b) -> Any: ...

    def finalize(self, state) -> Any: ...


class TreeAccumulator:
    """Binary-counter pairwise-tree accumulation of pytrees.

    The software PIS: ``num_slots`` >= ceil(log2 pushes) + 1 slots bound
    the live state; the pairing schedule depends only on the push count.
    """

    def __init__(self, num_slots: int):
        self.num_slots = num_slots

    @classmethod
    def for_count(cls, num_pushes: int) -> "TreeAccumulator":
        return cls(juggler.num_slots_for(num_pushes))

    def init(self, template) -> juggler.JugglerState:
        return juggler.juggler_init(template, self.num_slots)

    def push(self, state, x) -> juggler.JugglerState:
        return juggler.juggler_push(state, x)

    def merge(self, a, b) -> juggler.JugglerState:
        """Fold b's slots to one partial and insert it into a's counter —
        a fixed, deterministic (if unbalanced) pairing of the two trees."""
        folded = juggler.juggler_finalize(b)
        merged = juggler.juggler_push(a, folded)
        return merged._replace(count=a.count + b.count)

    def finalize(self, state, *, mean: bool = False):
        return juggler.juggler_finalize(state, mean=mean)


class KahanAccumulator:
    """Compensated (sum, comp) accumulation of a single array/pytree."""

    def init(self, template):
        z = jax.tree.map(lambda t: jnp.zeros(jnp.shape(t), jnp.float32),
                         template)
        return (z, jax.tree.map(jnp.zeros_like, z))

    def push(self, state, x):
        acc, comp = state
        # two maps so tuple-valued two_sum never confuses pytree flattening
        # (XLA CSE merges the duplicated arithmetic under jit).
        s = jax.tree.map(lambda a, b: two_sum(a, b)[0], acc, x)
        e = jax.tree.map(lambda a, b: two_sum(a, b)[1], acc, x)
        return (s, jax.tree.map(jnp.add, comp, e))

    def merge(self, a, b):
        state = self.push(a, b[0])                   # two-sum the sums
        return (state[0],
                jax.tree.map(lambda c, cb: c + cb, state[1], b[1]))

    def finalize(self, state):
        acc, comp = state
        return jax.tree.map(lambda a, c: a + c, acc, comp)


class LimbAccumulator:
    """INTAC two-limb carry-save accumulation (exact within quantization).

    ``scale`` is the shared power-of-two from ``intac.choose_scale`` — the
    a-priori bit-width parameterization; push/merge are pure integer ops.
    """

    def __init__(self, scale):
        self.scale = scale

    def init(self, template) -> intac.LimbState:
        return intac.limb_init(jnp.shape(template), self.scale)

    def push(self, state, x) -> intac.LimbState:
        return intac.limb_add(state, x)

    def merge(self, a, b) -> intac.LimbState:
        return intac.limb_merge(a, b)

    def finalize(self, state) -> jnp.ndarray:
        return intac.limb_finalize(state)


class BinAccumulator:
    """Exponent-indexed bin accumulation (Liguori's procrastination /
    Neal's small superaccumulator, int32 edition).

    ``max_abs`` anchors the fixed-point window a priori — the bin
    analogue of ``LimbAccumulator``'s shared scale; pushes are exact
    digit splits + integer adds (order-independent), and the one rounding
    happens in ``finalize``.  Up to ``intac.BIN_MAX_TERMS`` (= 2^22)
    pushes accumulate with no bin overflow.
    """

    def __init__(self, max_abs):
        self.e_ref = intac.bin_ref_exponent(max_abs)

    def init(self, template):
        return jnp.zeros((intac.NUM_BINS,) + jnp.shape(template), jnp.int32)

    def push(self, state, x):
        return state + intac.bin_split(x, self.e_ref)

    def merge(self, a, b):
        return a + b

    def finalize(self, state) -> jnp.ndarray:
        return intac.bin_combine(state, self.e_ref)


class FlashAccumulator:
    """Online-softmax partials: state = (max m, denom l, weighted out o).

    ``push``/``merge`` are the same associative combine (flash partials are
    their own partial-stream type); ``finalize`` returns the normalized
    output ``o / l``.
    """

    _NEG = -1e30

    def init(self, template):
        m, l, o = template
        return (jnp.full(jnp.shape(m), self._NEG, jnp.float32),
                jnp.zeros(jnp.shape(l), jnp.float32),
                jnp.zeros(jnp.shape(o), jnp.float32))

    def push(self, state, partial):
        # lazy import: core.segmented imports repro.reduce for the shared
        # sentinel, so this edge must not exist at module-load time.
        from repro.core.segmented import flash_partial_combine
        m1, l1, o1 = state
        m2, l2, o2 = partial
        return flash_partial_combine(m1, l1, o1, m2, l2, o2)

    def merge(self, a, b):
        return self.push(a, b)

    def finalize(self, state):
        m, l, o = state
        return o / jnp.maximum(l, 1e-30)[..., None]


# ---------------------------------------------------------------------------
# Composition helpers
# ---------------------------------------------------------------------------


def scan_accumulate(acc: Accumulator, xs, template=None):
    """Fold a stacked stream (leading axis) through ``acc`` with lax.scan."""
    if template is None:
        template = jax.tree.map(lambda x: x[0], xs)
    state0 = acc.init(template)
    state, _ = jax.lax.scan(lambda s, x: (acc.push(s, x), None), state0, xs)
    return acc.finalize(state)


def merge_tree(acc: Accumulator, states):
    """Fixed pairwise-tree merge of a list of accumulator states."""
    items = list(states)
    if not items:
        raise ValueError("merge_tree: empty state list")
    while len(items) > 1:
        nxt = [acc.merge(items[i], items[i + 1])
               for i in range(0, len(items) - 1, 2)]
        if len(items) % 2:
            nxt.append(items[-1])
        items = nxt
    return items[0]


def accumulate_microbatch_grads(grad_fn, params, microbatches, *,
                                num_microbatches: int, mean: bool = True):
    """Microbatch gradient accumulation through the Accumulator protocol.

    Scans ``grad_fn(params, mb)`` over stacked microbatches, pushing each
    gradient into a ``TreeAccumulator`` (O(log n) live copies, fixed
    pairing schedule).  Returns (mean_or_sum, aux_stacked).
    """
    acc = TreeAccumulator.for_count(num_microbatches)

    template = jax.eval_shape(
        lambda p, m: grad_fn(p, m)[0], params,
        jax.tree.map(lambda x: x[0], microbatches))
    template = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), template)

    def step(state, mb):
        g, aux = grad_fn(params, mb)
        return acc.push(state, g), aux

    state, aux = jax.lax.scan(step, acc.init(template), microbatches)
    return acc.finalize(state, mean=mean), aux
