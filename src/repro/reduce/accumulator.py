"""The streaming ``Accumulator`` protocol — one contract for every state
machine in the repo.

JugglePAC is ultimately a streaming accumulator with bounded state; the
repo grew three ad-hoc incarnations of that idea (the gradient juggler's
``JugglerState``, INTAC's ``LimbState``, flash-decode's (m, l, o)
partials), each with its own init/step/merge spelling.  This module gives
them one protocol:

    init(template)      -> state        bounded, pytree-shaped
    push(state, x)      -> state        consume one stream element
    merge(a, b)         -> state        combine two partial streams
                                        (cross-block / cross-device)
    finalize(state)     -> value        the once-per-set "final addition"

Any instance composes with ``lax.scan`` (push is the step function) and
with fixed pairing trees (``merge_tree``), so the same code path handles
microbatch gradients, exact distributed sums, and attention partials.

Instances:
  * ``TreeAccumulator``  — binary-counter pairwise tree (wraps
    ``core.juggler``): O(log n) live state, O(log n) error growth.
  * ``KahanAccumulator`` — (sum, compensation) two-sum pair: O(1) state,
    ~f64 accuracy.
  * ``LimbAccumulator``  — INTAC two-limb int32 carry-save (wraps
    ``core.intac``): exact, order-independent, one rounding at finalize.
  * ``Limb3Accumulator`` — the three-limb variant: the exactly-captured
    quantization residual rides along as a compensated f32 limb, so the
    finalized sum is within 1 ulp of the f64 reference for arbitrary f32
    streams — not just values on the scale's dyadic grid.  Integer limbs
    keep the bitwise order-independent contract; the residual pair is
    order-pinned tolerance.
  * ``BinAccumulator``   — exponent-indexed "procrastination" bins (wraps
    ``core.intac`` bin_split/combine): exact for any f32 within the
    window, order-independent, all rounding deferred to finalize.
  * ``FlashAccumulator`` — online-softmax (m, l, o) triple (wraps
    ``core.segmented``): the "any multi-cycle operator" clause of the
    paper, instantiated for attention.
"""

from __future__ import annotations

import math
from typing import Any, Protocol, runtime_checkable

import jax
import jax.numpy as jnp

from repro.core import intac, juggler
from .policy import fused_psum, two_sum


@runtime_checkable
class Accumulator(Protocol):
    """Structural protocol: anything with init/push/merge/finalize.

    ``merge`` is the declared combiner — it is what ``merge_tree`` folds
    with locally and what ``merge_across`` folds with across devices, so
    stating it once gives a state machine both a streaming and a
    distributed face.

    >>> import jax.numpy as jnp
    >>> acc = KahanAccumulator()
    >>> st = acc.init(jnp.zeros(2))
    >>> st = acc.push(st, jnp.asarray([1.0, 2.0]))
    >>> st = acc.push(st, jnp.asarray([3.0, 4.0]))
    >>> [float(v) for v in acc.finalize(st)]
    [4.0, 6.0]
    >>> isinstance(acc, Accumulator)
    True
    """

    def init(self, template) -> Any: ...

    def push(self, state, x) -> Any: ...

    def merge(self, a, b) -> Any: ...

    def finalize(self, state) -> Any: ...


class TreeAccumulator:
    """Binary-counter pairwise-tree accumulation of pytrees.

    The software PIS: ``num_slots`` >= ceil(log2 pushes) + 1 slots bound
    the live state; the pairing schedule depends only on the push count.
    """

    def __init__(self, num_slots: int):
        self.num_slots = num_slots

    @classmethod
    def for_count(cls, num_pushes: int) -> "TreeAccumulator":
        return cls(juggler.num_slots_for(num_pushes))

    def init(self, template) -> juggler.JugglerState:
        return juggler.juggler_init(template, self.num_slots)

    def push(self, state, x) -> juggler.JugglerState:
        return juggler.juggler_push(state, x)

    def merge(self, a, b) -> juggler.JugglerState:
        """Fold b's slots to one partial and insert it into a's counter —
        a fixed, deterministic (if unbalanced) pairing of the two trees."""
        folded = juggler.juggler_finalize(b)
        merged = juggler.juggler_push(a, folded)
        return merged._replace(count=a.count + b.count)

    def finalize(self, state, *, mean: bool = False):
        return juggler.juggler_finalize(state, mean=mean)


class KahanAccumulator:
    """Compensated (sum, comp) accumulation of a single array/pytree."""

    def init(self, template):
        z = jax.tree.map(lambda t: jnp.zeros(jnp.shape(t), jnp.float32),
                         template)
        return (z, jax.tree.map(jnp.zeros_like, z))

    def push(self, state, x):
        acc, comp = state
        # two maps so tuple-valued two_sum never confuses pytree flattening
        # (XLA CSE merges the duplicated arithmetic under jit).
        s = jax.tree.map(lambda a, b: two_sum(a, b)[0], acc, x)
        e = jax.tree.map(lambda a, b: two_sum(a, b)[1], acc, x)
        return (s, jax.tree.map(jnp.add, comp, e))

    def merge(self, a, b):
        state = self.push(a, b[0])                   # two-sum the sums
        return (state[0],
                jax.tree.map(lambda c, cb: c + cb, state[1], b[1]))

    def finalize(self, state):
        acc, comp = state
        return jax.tree.map(lambda a, c: a + c, acc, comp)


class LimbAccumulator:
    """INTAC two-limb carry-save accumulation (exact within quantization).

    ``scale`` is the shared power-of-two from ``intac.choose_scale`` — the
    a-priori bit-width parameterization; push/merge are pure integer ops.

    >>> import jax.numpy as jnp
    >>> acc = LimbAccumulator(2.0 ** 16)
    >>> a, b = acc.init(jnp.zeros(1)), acc.init(jnp.zeros(1))
    >>> for _ in range(10):
    ...     a = acc.push(a, jnp.asarray([0.5]))
    ...     b = acc.push(b, jnp.asarray([0.25]))
    >>> float(acc.finalize(acc.merge(a, b))[0])     # exact, order-free
    7.5
    """

    def __init__(self, scale):
        self.scale = scale

    def init(self, template) -> intac.LimbState:
        return intac.limb_init(jnp.shape(template), self.scale)

    def push(self, state, x) -> intac.LimbState:
        return intac.limb_add(state, x)

    def merge(self, a, b) -> intac.LimbState:
        return intac.limb_merge(a, b)

    def finalize(self, state) -> jnp.ndarray:
        return intac.limb_finalize(state)


class Limb3Accumulator:
    """INTAC three-limb carry-save accumulation: exact for arbitrary f32.

    ``LimbAccumulator`` with the dyadic-grid caveat removed: pushes split
    each operand losslessly into (hi, lo, residual) — the residual is
    what quantization rounded away, captured exactly and folded through a
    compensated ``two_sum`` pair.  The integer limbs keep the bitwise
    order-independent contract; ``finalize`` is one carry-resolve +
    compensated combine within 1 ulp of the f64 reference.

    >>> import jax.numpy as jnp
    >>> acc = Limb3Accumulator(2.0 ** 16)
    >>> st = acc.init(jnp.zeros(1))
    >>> for _ in range(3):
    ...     st = acc.push(st, jnp.asarray([1 / 3]))    # off the grid
    >>> float(abs(acc.finalize(st)[0] - 1.0)) < 1e-7
    True
    """

    def __init__(self, scale):
        self.scale = scale

    def init(self, template) -> intac.Limb3State:
        return intac.limb3_init(jnp.shape(template), self.scale)

    def push(self, state, x) -> intac.Limb3State:
        return intac.limb_add3(state, x)

    def merge(self, a, b) -> intac.Limb3State:
        return intac.limb_merge3(a, b)

    def merge_across(self, state, axis_names):
        """Cross-device merge (inside shard_map), taken by the module
        ``merge_across`` in place of its generic paths: the one shared
        three-limb lowering (``core.intac.limb3_merge_across`` — the
        residual pair re-binned as exponent-indexed digits, then one
        *fused* int32 psum over [hi | lo | digits]); the shared scale
        leaf passes through untouched, and the wrap-event count
        (overflow guard rail) psums like any other integer component."""
        hi, lo, res, comp = intac.limb3_merge_across(
            state.hi, state.lo, state.res, state.comp, axis_names)
        ovf = (None if state.ovf is None
               else jax.lax.psum(state.ovf, tuple(axis_names)))
        return intac.Limb3State(hi, lo, res, comp, state.scale, ovf)

    def finalize(self, state) -> jnp.ndarray:
        return intac.limb3_finalize(state)


class BinAccumulator:
    """Exponent-indexed bin accumulation (Liguori's procrastination /
    Neal's small superaccumulator, int32 edition).

    ``max_abs`` anchors the fixed-point window a priori — the bin
    analogue of ``LimbAccumulator``'s shared scale; pushes are exact
    digit splits + integer adds (order-independent), and the one rounding
    happens in ``finalize``.  Up to ``intac.BIN_MAX_TERMS`` (= 2^22)
    pushes accumulate with no bin overflow.
    """

    #: every state leaf merges by addition, so a cross-device merge may
    #: lower to one fused associative psum per dtype (see
    #: ``merge_across``).
    #: LimbAccumulator cannot claim this: its state carries the shared
    #: ``scale`` leaf, which ``merge`` keeps rather than adds.
    merge_is_add = True

    def __init__(self, max_abs):
        self.e_ref = intac.bin_ref_exponent(max_abs)

    def init(self, template):
        return jnp.zeros((intac.NUM_BINS,) + jnp.shape(template), jnp.int32)

    def push(self, state, x):
        return state + intac.bin_split(x, self.e_ref)

    def merge(self, a, b):
        return a + b

    def finalize(self, state) -> jnp.ndarray:
        return intac.bin_combine(state, self.e_ref)


class FlashAccumulator:
    """Online-softmax partials: state = (max m, denom l, weighted out o).

    ``push``/``merge`` are the same associative combine (flash partials are
    their own partial-stream type); ``finalize`` returns the normalized
    output ``o / l``.
    """

    _NEG = -1e30

    def init(self, template):
        m, l, o = template
        return (jnp.full(jnp.shape(m), self._NEG, jnp.float32),
                jnp.zeros(jnp.shape(l), jnp.float32),
                jnp.zeros(jnp.shape(o), jnp.float32))

    def push(self, state, partial):
        # lazy import: core.segmented imports repro.reduce for the shared
        # sentinel, so this edge must not exist at module-load time.
        from repro.core.segmented import flash_partial_combine
        m1, l1, o1 = state
        m2, l2, o2 = partial
        return flash_partial_combine(m1, l1, o1, m2, l2, o2)

    def merge(self, a, b):
        return self.push(a, b)

    def finalize(self, state):
        m, l, o = state
        return o / jnp.maximum(l, 1e-30)[..., None]


class CascadeAccumulator:
    """``depth`` chained plain accumulators — the cascaded-PAC
    construction of arXiv 2509.15069, as a streaming state machine.

    Every push folds the element into stage 1 and then re-folds each
    stage's running value into the next: after n pushes stage k holds
    the binomially time-index-weighted sum
    ``sum_i C(n-1-i + k-1, k-1) x_i`` (``algebra.cascade_weights``), so
    a fixed linear combination of the stages realizes any polynomial
    time-index weighting (``algebra.cascade_poly_coeffs``) — FIR-style
    weighted reduction out of nothing but plain adders.

    State is ``(count, stage sums)``; ``merge`` concatenates two
    partial streams *in argument order* (a then b) via the exact
    stage-mixing law — for ``m = b.count`` trailing elements,
    ``S_k = A_k + B_k + sum_{j<k} C(m+k-j-1, k-j) A_j`` — so chunked or
    scanned evaluation matches the one-shot stream.  ``finalize``
    stacks the stage sums (leading axis = stage).

    >>> import jax.numpy as jnp
    >>> acc = CascadeAccumulator(2)
    >>> st = acc.init(jnp.zeros(()))
    >>> for v in (1.0, 10.0, 100.0):
    ...     st = acc.push(st, jnp.asarray(v))
    >>> [float(v) for v in acc.finalize(st)]      # [sum, 3*1+2*10+1*100]
    [111.0, 123.0]
    >>> a = acc.init(jnp.zeros(())); b = acc.init(jnp.zeros(()))
    >>> a = acc.push(a, jnp.asarray(1.0))
    >>> for v in (10.0, 100.0):
    ...     b = acc.push(b, jnp.asarray(v))
    >>> [float(v) for v in acc.finalize(acc.merge(a, b))]
    [111.0, 123.0]
    """

    def __init__(self, depth: int):
        if depth < 1:
            raise ValueError(f"cascade depth must be >= 1, got {depth}")
        self.depth = int(depth)

    def init(self, template):
        z = jnp.zeros(jnp.shape(template), jnp.float32)
        return (jnp.zeros((), jnp.int32), (z,) * self.depth)

    def push(self, state, x):
        count, sums = state
        run = x.astype(jnp.float32)
        new = []
        for s in sums:
            run = s + run               # stage k folds stage k-1's value
            new.append(run)
        return (count + 1, tuple(new))

    def merge(self, a, b):
        ca, sa = a
        cb, sb = b
        m = cb.astype(jnp.float32)
        out = []
        for k in range(1, self.depth + 1):
            s = sa[k - 1] + sb[k - 1]
            # detlint: ok[DET002] closed-form cascade merge: fixed small
            # depth, order is part of the formula; property tests pin it
            for j in range(1, k):
                r = k - j               # C(m + r - 1, r), m traced
                coef = jnp.float32(1.0)
                for t in range(r):
                    coef = coef * (m + t)
                s = s + (coef / math.factorial(r)) * sa[j - 1]
            out.append(s)
        return (ca + cb, tuple(out))

    def finalize(self, state):
        return jnp.stack(state[1], axis=0)


# ---------------------------------------------------------------------------
# Composition helpers
# ---------------------------------------------------------------------------


def scan_accumulate(acc: Accumulator, xs, template=None):
    """Fold a stacked stream (leading axis) through ``acc`` with lax.scan."""
    if template is None:
        template = jax.tree.map(lambda x: x[0], xs)
    state0 = acc.init(template)
    state, _ = jax.lax.scan(lambda s, x: (acc.push(s, x), None), state0, xs)
    return acc.finalize(state)


def merge_tree(acc: Accumulator, states):
    """Fixed pairwise-tree merge of a list of accumulator states."""
    items = list(states)
    if not items:
        raise ValueError("merge_tree: empty state list")
    while len(items) > 1:
        nxt = [acc.merge(items[i], items[i + 1])
               for i in range(0, len(items) - 1, 2)]
        if len(items) % 2:
            nxt.append(items[-1])
        items = nxt
    return items[0]


def merge_across(acc: Accumulator, state, axis_names):
    """Cross-device merge of per-device accumulator states (inside
    shard_map).

    Every ``Accumulator`` states its combiner as ``merge``; this is the
    collective face of that contract — the same role
    ``collective.merge_carry_across`` plays for policy carries.  An
    accumulator with its own ``merge_across`` method (Limb3Accumulator:
    psum'd integer limbs + an order-pinned residual fold) keeps full
    control of the lowering; one declaring ``merge_is_add`` (every state
    leaf merges by plain addition, e.g. BinAccumulator) reduces with one
    *fused* batched ``psum`` per dtype — the leaves ravel-concat into a
    single collective (``policy.fused_psum``), bitwise identical to
    per-leaf psums because psum is elementwise; otherwise each leaf
    all-gathers along
    ``axis_names`` and the per-device states fold strictly in device
    order, so the combine schedule is a pure function of the mesh —
    deterministic, and exact whenever ``merge`` is (LimbAccumulator,
    BinAccumulator).

    Example (one-device mesh; any device count works the same way):

    >>> import jax, jax.numpy as jnp, numpy as np
    >>> from jax.sharding import Mesh, PartitionSpec as P
    >>> from jax.experimental.shard_map import shard_map
    >>> mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    >>> acc = KahanAccumulator()
    >>> def f(x):
    ...     st = acc.push(acc.init(x), x)          # local partial stream
    ...     return acc.finalize(merge_across(acc, st, ("data",)))
    >>> out = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
    ...                 check_rep=False)(jnp.asarray([2.0, 3.0]))
    >>> [float(v) for v in out]
    [2.0, 3.0]
    """
    axes = tuple(axis_names)
    own = getattr(acc, "merge_across", None)
    if callable(own):
        return own(state, axes)
    if getattr(acc, "merge_is_add", False):
        # one batched collective per dtype instead of one psum per leaf:
        # psum is elementwise, so the fused form is bitwise identical
        leaves, treedef = jax.tree.flatten(state)
        return jax.tree.unflatten(treedef, fused_psum(leaves, axes))
    gathered = jax.tree.map(
        lambda x: jax.lax.all_gather(x, axes, axis=0), state)
    nshards = jax.tree.leaves(gathered)[0].shape[0]
    merged = jax.tree.map(lambda x: x[0], gathered)
    # detlint: ok[DET002] strict device-order merge is the contract:
    # merge chains are two_sum data-dependent or integer-exact
    for k in range(1, nshards):
        merged = acc.merge(merged, jax.tree.map(lambda x: x[k], gathered))
    return merged


def reduce_microbatch_grads(grad_fn, params, microbatches, *,
                            num_microbatches: int, policy: str,
                            backend=None, mesh=None):
    """Microbatch gradient mean through the ``repro.reduce`` front door.

    The policy-exact alternative to ``accumulate_microbatch_grads``:
    per-microbatch gradients stack into an (m, |leaf|) stream per leaf
    (one row per microbatch = one schedule block) and mean under any
    accuracy policy — with the integer tiers, the result is bitwise
    independent of microbatch count and executor.  Costs m live gradient
    copies instead of O(log m).  ``backend=None`` auto-selects; pass
    ``mesh`` to route the reduction through the ``shard_map`` backend
    explicitly (ambient-mesh auto-selection is deliberately inert inside
    a jit trace, and for m-row streams the local executor is normally
    the right choice anyway).  Returns (mean_grads, aux_stacked); leaf
    dtypes are preserved.
    """
    from .api import ReduceSpec, reduce as _reduce
    spec = ReduceSpec(op="mean", policy=policy, backend=backend,
                      block_size=1)

    def scan_step(_, mb):
        g, aux = grad_fn(params, mb)
        return 0, (g, aux)

    _, (stacked, aux) = jax.lax.scan(scan_step, 0, microbatches)
    grads = jax.tree.map(
        lambda g: _reduce(
            g.astype(jnp.float32).reshape(num_microbatches, -1),
            spec=spec, mesh=mesh)
        .reshape(g.shape[1:]).astype(g.dtype), stacked)
    return grads, aux


def accumulate_microbatch_grads(grad_fn, params, microbatches, *,
                                num_microbatches: int, mean: bool = True):
    """Microbatch gradient accumulation through the Accumulator protocol.

    Scans ``grad_fn(params, mb)`` over stacked microbatches, pushing each
    gradient into a ``TreeAccumulator`` (O(log n) live copies, fixed
    pairing schedule).  Returns (mean_or_sum, aux_stacked).
    """
    acc = TreeAccumulator.for_count(num_microbatches)

    template = jax.eval_shape(
        lambda p, m: grad_fn(p, m)[0], params,
        jax.tree.map(lambda x: x[0], microbatches))
    template = jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype), template)

    def step(state, mb):
        g, aux = grad_fn(params, mb)
        return acc.push(state, g), aux

    state, aux = jax.lax.scan(step, acc.init(template), microbatches)
    return acc.finalize(state, mean=mean), aux
