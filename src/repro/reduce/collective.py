"""Policy-selectable cross-device means — the distributed face of
``repro.reduce``.

The repo's three gradient all-reduce flavors were separate functions
(``_hierarchical_mean``, ``compressed_psum_mean``, ``intac_psum``); here
they are the same accuracy knob the array API exposes:

  * ``fast``        — hierarchical fp32 psum ('data' in-pod ICI first,
                      then 'pod' DCI), divide once.
  * ``compensated`` — INTAC *compressed* all-reduce with error feedback:
    quantize to ``bits``-bit fixed point on a shared power-of-two scale,
    psum in the exact integer domain, dequantize once; the local
    quantization error is carried as next step's residual — the
    collective analogue of a Kahan compensation term (bits/32 of the
    fp32 payload on the wire).
  * ``exact``       — full-width INTAC integer psum: bitwise-deterministic
    for any reduction topology / pod layout, no compression.  The shared
    scale shrinks with the device count (single-limb headroom).
  * ``exact2``      — three-limb INTAC psum: the per-device hi/lo limb
    split keeps full-resolution quantization (scale sized by magnitude
    alone) for up to 2^15 devices, and the exactly-captured quantization
    residual is re-expressed as exponent-indexed int32 digits (a small
    Neal-style superaccumulator, arXiv 1505.05571) that psum exactly, so
    the mean is within 1 ulp of the f64 reference *and* bitwise-invariant
    across device count, mesh shape, and device permutation; one
    carry-resolve per reduction.
  * ``procrastinate`` — per-exponent-bin integer psum: each device splits
    its gradient into exponent-window digits, every bin psums in the
    exact integer domain, and one carry-resolve + compensated combine
    defers all rounding — <=1 ulp of the f32 mean for any topology
    (absolute 2^-49-of-max bound when devices cancel catastrophically).

All tiers share one signature so training code switches policy without
rewiring residual plumbing: ``(mean, new_residual)`` — every tier except
compensated passes ``residual`` through untouched (including ``None``;
only compensated materializes an error-feedback state).

Must be called inside ``shard_map`` (they use named-axis collectives).

``merge_carry_across`` is the second face of this module: where
``collective_mean`` reduces *raw gradients* across devices, it reduces
*policy carries* — the partial block-schedule state each shard of the
``shard_map`` backend produced — with the policy's own combiner (one
integer ``psum`` per integer carry component, a gathered in-order
two-sum fold for order-sensitive float state: compensated's carry,
exact2's residual limb).
"""

from __future__ import annotations

from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.core import intac
from .backends import get_backend
from .policy import Policy, fused_psum, get_policy

COLLECTIVE_POLICIES = ("fast", "compensated", "exact", "exact2",
                       "procrastinate")


def merge_carry_across(policy: Policy, carry, axis_names):
    """Merge per-shard policy carries across mesh axes (inside shard_map).

    ``carry`` is the policy carry tuple a local backend produced from a
    shard's blocks.  The lowering is the policy's own
    (``Policy.merge_across``): one associative int32 psum per integer
    carry component (any psum topology gives the same bits — the
    ``intac_psum3``/``bin_psum`` argument applied to carries that are
    *already* in the integer domain; since the residual-digit redesign
    this covers every exact2 component too), and an all-gather + strict
    device-order fold with ``policy.merge`` for order-sensitive float
    state (compensated's carry), which pins the combine schedule the way
    the block schedule pins per-shard order.
    """
    return policy.merge_across(carry, axis_names)


def collective_mean(x: jnp.ndarray, axis_names: Sequence[str], *,
                    policy: str = "fast", bits: int = 8,
                    residual: Optional[jnp.ndarray] = None
                    ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cross-device mean of one array under an accuracy policy.

    ``axis_names`` is ordered outermost (slowest, e.g. 'pod') to innermost
    (fastest, e.g. 'data'); reductions run innermost-first to match the
    physical topology.  Returns (mean, new_residual).

    Must run inside ``shard_map`` — e.g. on a one-device mesh:

    >>> import jax, jax.numpy as jnp, numpy as np
    >>> from jax.sharding import Mesh, PartitionSpec as P
    >>> from jax.experimental.shard_map import shard_map
    >>> mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    >>> f = lambda x: collective_mean(x, ("data",), policy="exact2")[0]
    >>> out = shard_map(f, mesh=mesh, in_specs=P(), out_specs=P(),
    ...                 check_rep=False)(jnp.asarray([1.5, -2.0]))
    >>> [float(v) for v in out]
    [1.5, -2.0]
    """
    axes = tuple(axis_names)
    if policy == "fast":
        g = x
        for a in reversed(axes):
            g = jax.lax.psum(g, a)      # innermost (fastest) axis first
        return g / jax.lax.psum(jnp.float32(1.0), axes), residual  # detlint: ok[DET006] device count well under 2^24; one collective keeps the fast tier fast

    # the integer tiers are the core INTAC collectives (one copy of each
    # quantize/psum/resolve recipe lives in core/intac.py); integer sums
    # are associative, so the joint-axes psum is bitwise identical to any
    # hierarchical per-axis order.
    if policy == "exact":
        n = jax.lax.psum(1, axes)
        return intac.intac_psum(x, axes) / n, residual

    if policy == "exact2":
        n = jax.lax.psum(1, axes)
        return intac.intac_psum3(x, axes) / n, residual

    if policy == "procrastinate":
        n = jax.lax.psum(1, axes)
        return intac.bin_psum(x, axes) / n, residual

    if policy == "compensated":
        if residual is None:       # only this policy materializes a state
            residual = jnp.zeros(x.shape, jnp.float32)
        return intac.compressed_psum_mean(x, residual, axes, bits=bits)

    raise ValueError(f"unknown collective policy {policy!r}; "
                     f"choose from {COLLECTIVE_POLICIES}")


def collective_weighted_mean(x: jnp.ndarray, w: jnp.ndarray, axis_names,
                             *, policy: str = "fast", bits: int = 8,
                             eps: float = 1e-9) -> jnp.ndarray:
    """Cross-device weighted mean ``sum(w*x) / sum(w)`` under an
    accuracy policy — the collective face of ``op="weighted_sum"``.

    Both the weighted numerator and the weight mass reduce through
    ``collective_mean`` (the per-device counts cancel in the ratio), so
    each gets its own policy-sized quantization grid; for the bitwise
    tiers the result is invariant to topology like the mean itself.
    Must run inside ``shard_map``.

    >>> import jax, jax.numpy as jnp, numpy as np
    >>> from jax.sharding import Mesh, PartitionSpec as P
    >>> from jax.experimental.shard_map import shard_map
    >>> mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    >>> f = lambda x, w: collective_weighted_mean(x, w, ("data",),
    ...                                           policy="exact2")
    >>> out = shard_map(f, mesh=mesh, in_specs=(P(), P()), out_specs=P(),
    ...                 check_rep=False)(jnp.asarray([1.0, 4.0]),
    ...                                  jnp.asarray([3.0, 1.0]))
    >>> [float(v) for v in out]                    # per-element w*x / w
    [1.0, 4.0]
    """
    num, _ = collective_mean(x * w, axis_names, policy=policy, bits=bits)
    den, _ = collective_mean(w, axis_names, policy=policy, bits=bits)
    return num / jnp.maximum(den, eps)


def collective_moments(x: jnp.ndarray, axis_names, *,
                       policy: str = "fast", bits: int = 8
                       ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Cross-device running moments: elementwise (mean, var) over the
    device axis — the collective face of ``op="moments"``.

    Two ``collective_mean`` passes (E[x] and E[x^2]) rather than one
    concatenated payload: the integer tiers size their quantization
    grid per collective, and x and x^2 live on very different scales —
    sharing a grid would cost the smaller component its resolution.
    ``var = max(E[x^2] - E[x]^2, 0)`` with the clamp guarding float-tier
    cancellation; under a bitwise tier both expectations — hence the
    moments — are invariant to topology.  Must run inside ``shard_map``.

    >>> import jax, jax.numpy as jnp, numpy as np
    >>> from jax.sharding import Mesh, PartitionSpec as P
    >>> from jax.experimental.shard_map import shard_map
    >>> mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    >>> f = lambda x: collective_moments(x, ("data",), policy="exact2")
    >>> m, v = shard_map(f, mesh=mesh, in_specs=P(), out_specs=(P(), P()),
    ...                  check_rep=False)(jnp.asarray([1.5, -2.0]))
    >>> [float(a) for a in m], [float(a) for a in v]
    ([1.5, -2.0], [0.0, 0.0])
    """
    m1, _ = collective_mean(x, axis_names, policy=policy, bits=bits)
    m2, _ = collective_mean(x * x, axis_names, policy=policy, bits=bits)
    return m1, jnp.maximum(m2 - m1 * m1, 0.0)


def elastic_reduce_mean(stack: jnp.ndarray, axis_names, *,
                        policy: str = "exact2",
                        block_size: int = 512) -> jnp.ndarray:
    """Topology-elastic global mean of a sharded item stack.

    ``stack`` is this shard's (m_local, ...) slice of a global stack of
    items (microbatch gradients, per-example losses); the result is the
    mean over *all* items on *all* shards, with the elastic guarantee:
    for a bitwise policy (``exact2`` since the residual-digit redesign,
    ``exact``, ``procrastinate``) the returned floats are bit-identical
    no matter how the same global stack is split across devices — 1x8,
    2x4, 8x1, or any permutation.  Three ingredients make that hold:

      * the quantization scale is sized from a ``pmax``-shared global
        max, so every shard prepares on the same grid;
      * the carry out of the local block schedule is partition-invariant
        (canonical integer limbs / exponent-indexed digits are pure
        functions of the global integer sums);
      * cross-shard merge is one associative integer ``psum`` per carry
        component (``merge_carry_across``).

    Must run inside ``shard_map``.  This is the reduction under
    ``repro.distributed.collectives.make_elastic_train_step`` and the
    resume-anywhere checkpoint story in ``docs/robustness.md``.

    >>> import jax, jax.numpy as jnp, numpy as np
    >>> from jax.sharding import Mesh, PartitionSpec as P
    >>> from jax.experimental.shard_map import shard_map
    >>> mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    >>> f = lambda x: elastic_reduce_mean(x, ("data",))
    >>> out = shard_map(f, mesh=mesh, in_specs=P("data"), out_specs=P(),
    ...                 check_rep=False)(jnp.asarray([[1.0, 3.0]]))
    >>> [float(v) for v in out]
    [1.0, 3.0]
    """
    axes = tuple(axis_names)
    pol = get_policy(policy)
    m_local = stack.shape[0]
    flat = stack.reshape(m_local, -1)                       # (m, D)
    num_total = jax.lax.psum(m_local, axes)
    # shared grid: every shard quantizes against the global max
    gmax = jax.lax.pmax(jnp.max(jnp.abs(flat)), axes)
    domain, ctx = pol.prepare(flat, num_total, shared_max=gmax)
    ids = jnp.zeros(m_local, jnp.int32)
    carry = get_backend("blocked").run(domain, ids, 1, policy=pol,
                                       block_size=block_size)
    carry = merge_carry_across(pol, carry, axes)
    out = pol.finalize(carry, ctx)[0]                       # (D,)
    return (out / num_total).reshape(stack.shape[1:])


def collective_mean_tree(grads, residuals, axis_names, *,
                         policy: str = "fast", bits: int = 8):
    """Pytree version of ``collective_mean``; residuals may be None.

    The fast tier fuses the whole tree: instead of one hierarchical psum
    per leaf (a per-leaf collective latency floor that dominates small
    parameter trees), every leaf ravel-concats into one batched psum per
    dtype per mesh axis (``fused_psum``, innermost axis first as before).
    psum is elementwise, so each leaf's bits are identical to the
    per-leaf lowering.  The integer tiers keep per-leaf collectives:
    their quantization grids (pmax-shared scale / window anchor) are
    sized per leaf, which is an accuracy property worth one collective
    each.
    """
    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = ([None] * len(flat_g) if residuals is None
              else tdef.flatten_up_to(residuals))
    if policy == "fast" and len(flat_g) > 1:
        axes = tuple(axis_names)
        leaves = flat_g
        for a in reversed(axes):    # innermost (fastest) axis first
            leaves = fused_psum(leaves, (a,))
        n = jax.lax.psum(jnp.float32(1.0), axes)  # detlint: ok[DET006] device count well under 2^24
        return tdef.unflatten([g / n for g in leaves]), \
            tdef.unflatten(flat_r)
    means, res = [], []
    for g, r in zip(flat_g, flat_r):
        m, nr = collective_mean(g, axis_names, policy=policy, bits=bits,
                                residual=r)
        means.append(m)
        res.append(nr)
    return tdef.unflatten(means), tdef.unflatten(res)
