"""Backend registry for ``repro.reduce`` — one schedule, four executors.

Every backend runs the *same* fixed block schedule (the JugglePAC pairing
contract): the (N, D) stream is padded to row blocks with
``OUT_OF_RANGE_LABEL``, each block contributes a one-hot matmul
``contrib = onehot(ids).T @ vals`` (the MXU form of "pair everything in
this block by label"), and blocks fold into the policy carry strictly in
stream order.  Because the schedule — not the executor — defines the
addition order, results are bitwise identical across backends:

  * ``ref``       — unrolled Python loop over blocks; the readable oracle
                    of the schedule (not of the math — that is
                    ``core.segmented.segment_sum_ref``).
  * ``blocked``   — ``lax.scan`` over blocks; jit-friendly, the CPU/GPU
                    default.
  * ``pallas``    — the TPU kernel (interpret mode off-TPU), with the VMEM
                    accumulator budget enforced by label-space tiling —
                    "2–8 PIS registers, not a BRAM".
  * ``shard_map`` — the multi-device executor: whole blocks of the same
                    schedule split across a device mesh, each shard runs a
                    local backend over its blocks, and the per-shard policy
                    carries merge with the policy's own combiner
                    (``merge_carry_across`` -> ``Policy.merge_across``)
                    before one finalize.  Integer carry components merge
                    by associative int32 psum — bitwise identical to the
                    single-device schedule *at any shard count* (every
                    carry component of exact / exact2 / procrastinate,
                    exact2's residual included since its digit redesign);
                    float carry state (fast/compensated carries) keeps
                    documented tolerance via an order-pinned fold instead
                    (see docs/architecture.md and docs/robustness.md).

New executors (GPU pallas, ...) drop in with ``@register_backend``; the
supported-policies capability set gates both explicit selection and
``select_backend``'s auto choice, and ``distributed=True`` marks executors
that take the mesh/axis plumbing.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, FrozenSet, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, PartitionSpec as P

from .policy import Policy
from .program import BlockProgram, block_contrib, plan_program  # noqa: F401

#: The one padding sentinel for every reduction entry point in this repo.
#: Negative => never equal to a real label in [0, num_segments), so one-hot
#: comparisons drop padded rows for free; scatter paths must mask it
#: explicitly (negative indices wrap in JAX) — see ``mask_out_of_range``.
OUT_OF_RANGE_LABEL: int = -1

BACKENDS: Dict[str, "Backend"] = {}


@dataclasses.dataclass(frozen=True)
class Backend:
    """A registered executor of the block schedule.

    ``run(values, ids, num_segments, policy=..., block_size=...,
    interpret=...)`` receives domain-prepared (N, D) values (f32 or int32 —
    ``Policy.prepare`` already ran) and returns the policy carry tuple of
    (num_segments, D) arrays, *not yet finalized*.
    """

    name: str
    run: Callable
    policies: FrozenSet[str]          # capability: policies it can execute
    description: str = ""
    #: distributed executors additionally accept ``mesh=``/``axis_names=``
    #: (threaded by ``reduce`` from its own kwargs or the ambient mesh)
    distributed: bool = False
    #: staged executors additionally accept ``program=`` (a planned
    #: ``BlockProgram``: contrib mode + stage cost hints); distributed
    #: staged executors also take ``to_domain=``/``prep_state=`` so the
    #: domain map runs per shard.  Off by default so pre-staged custom
    #: backends keep their old ``run`` signature.
    staged: bool = False

    def supports(self, policy: Policy) -> bool:
        return "*" in self.policies or policy.name in self.policies


def register_backend(name: str, *, policies, description: str = "",
                     distributed: bool = False, staged: bool = False):
    """Decorator: register ``fn`` as backend ``name``.

    ``policies``: iterable of policy names the executor implements, or the
    string "*" for schedule-generic executors that thread any policy carry.
    ``distributed=True`` marks executors that want the mesh plumbing
    (``run`` then also receives ``mesh=`` and ``axis_names=``).

    >>> import jax.numpy as jnp
    >>> import repro
    >>> @register_backend("doubled_demo", policies=("fast",),
    ...                   description="blocked, then doubled (demo)")
    ... def _run_doubled(values, ids, n, *, policy, block_size=512,
    ...                  interpret=None):
    ...     carry = get_backend("blocked").run(
    ...         values, ids, n, policy=policy, block_size=block_size)
    ...     return tuple(2 * c for c in carry)
    >>> float(repro.reduce(jnp.arange(4.0), backend="doubled_demo"))
    12.0
    >>> del BACKENDS["doubled_demo"]          # keep the registry clean
    """
    def deco(fn):
        if isinstance(policies, str):
            if policies != "*":
                raise ValueError(
                    f"register_backend({name!r}): policies must be an "
                    f"iterable of policy names or the string '*', got "
                    f"{policies!r} (did you mean ({policies!r},)?)")
            caps = frozenset({"*"})
        else:
            caps = frozenset(policies)
        BACKENDS[name] = Backend(name=name, run=fn, policies=caps,
                                 description=description,
                                 distributed=distributed, staged=staged)
        return fn
    return deco


def get_backend(name: str) -> Backend:
    try:
        return BACKENDS[name]
    except KeyError:
        raise ValueError(f"unknown backend {name!r}; registered: "
                         f"{sorted(BACKENDS)}") from None


def ambient_mesh() -> Optional[Mesh]:
    """The mesh of an enclosing ``with mesh:`` context, or None.

    The ``shard_map`` backend and ``select_backend`` both consult this so
    ``repro.reduce(...)`` scales out without explicit plumbing whenever the
    caller already activated a mesh.  Resolution happens *before* the jit
    boundary (in ``reduce``), so the dispatch cache keys on the concrete
    mesh, never on mutable thread state.
    """
    try:
        from jax._src import mesh as _mesh_lib      # no public accessor yet
        m = _mesh_lib.thread_resources.env.physical_mesh
        return None if m.empty else m
    except (ImportError, AttributeError):           # jax internals moved
        # degrade loudly, not silently: `with mesh:` auto-selection stops
        # working until this accessor is updated (tests pin the behavior)
        import warnings
        warnings.warn("repro.reduce: cannot read the ambient jax mesh "
                      "from this jax version; `with mesh:` backend "
                      "auto-selection is disabled — pass mesh= explicitly",
                      RuntimeWarning, stacklevel=2)
        return None


def default_mesh() -> Mesh:
    """One flat 'shards' axis over every visible device."""
    return Mesh(np.asarray(jax.devices()), ("shards",))


def select_backend(policy: Policy, mesh: Optional[Mesh] = None) -> Backend:
    """Auto-selection: shard_map under a multi-device mesh, the TPU kernel
    on TPU, the scanned form elsewhere.

    A mesh (explicit, or — for top-level untraced calls only — the
    ambient ``with mesh:`` context) spanning more than one device selects
    the ``shard_map`` backend, which shards the stream and runs the local
    auto-choice per shard.  The pallas wrapper already
    tiles the label space to its VMEM budget, so accumulator size never
    disqualifies it; off-TPU the kernel runs in interpret mode (a
    validation path, not a fast path), so ``blocked`` is the performance
    default.
    """
    if mesh is None:
        # Honor the ambient mesh only for top-level (untraced) calls:
        # reduce() is also called from inside jit/shard_map-traced model
        # code (MoE combine, serving means), where auto-escalating to a
        # nested shard_map would be wrong.  An explicit mesh= always wins.
        try:
            clean = jax.core.trace_state_clean()
        except Exception:
            clean = False       # can't tell => never auto-escalate
        mesh = ambient_mesh() if clean else None
    if mesh is not None and mesh.size > 1:
        cand = get_backend("shard_map")
        if cand.supports(policy):
            return cand
    return select_local_backend(policy)


def select_local_backend(policy: Policy) -> Backend:
    """The single-device auto-choice (also each shard_map shard's inner
    executor): pallas on TPU when capable, blocked otherwise."""
    if jax.default_backend() == "tpu":
        cand = get_backend("pallas")
        if cand.supports(policy):
            return cand
    return get_backend("blocked")


# ---------------------------------------------------------------------------
# Shared schedule helpers
# ---------------------------------------------------------------------------


def mask_out_of_range(segment_ids: jnp.ndarray,
                      num_segments: int) -> jnp.ndarray:
    """Map every label outside [0, num_segments) to OUT_OF_RANGE_LABEL."""
    ids = segment_ids.astype(jnp.int32)
    ok = (ids >= 0) & (ids < num_segments)
    return jnp.where(ok, ids, jnp.int32(OUT_OF_RANGE_LABEL))


def _pad_to_blocks(values, segment_ids, block_size):
    """Pad N to a multiple of block_size; padded rows carry the sentinel."""
    n, d = values.shape
    pad = (-n) % block_size
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        segment_ids = jnp.pad(segment_ids, (0, pad),
                              constant_values=OUT_OF_RANGE_LABEL)
    nb = (n + pad) // block_size
    return (values.reshape(nb, block_size, d),
            segment_ids.reshape(nb, block_size).astype(jnp.int32), nb)


def _block_contrib(vals, ids, num_segments, policy, program=None):
    """One gather stage for one (B, W) block — the staged program's
    contrib step, shared verbatim with the pallas kernel body
    (``repro.reduce.program.block_contrib``), so every backend lowers to
    the same dot(s) / lane scatter and the cross-backend bitwise contract
    holds per (policy, program)."""
    return block_contrib(vals, ids, num_segments, policy, program)


# ---------------------------------------------------------------------------
# Built-in backends
# ---------------------------------------------------------------------------


@register_backend("ref", policies="*", staged=True,
                  description="unrolled Python loop over blocks; the "
                              "readable schedule oracle")
def _run_ref(values, segment_ids, num_segments, *, policy: Policy,
             block_size: int = 512, interpret: Optional[bool] = None,
             program: Optional[BlockProgram] = None):
    vb, ib, nb = _pad_to_blocks(values, segment_ids, block_size)
    carry = policy.init(num_segments, values.shape[1])
    for b in range(nb):
        contrib = _block_contrib(vb[b], ib[b], num_segments, policy,
                                 program)
        carry = policy.update(carry, contrib)
        # pin the block boundary: without it XLA may fuse the unrolled
        # blocks and reassociate degenerate (S=1) dots, breaking the
        # bitwise-equal-to-scan contract the scheduled backends share.
        carry = jax.lax.optimization_barrier(carry)
    return carry


@register_backend("blocked", policies="*", staged=True,
                  description="lax.scan over blocks; jit-friendly "
                              "CPU/GPU default")
def _run_blocked(values, segment_ids, num_segments, *, policy: Policy,
                 block_size: int = 512, interpret: Optional[bool] = None,
                 program: Optional[BlockProgram] = None):
    vb, ib, nb = _pad_to_blocks(values, segment_ids, block_size)

    def step(carry, blk):
        vals, ids = blk
        contrib = _block_contrib(vals, ids, num_segments, policy, program)
        return policy.update(carry, contrib), None

    carry0 = policy.init(num_segments, values.shape[1])
    carry, _ = jax.lax.scan(step, carry0, (vb, ib))
    return carry


@register_backend("pallas", policies=("fast", "compensated", "exact",
                                      "exact2", "procrastinate"),
                  staged=True,
                  description="TPU Pallas kernel (interpret off-TPU), "
                              "double-buffered multi-block grid, "
                              "VMEM-budget label-space tiling")
def _run_pallas(values, segment_ids, num_segments, *, policy: Policy,
                block_size: int = 512, interpret: Optional[bool] = None,
                program: Optional[BlockProgram] = None,
                blocks_per_step: Optional[int] = None):
    from repro.kernels import jugglepac_segsum as _ss
    from repro.kernels.ops import seg_tile_for
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    d = values.shape[1]
    # same padding contract as every backend, flattened back for the grid
    vb, ib, _ = _pad_to_blocks(values, segment_ids, block_size)
    values = vb.reshape(-1, d)
    segment_ids = ib.reshape(-1)
    # VMEM-budget label tiling, shared with kernels.ops.segment_sum
    seg_tile = seg_tile_for(num_segments, d, policy.carry_len)
    parts = []
    for off in range(0, num_segments, seg_tile):
        s = min(seg_tile, num_segments - off)
        parts.append(_ss.segsum_policy_pallas(
            values, segment_ids, s, policy=policy,
            block_rows=block_size, seg_offset=off, interpret=interpret,
            program=program, blocks_per_step=blocks_per_step))
    if len(parts) == 1:
        return parts[0]
    return tuple(jnp.concatenate([p[i] for p in parts], axis=0)
                 for i in range(policy.carry_len))


@register_backend("shard_map", policies="*", distributed=True, staged=True,
                  description="multi-device: whole schedule blocks per "
                              "shard, per-shard domain prep, carries "
                              "merged with one fused collective per "
                              "carry dtype")
def _run_shard_map(values, segment_ids, num_segments, *, policy: Policy,
                   block_size: int = 512, interpret: Optional[bool] = None,
                   mesh: Optional[Mesh] = None, axis_names=None,
                   program: Optional[BlockProgram] = None,
                   to_domain=None, prep_state=()):
    """Split the block schedule across a device mesh.

    The (N, D) stream pads to ``nshards * block_size`` granularity with
    ``OUT_OF_RANGE_LABEL`` rows (sentinel blocks contribute the policy
    identity, so uneven N costs nothing but the padding), so every shard
    receives *whole, contiguous* schedule blocks.  Each shard folds its
    blocks with the local auto-backend — the identical kernel body the
    single-device path runs — and the per-shard carries merge via
    ``collective.merge_carry_across`` with the policy's combiner (one
    fused batched psum per carry dtype for the add-mergeable tiers).
    One finalize happens on the merged carry, outside this function,
    exactly as on every other backend.

    ``to_domain`` moves the domain map *inside* the shards: when given
    (the staged path ``reduce`` drives), ``values`` arrive raw and each
    shard maps its own row slice into the policy domain —
    ``to_domain(local_rows, *prep_state)`` with ``prep_state`` the
    globally-computed, replicated finalize context (quantization scale /
    window anchor).  ``Policy.to_domain`` is row-local by contract, so
    the per-shard map is bit-identical to slicing a whole-stream domain
    — zero bits change — while the expensive digitization (exact2's
    residual bin_split is the dominant smoke-size cost) now scales with
    the shard count instead of serializing on one device, and only the
    narrow raw rows cross the host-to-device boundary, not the widened
    domain planes.  ``to_domain=None`` keeps the legacy contract:
    ``values`` already domain-prepared (direct ``backend.run`` callers).

    Invariant: integer carry state is bitwise identical to the
    single-device schedule at any shard count, because the quantization
    scale / window anchor is one global constant (computed before
    sharding, on the full masked stream) and integer carry addition is
    associative — that is the whole result for ``exact``,
    ``procrastinate``, *and* ``exact2`` (whose residual travels as
    exponent-indexed int32 digits, so even its finalized float is bitwise
    at any shard count, mesh shape, or device permutation — the elastic
    guarantee in docs/robustness.md).  The float tiers (fast /
    compensated) change their cross-shard combine order with the shard
    count — documented tolerance, not bitwise.
    """
    # deferred: collective imports this module's sentinel at load time
    from .collective import merge_carry_across
    from jax.experimental.shard_map import shard_map
    if mesh is None:
        mesh = ambient_mesh() or default_mesh()
    axes = tuple(axis_names) if axis_names else tuple(mesh.axis_names)
    unknown = [a for a in axes if a not in mesh.axis_names]
    if unknown:
        raise ValueError(f"shard_map backend: axis_names {unknown} not in "
                         f"mesh axes {mesh.axis_names}")
    nshards = int(np.prod([mesh.shape[a] for a in axes]))
    inner = select_local_backend(policy)
    inner_kw = {"program": program} if inner.staged else {}

    n, d = values.shape
    pad = (-n) % (nshards * block_size)
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        segment_ids = jnp.pad(segment_ids, (0, pad),
                              constant_values=OUT_OF_RANGE_LABEL)

    prep_state = tuple(prep_state)

    def shard_body(v, ids, *prep):
        if to_domain is not None:
            v = to_domain(v, *prep)
        carry = inner.run(v, ids, num_segments, policy=policy,
                          block_size=block_size, interpret=interpret,
                          **inner_kw)
        # the merge issues immediately after the local fold, with no
        # barrier in between: one fused collective per carry dtype, free
        # to overlap the tail of the last block's update on hardware
        # with async collectives
        return merge_carry_across(policy, carry, axes)

    row_spec = axes if len(axes) > 1 else axes[0]
    return shard_map(shard_body, mesh=mesh,
                     in_specs=(P(row_spec, None), P(row_spec))
                     + (P(),) * len(prep_state),
                     out_specs=P(), check_rep=False)(
                         values, segment_ids.astype(jnp.int32),
                         *prep_state)
