"""jamba-v0.1-52b — 32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536,
Mamba + attention 1:7 interleave, MoE 16 experts top-2 every other layer.
[arXiv:2403.19887; hf]

Period of 8 (the Jamba block): attention at position 4, Mamba elsewhere;
MoE MLP at odd positions, dense SwiGLU at even ones."""

from repro.models.config import BlockSpec, MambaCfg, ModelConfig, MoECfg

_PERIOD = tuple(
    BlockSpec("attn" if i == 4 else "mamba",
              "moe" if i % 2 == 1 else "swiglu")
    for i in range(8))

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=65536,
    period=_PERIOD,
    moe=MoECfg(num_experts=16, top_k=2, d_ff_expert=14336),
    mamba=MambaCfg(d_state=16, d_conv=4, expand=2),
    subquadratic=True,        # hybrid: O(1) mamba state + 4 attn layers
)

SMOKE = CONFIG.scaled(
    n_layers=8, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
    moe=MoECfg(num_experts=4, top_k=2, d_ff_expert=256),
    mamba=MambaCfg(d_state=4, d_conv=4, expand=2), dtype="float32")
