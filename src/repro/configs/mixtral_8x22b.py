"""mixtral-8x22b — 56L d_model=6144 48H (GQA kv=8) d_ff=16384 vocab=32768,
MoE 8 experts top-2, sliding-window attention. [arXiv:2401.04088; hf]"""

from repro.models.config import BlockSpec, ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    n_layers=56,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    d_ff=16384,
    vocab=32768,
    period=(BlockSpec("attn", "moe"),),
    moe=MoECfg(num_experts=8, top_k=2, d_ff_expert=16384),
    window=4096,
    rope_theta=1e6,
    subquadratic=True,        # SWA ring cache => O(window) decode memory
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_ff=256, vocab=512,
    moe=MoECfg(num_experts=4, top_k=2, d_ff_expert=256), window=16,
    dtype="float32")
