"""Architecture registry: ``--arch <id>`` resolution.

Each module exposes CONFIG (the exact published configuration) and SMOKE
(a reduced same-family configuration for CPU smoke tests)."""

from __future__ import annotations

import importlib
from typing import Dict

from repro.models.config import ModelConfig, SHAPES, SHAPES_BY_NAME, ShapeCfg

_MODULES = {
    "mixtral-8x22b": "mixtral_8x22b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "xlstm-125m": "xlstm_125m",
    "phi3-medium-14b": "phi3_medium_14b",
    "deepseek-7b": "deepseek_7b",
    "stablelm-1.6b": "stablelm_1_6b",
    "minitron-8b": "minitron_8b",
    "qwen2-vl-7b": "qwen2_vl_7b",
    "jamba-v0.1-52b": "jamba_v0_1_52b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.SMOKE


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def shape_applicable(cfg: ModelConfig, shape: ShapeCfg) -> bool:
    """long_500k runs only for sub-quadratic archs (DESIGN.md §6)."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False
    return True
