"""deepseek-v2-lite-16b — 27L d_model=2048 16H d_ff=1408 vocab=102400,
MLA kv_lora=512, MoE 64 routed top-6 + 2 shared. [arXiv:2405.04434; hf]

Assignment note: the spec line says both "MoE 64e top-6" and "160 routed";
64 routed experts is the published V2-Lite config, so we use 64 (160 is the
full V2).  All 27 layers are MoE per the assignment line (the HF checkpoint
makes layer 0 dense; the assignment config omits that, and we follow the
assignment — recorded in DESIGN.md §Arch-applicability)."""

from repro.models.config import BlockSpec, ModelConfig, MoECfg

CONFIG = ModelConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    n_layers=27,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    period=(BlockSpec("attn", "moe"),),
    moe=MoECfg(num_experts=64, top_k=6, d_ff_expert=1408, num_shared=2,
               d_ff_shared=1408, router_norm_topk=True),
    attn_type="mla",
    kv_lora_rank=512,
    qk_nope_dim=128,
    qk_rope_dim=64,
    v_head_dim=128,
)

SMOKE = CONFIG.scaled(
    n_layers=2, d_model=128, n_heads=4, d_ff=64, vocab=512,
    moe=MoECfg(num_experts=8, top_k=2, d_ff_expert=64, num_shared=1,
               d_ff_shared=64, router_norm_topk=True),
    kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8, v_head_dim=16,
    dtype="float32")
