"""xlstm-125m — 12L d_model=768 4 heads vocab=50304, sLSTM + mLSTM blocks,
no separate FFN (d_ff=0). [arXiv:2405.04517]

Block mix: 3 mLSTM : 1 sLSTM per period (the xLSTM paper's LM configs are
mLSTM-dominant); 12 layers = 3 periods."""

from repro.models.config import BlockSpec, ModelConfig, XLSTMCfg

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    period=(BlockSpec("mlstm", "none"), BlockSpec("mlstm", "none"),
            BlockSpec("mlstm", "none"), BlockSpec("slstm", "none")),
    xlstm=XLSTMCfg(num_heads=4, proj_factor_m=2.0, proj_factor_s=4 / 3,
                   conv_kernel=4),
    tie_embeddings=True,
    subquadratic=True,        # O(1) recurrent state
)

SMOKE = CONFIG.scaled(
    n_layers=4, d_model=64, vocab=256,
    xlstm=XLSTMCfg(num_heads=2, proj_factor_m=2.0, proj_factor_s=4 / 3,
                   conv_kernel=4),
    dtype="float32")
