"""minitron-8b — 32L d_model=4096 32H (GQA kv=8) d_ff=16384 vocab=256000,
pruned nemotron. [arXiv:2407.14679; hf]"""

from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab=256000,
    period=(BlockSpec("attn", "swiglu"),),
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                      d_ff=256, vocab=512, dtype="float32")
