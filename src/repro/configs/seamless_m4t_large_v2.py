"""seamless-m4t-large-v2 — enc-dec, 24L encoder + 24L decoder, d_model=1024
16H (MHA kv=16) d_ff=8192 vocab=256206, multimodal. [arXiv:2308.11596; hf]

Backbone only: the speech frontend is a STUB — input_specs() provides
precomputed audio-frame embeddings (B, S_enc, D) for the encoder; the
decoder consumes text tokens with cross-attention to the encoder memory."""

from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-large-v2",
    family="audio",
    n_layers=24,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=8192,
    vocab=256206,
    period=(BlockSpec("attn", "gelu"),),
    encoder_layers=24,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                      d_ff=256, vocab=512, encoder_layers=2, dtype="float32")
