"""qwen2-vl-7b — 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064,
M-RoPE, dynamic resolution. [arXiv:2409.12191; hf]

Backbone only: the vision frontend is a STUB — input_specs() provides
precomputed patch embeddings (B, S, D) plus 3-axis M-RoPE position ids.
Dynamic resolution = variable patches per image, which the segmented
(JugglePAC) pooling path handles; decode uses text tokens."""

from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-7b",
    family="vlm",
    n_layers=28,
    d_model=3584,
    n_heads=28,
    n_kv_heads=4,
    d_ff=18944,
    vocab=152064,
    period=(BlockSpec("attn", "swiglu"),),
    mrope=True,
    rope_theta=1e6,
    embed_inputs=True,
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                      d_ff=256, vocab=512, dtype="float32")
