"""stablelm-1.6b — 24L d_model=2048 32H (MHA kv=32) d_ff=5632 vocab=100352.
[hf:stabilityai/stablelm-2-1_6b]"""

from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-1.6b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    d_ff=5632,
    vocab=100352,
    period=(BlockSpec("attn", "swiglu"),),
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                      d_ff=256, vocab=512, dtype="float32")
