"""deepseek-7b — 30L d_model=4096 32H (MHA kv=32) d_ff=11008 vocab=102400,
llama architecture. [arXiv:2401.02954; hf]"""

from repro.models.config import BlockSpec, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=11008,
    vocab=102400,
    period=(BlockSpec("attn", "swiglu"),),
)

SMOKE = CONFIG.scaled(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                      d_ff=256, vocab=512, dtype="float32")
