"""Continuous-batching serving engine: paged KV admission, chunked
prefill, per-slot decode, in-order results.

This is the paper's scenario run at serving granularity.  JugglePAC
juggles back-to-back variable-length datasets through one pipelined
accumulator and emits per-set results in input order; the engine juggles
back-to-back variable-length *requests* through a fixed array of decode
slots and delivers per-request results in submission order:

  * requests  = the paper's variable-length sets;
  * decode slots = the pipeline stages (``max_batch`` of them, never
    reshaped — admission swaps a retired request's slot to the next
    arrival mid-stream, the batch keeps stepping);
  * reorder buffer = the in-order output contract (``Scheduler``);
  * ``PagedKVPool`` = the bounded intermediate storage (admission is
    gated on free KV pages, the "few PIS registers" rule).

Prefill streams in ``prefill_chunk``-token pieces interleaved with decode
steps (chunked prefill), so one long prompt cannot stall the in-flight
batch.  Every chunk is padded to the same width and every decode step runs
at the full ``max_batch`` width with idle slots masked, so the engine
compiles exactly two model programs — and a request's logits are bitwise
independent of batch composition (row-parallel math at fixed shapes),
which is what makes the sequential one-at-a-time oracle an *exact* spec
for the batched engine under greedy decoding.

Per-request accuracy plumbing goes through ``repro.reduce``:

  * sampling keys derive from (engine seed, request id or ``Request.seed``,
    step) — never from a shared stream split — so sampled tokens are
    reproducible under any batch composition;
  * per-request ``mean_logprob`` is one segmented mean over the flat
    (step x slot) logprob stream with the ``logprob_policy`` knob —
    ``compensated`` by default; ``exact2`` makes the mean *bitwise*
    invariant to batch composition (serving replicas agree to the last
    bit, the property pinned by tests/test_serve.py).

The old all-at-once API survives as a thin wrapper: ``generate()``
enqueues every request at time zero and drains the loop.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Callable, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import reduce as _reduce
from repro.models import decode_step, forward, init_caches, pad_caches_to
from repro.models.config import ModelConfig

from .kv_pool import PagedKVPool
from .scheduler import Scheduler, TrackedRequest


@dataclasses.dataclass
class Request:
    prompt: Sequence[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_id: Optional[int] = None
    #: optional per-request sampling seed: when set, sampled tokens depend
    #: only on (engine seed, this seed, step) — stable even if the request
    #: is resubmitted under a different request id
    seed: Optional[int] = None


@dataclasses.dataclass
class Result:
    tokens: List[int]
    prompt_len: int
    mean_logprob: Optional[float] = None
    rid: int = -1
    finish_reason: Optional[str] = None
    latency_s: float = 0.0


class Engine:
    """Continuous-batching engine over ``Scheduler`` + ``PagedKVPool``.

    ``max_batch`` decode slots share one pre-allocated cache of
    ``max_len`` context each; ``num_pages`` x ``page_size`` tokens of KV
    pool gate admission (default: exactly enough for every slot at full
    context, so admission is slot-bound; shrink it to exercise queueing).
    """

    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 512,
                 seed: int = 0, max_batch: int = 8, page_size: int = 16,
                 num_pages: Optional[int] = None,
                 prefill_chunk: int = 32,
                 logprob_policy: str = "compensated"):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.max_batch = max_batch
        self.prefill_chunk = max(1, int(prefill_chunk))
        self.logprob_policy = logprob_policy
        _reduce.get_policy(logprob_policy)        # fail fast on a typo
        self._base_key = jax.random.PRNGKey(seed)
        pool_pages = num_pages if num_pages is not None else \
            max_batch * PagedKVPool(1, page_size).pages_for(max_len)
        self.pool = PagedKVPool(pool_pages, page_size)
        self.scheduler = Scheduler(max_batch, self.pool)
        self._caches = init_caches(cfg, max_batch, max_len)
        # chunked prefill streams through the attention extend path; SSM
        # states need sequential prefill and ring (SWA) caches must not
        # see padded chunk writes — those archs prefill whole-prompt.
        self._extend_ok = (all(sp.kind == "attn" for sp in cfg.period)
                           and cfg.window is None)
        self._clock = 0
        self._rid_base = 0
        self._lp_vals: List[np.ndarray] = []
        self._lp_ids: List[np.ndarray] = []

        def _decode_fn(params, tok, caches, pos, active):
            logits, new_caches = decode_step(params, cfg, tok, caches, pos,
                                             moe_impl="dense")
            # freeze idle / mid-prefill slots: their rows' garbage writes
            # (token 0 at position 0) and length bumps must not stick
            def keep(new, old):
                sel = active.reshape((1, -1) + (1,) * (new.ndim - 2))
                return jnp.where(sel, new, old)
            new_caches = jax.tree.map(keep, new_caches, caches)
            return logits, new_caches

        def _with_length(caches, value):
            out = []
            for c in caches:
                core = c["core"]
                if hasattr(core, "length"):
                    core = core._replace(
                        length=jnp.full_like(core.length, value))
                out.append({**c, "core": core})
            return out

        def _prefill_chunk_fn(params, caches, slot, toks, start, n_valid):
            # one prompt chunk for one slot: slice the slot's cache view,
            # extend it with the chunk (pad tokens write past n_valid and
            # are rolled back via the length repair), splice it back
            sub = jax.tree.map(
                lambda l: jax.lax.dynamic_slice_in_dim(l, slot, 1, axis=1),
                caches)
            sub = _with_length(sub, start)
            logits, new_sub, _ = forward(params, cfg, tokens=toks,
                                         mode="decode", caches=sub,
                                         moe_impl="dense",
                                         position_offset=start)
            new_sub = _with_length(new_sub, start + n_valid)
            caches = jax.tree.map(
                lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                    full, one, slot, axis=1),
                caches, new_sub)
            last = jax.lax.dynamic_slice_in_dim(logits, n_valid - 1, 1,
                                                axis=1)
            return last, caches

        def _classic_prefill_fn(params, caches, slot, toks):
            # whole-prompt fallback (SSM / sliding-window archs): standard
            # prefill at B=1, pad to max_len, splice into the slot
            logits, new_sub, _ = forward(params, cfg, tokens=toks,
                                         mode="prefill", moe_impl="dense")
            new_sub = pad_caches_to(cfg, new_sub, self.max_len)
            caches = jax.tree.map(
                lambda full, one: jax.lax.dynamic_update_slice_in_dim(
                    full, one, slot, axis=1),
                caches, new_sub)
            return logits[:, -1:], caches

        def _sample_fn(key, logits, custom, idv, steps, temps):
            # per-request PRNG: (engine seed, request id | Request.seed,
            # step) — batchmates and finish order cannot perturb a
            # request's sample stream
            def mk(c, i, s):
                return jax.random.fold_in(
                    jax.random.fold_in(jax.random.fold_in(key, c), i), s)
            keys = jax.vmap(mk)(custom, idv, steps)
            lg = logits[:, -1, :cfg.vocab]
            greedy = jnp.argmax(lg, axis=-1)
            scaled = lg / jnp.maximum(temps[:, None], 1e-6)
            sampled = jax.vmap(jax.random.categorical)(keys, scaled)
            tok = jnp.where(temps > 0, sampled, greedy)
            logp = jax.nn.log_softmax(lg, axis=-1)
            lp = jnp.take_along_axis(logp, tok[:, None], axis=-1)[:, 0]
            return tok.astype(jnp.int32), lp.astype(jnp.float32)

        self._decode = jax.jit(_decode_fn)
        self._prefill_chunk = jax.jit(_prefill_chunk_fn)
        self._classic_prefill = jax.jit(_classic_prefill_fn)
        self._sample = jax.jit(_sample_fn)

    # -- intake ------------------------------------------------------------

    def submit(self, request: Request, *, arrival: float = 0.0) -> int:
        """Enqueue one request; ``arrival`` is in engine steps relative to
        the start of the next :meth:`run`.  Returns the request id, which
        is also its delivery position."""
        plen = len(request.prompt)
        need = min(plen + max(request.max_new_tokens, 1), self.max_len)
        return self.scheduler.submit(request, arrival=arrival,
                                     need_tokens=need)

    def cancel(self, rid: int) -> bool:
        """Kill a request wherever it is (queued, prefilling, or
        mid-decode).  Its KV pages and slot are released immediately;
        other requests' outputs are untouched (per-slot isolation).  The
        reorder buffer still delivers a ``cancelled`` result in order."""
        tr = self.scheduler.tracked(rid)
        if tr.state == "done":
            return False
        if not tr.out:
            tr.out = list(tr.request.prompt)
        tr.finish_reason = "cancelled"
        self.scheduler.finish(tr, self._result_of(tr), reason="cancelled")
        return True

    # -- the continuous loop ----------------------------------------------

    def run(self, *, on_step: Optional[Callable] = None) -> List[Result]:
        """Drain every submitted request; returns results in submission
        order.  ``on_step(engine, step)`` fires after each engine step
        (fault injection, probes)."""
        sched = self.scheduler
        self._clock = 0
        self._rid_base = sched._next_deliver
        self._lp_vals, self._lp_ids = [], []
        delivered: List[Result] = []
        while sched.has_work():
            sched.advance(self._clock)
            progressed = bool(sched.admit())
            progressed |= self._prefill_work()
            progressed |= self._decode_work()
            delivered.extend(sched.pop_ready())
            if on_step is not None:
                on_step(self, self._clock)
                delivered.extend(sched.pop_ready())
            if not progressed and sched.next_arrival() is None \
                    and not any(r is not None for r in sched.slots) \
                    and sched._queue:
                raise RuntimeError(
                    "admission deadlock: queued requests cannot be "
                    "admitted and no slot is active")
            self._clock += 1
        self._finalize_logprobs(delivered)
        return delivered

    def generate(self, requests: List[Request], *,
                 truncate_prompts: bool = False) -> List[Result]:
        """Generate for a batch of requests (all enqueued at time zero,
        then drained — the all-at-once wrapper over the continuous loop).

        Validation happens up front — an empty batch, an empty prompt,
        or a prompt that cannot fit the engine's ``max_len`` context
        (together with at least one new token) fails fast with a
        ``ValueError`` naming the offending request, instead of a shape
        error deep in prefill.  ``truncate_prompts=True`` instead keeps
        the *last* ``max_len - 1`` tokens of an over-long prompt (the
        usual sliding-context behavior); ``Result.prompt_len`` then
        reports the truncated length.
        """
        if not requests:
            raise ValueError("generate() needs at least one request; "
                             "got an empty batch")
        limit = self.max_len - 1       # decode stops at max_len - 1
        for i, r in enumerate(requests):
            if len(r.prompt) == 0:
                raise ValueError(f"request {i} has an empty prompt")
            if len(r.prompt) > limit and not truncate_prompts:
                raise ValueError(
                    f"request {i} prompt has {len(r.prompt)} tokens but "
                    f"the engine context is max_len={self.max_len} "
                    f"(prompts are capped at {limit} so at least one "
                    f"token can be generated); shorten the prompt or "
                    f"pass truncate_prompts=True")
        if truncate_prompts:
            requests = [dataclasses.replace(r, prompt=list(r.prompt)[-limit:])
                        for r in requests]
        rids = [self.submit(r) for r in requests]
        by_rid = {res.rid: res for res in self.run()}
        return [by_rid[rid] for rid in rids]

    # -- phases ------------------------------------------------------------

    def _prefill_work(self) -> bool:
        """One prompt chunk per mid-prefill slot (chunked prefill: long
        prompts interleave with decode steps instead of stalling them)."""
        worked = False
        for tr in self.scheduler.in_state("prefill"):
            worked = True
            prompt = list(tr.request.prompt)
            if self._extend_ok:
                chunk = self.prefill_chunk
                start = tr.prefill_pos
                piece = prompt[start:start + chunk]
                n_valid = len(piece)
                toks = np.zeros((1, chunk), np.int32)
                toks[0, :n_valid] = piece
                logits, self._caches = self._prefill_chunk(
                    self.params, self._caches, jnp.int32(tr.slot),
                    jnp.asarray(toks), jnp.int32(start),
                    jnp.int32(n_valid))
                tr.prefill_pos = start + n_valid
                if tr.prefill_pos < len(prompt):
                    continue                      # more chunks to stream
            else:
                toks = np.asarray(prompt, np.int32)[None, :]
                logits, self._caches = self._classic_prefill(
                    self.params, self._caches, jnp.int32(tr.slot),
                    jnp.asarray(toks))
                tr.prefill_pos = len(prompt)
            self._first_token(tr, logits)
        return worked

    def _first_token(self, tr: TrackedRequest, logits) -> None:
        """Prefill just completed: sample the request's first token from
        the last prompt position's logits."""
        req = tr.request
        custom, idv = self._key_id(tr)
        tok, lp = self._sample(
            self._base_key, logits,
            jnp.asarray([custom], jnp.int32), jnp.asarray([idv], jnp.int32),
            jnp.asarray([0], jnp.int32),
            jnp.asarray([max(req.temperature, 0.0)], jnp.float32))
        t = int(np.asarray(tok)[0])
        self._lp_vals.append(np.asarray(lp, np.float32))
        self._lp_ids.append(np.asarray([tr.rid - self._rid_base], np.int32))
        tr.out = list(req.prompt) + [t]
        tr.last_token = t
        tr.new_tokens = 1
        tr.state = "decode"
        self._maybe_retire(tr, t)

    def _decode_work(self) -> bool:
        """One lock-step decode step across every decode-state slot; idle
        and mid-prefill slots ride along masked (fixed shapes => one
        compiled program, and per-row bitwise independence)."""
        dec = self.scheduler.in_state("decode")
        if not dec:
            return False
        b = self.max_batch
        toks = np.zeros((b, 1), np.int32)
        pos = np.zeros(b, np.int32)
        active = np.zeros(b, bool)
        custom = np.zeros(b, np.int32)
        idv = np.zeros(b, np.int32)
        steps = np.zeros(b, np.int32)
        temps = np.zeros(b, np.float32)
        for tr in dec:
            s = tr.slot
            active[s] = True
            toks[s, 0] = tr.last_token
            plen = len(tr.request.prompt)
            pos[s] = plen + tr.new_tokens - 1     # == the slot's cache len
            custom[s], idv[s] = self._key_id(tr)
            steps[s] = tr.new_tokens
            temps[s] = max(tr.request.temperature, 0.0)
        logits, self._caches = self._decode(
            self.params, jnp.asarray(toks), self._caches,
            jnp.asarray(pos), jnp.asarray(active))
        tok, lp = self._sample(self._base_key, logits,
                               jnp.asarray(custom), jnp.asarray(idv),
                               jnp.asarray(steps), jnp.asarray(temps))
        tok_np = np.asarray(tok)
        ids = np.full(b, _reduce.OUT_OF_RANGE_LABEL, np.int32)
        for tr in dec:
            ids[tr.slot] = tr.rid - self._rid_base
        self._lp_vals.append(np.asarray(lp, np.float32))
        self._lp_ids.append(ids)
        for tr in dec:
            t = int(tok_np[tr.slot])
            tr.out.append(t)
            tr.last_token = t
            tr.new_tokens += 1
            self._maybe_retire(tr, t)
        return True

    def _maybe_retire(self, tr: TrackedRequest, last_tok: int) -> None:
        req = tr.request
        plen = len(req.prompt)
        reason = None
        if req.eos_id is not None and last_tok == req.eos_id:
            reason = "stop"
        elif tr.new_tokens >= req.max_new_tokens:
            reason = "length"
        elif plen + tr.new_tokens >= self.max_len:
            reason = "length"                     # context full
        if reason is not None:
            tr.finish_reason = reason
            self.scheduler.finish(tr, self._result_of(tr), reason=reason)

    # -- results -----------------------------------------------------------

    def _key_id(self, tr: TrackedRequest):
        """(custom-seed flag, id) feeding the per-request PRNG fold-in."""
        if tr.request.seed is not None:
            return 1, int(tr.request.seed)
        return 0, tr.rid

    def _result_of(self, tr: TrackedRequest) -> Result:
        lat = max(time.perf_counter() - tr.arrive_wall, 0.0) \
            if tr.arrive_wall else 0.0
        return Result(tokens=list(tr.out) or list(tr.request.prompt),
                      prompt_len=len(tr.request.prompt),
                      rid=tr.rid, finish_reason=tr.finish_reason,
                      latency_s=lat)

    def _finalize_logprobs(self, results: List[Result]) -> None:
        """One segmented mean over the whole run's (step x slot) logprob
        stream — requests are the variable-length sets; steps where a slot
        was idle / another request carry the sentinel and vanish from both
        sum and count.  ``logprob_policy`` selects the accuracy tier."""
        if not self._lp_vals:
            return
        nseg = max(r.rid for r in results) - self._rid_base + 1 \
            if results else 0
        if nseg <= 0:
            return
        mean = _reduce.reduce(
            jnp.asarray(np.concatenate(self._lp_vals)),
            segment_ids=jnp.asarray(np.concatenate(self._lp_ids)),
            num_segments=nseg, op="mean", policy=self.logprob_policy)
        mean_np = np.asarray(mean)
        for r in results:
            sampled = len(r.tokens) - r.prompt_len
            if sampled > 0:
                r.mean_logprob = float(mean_np[r.rid - self._rid_base])
        self._lp_vals, self._lp_ids = [], []
