"""Batched serving engine: continuous prefill + decode with a KV cache pool.

A deliberately small but real engine:
  * requests (prompt token lists) are batched up to ``max_batch``;
  * one shared prefill (padded to the longest prompt in the batch, left
    padding via per-request lengths) builds the caches;
  * lock-step decode with per-request stopping (eos or max_new_tokens);
  * greedy or temperature sampling with a seeded key per request;
  * per-request mean log-probability of the generated tokens, computed as
    one ``repro.reduce`` segmented mean: requests are the paper's
    variable-length sets (they stop at different steps), and steps where a
    request is already done carry the ``OUT_OF_RANGE_LABEL`` sentinel so
    they drop out of both sum and count.

The decode step is the same function the multi-pod dry-run lowers — on a
real pod it runs sharded; here it runs on CPU for the examples/tests.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import reduce as _reduce
from repro.models import (decode_step, encode, forward, init_caches,
                          pad_caches_to)
from repro.models.config import ModelConfig


@dataclasses.dataclass
class Request:
    prompt: Sequence[int]
    max_new_tokens: int = 32
    temperature: float = 0.0
    eos_id: Optional[int] = None


@dataclasses.dataclass
class Result:
    tokens: List[int]
    prompt_len: int
    mean_logprob: Optional[float] = None


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, max_len: int = 512,
                 seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.max_len = max_len
        self.key = jax.random.PRNGKey(seed)
        self._decode = jax.jit(
            lambda p, t, c, pos: decode_step(p, cfg, t, c, pos,
                                             moe_impl="dense"))

    def _prefill(self, tokens: jnp.ndarray):
        logits, caches, _ = forward(self.params, self.cfg, tokens=tokens,
                                    mode="prefill", moe_impl="dense")
        return logits[:, -1:], pad_caches_to(self.cfg, caches, self.max_len)

    def generate(self, requests: List[Request], *,
                 truncate_prompts: bool = False) -> List[Result]:
        """Generate for a batch of requests.

        Validation happens up front — an empty batch, an empty prompt,
        or a prompt that cannot fit the engine's ``max_len`` context
        (together with at least one new token) fails fast with a
        ``ValueError`` naming the offending request, instead of a shape
        error deep in prefill.  ``truncate_prompts=True`` instead keeps
        the *last* ``max_len - 1`` tokens of an over-long prompt (the
        usual sliding-context behavior); ``Result.prompt_len`` then
        reports the truncated length.
        """
        cfg = self.cfg
        if not requests:
            raise ValueError("generate() needs at least one request; "
                             "got an empty batch")
        limit = self.max_len - 1       # decode stops at max_len - 1
        for i, r in enumerate(requests):
            if len(r.prompt) == 0:
                raise ValueError(f"request {i} has an empty prompt")
            if len(r.prompt) > limit and not truncate_prompts:
                raise ValueError(
                    f"request {i} prompt has {len(r.prompt)} tokens but "
                    f"the engine context is max_len={self.max_len} "
                    f"(prompts are capped at {limit} so at least one "
                    f"token can be generated); shorten the prompt or "
                    f"pass truncate_prompts=True")
        if truncate_prompts:
            requests = [dataclasses.replace(r, prompt=list(r.prompt)[-limit:])
                        for r in requests]
        bsz = len(requests)
        plens = [len(r.prompt) for r in requests]
        pmax = max(plens)
        # right-align prompts (left padding) so position pmax-1 is the last
        # prompt token for every request
        toks = np.zeros((bsz, pmax), np.int32)
        for i, r in enumerate(requests):
            toks[i, pmax - plens[i]:] = np.asarray(r.prompt, np.int32)
        logits, caches = self._prefill(jnp.asarray(toks))

        out = [list(r.prompt) for r in requests]
        done = np.zeros(bsz, bool)
        max_new = max(r.max_new_tokens for r in requests)
        position = pmax
        cur, lp = self._sample(logits, requests)
        lp_chunks = [np.asarray(lp)]
        id_chunks = [np.arange(bsz, dtype=np.int32)]
        for i, r in enumerate(requests):
            t = int(cur[i, 0])
            out[i].append(t)
            if (r.eos_id is not None and t == r.eos_id) or \
                    r.max_new_tokens <= 1:
                done[i] = True

        for step in range(1, max_new):
            if bool(done.all()) or position >= self.max_len - 1:
                break
            logits, caches = self._decode(self.params, cur, caches,
                                          jnp.int32(position))
            cur, lp = self._sample(logits, requests)
            # a step only counts toward a request still generating; done
            # slots get the sentinel and vanish from the segmented mean
            id_chunks.append(np.where(~done, np.arange(bsz),
                                      _reduce.OUT_OF_RANGE_LABEL)
                             .astype(np.int32))
            lp_chunks.append(np.asarray(lp))
            position += 1
            for i, r in enumerate(requests):
                if done[i]:
                    continue
                t = int(cur[i, 0])
                out[i].append(t)
                if (r.eos_id is not None and t == r.eos_id) or \
                        len(out[i]) - plens[i] >= r.max_new_tokens:
                    done[i] = True

        # per-request mean logprob: one segmented mean over the flat
        # (steps x batch) stream — requests are variable-length sets.
        # Pad to the (max_new, bsz) shape so the jitted reduce dispatch
        # compiles per batch composition (max_new_tokens x batch size),
        # not per data-dependent early-stop step count; padded steps
        # carry the sentinel.
        while len(lp_chunks) < max_new:
            lp_chunks.append(np.zeros(bsz, np.float32))
            id_chunks.append(np.full(bsz, _reduce.OUT_OF_RANGE_LABEL,
                                     np.int32))
        mean_lp = _reduce.reduce(
            jnp.asarray(np.concatenate(lp_chunks)),
            segment_ids=jnp.asarray(np.concatenate(id_chunks)),
            num_segments=bsz, op="mean", policy="compensated")
        return [Result(tokens=o, prompt_len=p, mean_logprob=float(m))
                for o, p, m in zip(out, plens, np.asarray(mean_lp))]

    def _sample(self, logits, requests):
        """Returns (token (B, 1) int32, logprob-of-token (B,) f32)."""
        self.key, sub = jax.random.split(self.key)
        temps = jnp.asarray([[max(r.temperature, 0.0)] for r in requests])
        greedy = jnp.argmax(logits[:, -1, :self.cfg.vocab], axis=-1)
        scaled = logits[:, -1, :self.cfg.vocab] / jnp.maximum(temps, 1e-6)
        sampled = jax.random.categorical(sub, scaled, axis=-1)
        tok = jnp.where(temps[:, 0] > 0, sampled, greedy)
        logp = jax.nn.log_softmax(logits[:, -1, :self.cfg.vocab], axis=-1)
        lp = jnp.take_along_axis(logp, tok[:, None], axis=-1)[:, 0]
        return tok[:, None].astype(jnp.int32), lp.astype(jnp.float32)
