"""Continuous-batching scheduler: arrival queue, decode slots, in-order
results.

This is JugglePAC's control plane, lifted to serving.  The paper's problem
is a stream of back-to-back variable-length *sets* whose results must come
out in input order with bounded intermediate state; here the sets are
requests, the pipeline stages are the engine's fixed decode *slots*, and
the in-order output guarantee is the *reorder buffer*: requests finish in
whatever order their lengths dictate, but results are released strictly in
submission order.

Lifecycle of one request::

    submit()          pending   (arrival time not reached yet)
      advance(now)    queued    (arrived; waiting for a slot + KV pages)
      admit()         prefill   (slot assigned, pages reserved; prompt
                                 streams in chunks between decode steps)
                      decode    (engine flips the state after the last
                                 prompt chunk samples the first token)
      finish()        done      (slot + pages released, result buffered
                                 until every earlier rid has finished)

Admission is FIFO over *arrived* requests and is gated on the
``PagedKVPool``: a request is admitted only when its worst-case KV
footprint fits in free pages, so a burst of long prompts queues instead of
thrashing memory.  The scheduler is pure host-side bookkeeping — the
engine owns every jitted computation.
"""

from __future__ import annotations

import dataclasses
import heapq
import time
from typing import Any, Dict, List, Optional

from .kv_pool import PagedKVPool


@dataclasses.dataclass
class TrackedRequest:
    """One request's scheduling state (host-side, engine-agnostic)."""
    rid: int
    request: Any
    arrival: float
    need_tokens: int                 # worst-case KV footprint (pool gate)
    state: str = "pending"           # pending|queued|prefill|decode|done
    slot: Optional[int] = None
    prefill_pos: int = 0             # prompt tokens already streamed
    new_tokens: int = 0              # tokens sampled so far
    last_token: int = 0
    out: List[int] = dataclasses.field(default_factory=list)
    submit_wall: float = 0.0
    arrive_wall: float = 0.0
    finish_wall: float = 0.0
    finish_reason: Optional[str] = None

    @property
    def active(self) -> bool:
        return self.state in ("prefill", "decode")


class Scheduler:
    """Request queue + slot map + reorder buffer over a ``PagedKVPool``."""

    def __init__(self, max_slots: int, pool: PagedKVPool):
        if max_slots <= 0:
            raise ValueError(f"max_slots must be positive, got {max_slots}")
        self.max_slots = int(max_slots)
        self.pool = pool
        self.slots: List[Optional[int]] = [None] * self.max_slots
        self._tracked: Dict[int, TrackedRequest] = {}
        self._pending: List = []          # heap of (arrival, rid)
        self._queue: List[int] = []       # arrived, FIFO
        self._results: Dict[int, Any] = {}  # finished, awaiting delivery
        self._next_rid = 0
        self._next_deliver = 0

    # -- intake ------------------------------------------------------------

    def submit(self, request: Any, *, arrival: float = 0.0,
               need_tokens: int = 1) -> int:
        """Register a request; returns its rid (== delivery order)."""
        if self.pool.pages_for(need_tokens) > self.pool.num_pages:
            raise ValueError(
                f"request needs {self.pool.pages_for(need_tokens)} KV pages "
                f"({need_tokens} tokens) but the pool only has "
                f"{self.pool.num_pages}; raise num_pages or shorten the "
                f"request")
        rid = self._next_rid
        self._next_rid += 1
        tr = TrackedRequest(rid=rid, request=request, arrival=float(arrival),
                            need_tokens=int(need_tokens),
                            submit_wall=time.perf_counter())
        self._tracked[rid] = tr
        heapq.heappush(self._pending, (tr.arrival, rid))
        return rid

    def advance(self, now: float) -> List[TrackedRequest]:
        """Move every request with ``arrival <= now`` into the FIFO queue."""
        arrived = []
        while self._pending and self._pending[0][0] <= now:
            _, rid = heapq.heappop(self._pending)
            tr = self._tracked[rid]
            if tr.state != "pending":     # cancelled while pending
                continue
            tr.state = "queued"
            tr.arrive_wall = time.perf_counter()
            self._queue.append(rid)
            arrived.append(tr)
        return arrived

    def next_arrival(self) -> Optional[float]:
        return self._pending[0][0] if self._pending else None

    # -- admission ---------------------------------------------------------

    def admit(self) -> List[TrackedRequest]:
        """FIFO-admit queued requests into free slots while the pool can
        reserve their worst-case footprint.  Head-of-line blocking is
        deliberate: admission order == arrival order."""
        admitted = []
        while self._queue:
            free = [i for i, r in enumerate(self.slots) if r is None]
            if not free:
                break
            tr = self._tracked[self._queue[0]]
            if not self.pool.can_alloc(tr.need_tokens):
                break
            self._queue.pop(0)
            self.pool.alloc(tr.rid, tr.need_tokens)
            tr.slot = free[0]
            tr.state = "prefill"
            tr.prefill_pos = 0
            self.slots[free[0]] = tr.rid
            admitted.append(tr)
        return admitted

    # -- retirement --------------------------------------------------------

    def release(self, tr: TrackedRequest) -> None:
        """Give back ``tr``'s slot and pages (no result yet)."""
        if tr.slot is not None:
            self.slots[tr.slot] = None
            tr.slot = None
        self.pool.free(tr.rid)

    def finish(self, tr: TrackedRequest, result: Any,
               reason: str = "stop") -> None:
        """Retire ``tr``: release resources, buffer ``result`` for in-order
        delivery."""
        self.release(tr)
        if tr.state == "queued":
            self._queue.remove(tr.rid)
        tr.state = "done"
        tr.finish_reason = tr.finish_reason or reason
        tr.finish_wall = time.perf_counter()
        self._results[tr.rid] = result

    def pop_ready(self) -> List[Any]:
        """Results whose every predecessor has finished — the reorder
        buffer's in-order release."""
        out = []
        while self._next_deliver in self._results:
            out.append(self._results.pop(self._next_deliver))
            self._next_deliver += 1
        return out

    # -- views -------------------------------------------------------------

    def tracked(self, rid: int) -> TrackedRequest:
        return self._tracked[rid]

    def in_state(self, state: str) -> List[TrackedRequest]:
        """Active requests in ``state``, in slot order (deterministic)."""
        out = []
        for rid in self.slots:
            if rid is not None and self._tracked[rid].state == state:
                out.append(self._tracked[rid])
        return out

    def has_work(self) -> bool:
        return (bool(self._pending) or bool(self._queue)
                or any(r is not None for r in self.slots)
                or bool(self._results))

    @property
    def undelivered(self) -> int:
        return self._next_rid - self._next_deliver
