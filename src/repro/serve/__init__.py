"""Continuous-batching serving subsystem (scheduler, paged KV pool,
engine) — the paper's juggling act at request granularity."""

from .engine import Engine, Request, Result  # noqa: F401
from .kv_pool import FREE_PAGE, PagedKVPool, PoolExhausted  # noqa: F401
from .scheduler import Scheduler, TrackedRequest  # noqa: F401

__all__ = ["Engine", "Request", "Result", "Scheduler", "TrackedRequest",
           "PagedKVPool", "PoolExhausted", "FREE_PAGE"]
