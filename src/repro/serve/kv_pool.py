"""Paged KV-cache pool: fixed-size pages, per-request page tables.

JugglePAC mapping: the pool is the engine's bounded intermediate storage —
the serving analogue of the paper's "few PIS registers, not a BRAM".  A
request (one variable-length *set* in the paper's stream) owns a page
table: a list of fixed-size physical pages covering its KV footprint.
Pages are allocated when the scheduler admits the request and returned the
moment it retires (finishes, hits its length cap, or is cancelled
mid-decode), so back-to-back request streams reuse the same bounded pool
instead of growing per-request dense caches.

The pool is deliberately host-side bookkeeping (plain Python / numpy): it
gates *admission* — a request enters a decode slot only when its
worst-case footprint (prompt + max_new_tokens, capped at the engine
context) fits in free pages — and feeds the paged-gather decode kernel
(``repro.kernels.ops.flash_decode_paged``) its per-request page tables.

    pool = PagedKVPool(num_pages=64, page_size=16)
    pages = pool.alloc(rid=0, n_tokens=100)   # 7 pages
    pool.extend(rid=0, n_tokens=130)          # grows to 9 pages
    table = pool.page_table(0, max_pages=16)  # int32, -1 padded
    pool.free(0)                              # all 9 back in the free list
"""

from __future__ import annotations

from typing import Dict, List, Optional

import numpy as np

#: page-table padding sentinel — logical pages past a request's footprint
FREE_PAGE = -1


class PoolExhausted(RuntimeError):
    """Raised when an allocation cannot be satisfied from the free list."""


class PagedKVPool:
    """Fixed-size-page allocator with per-request page tables.

    ``num_pages`` physical pages of ``page_size`` tokens each.  Allocation
    is O(pages) off a free list; pages are recycled LIFO so a hot serving
    loop keeps touching the same memory.
    """

    def __init__(self, num_pages: int, page_size: int):
        if num_pages <= 0 or page_size <= 0:
            raise ValueError(
                f"PagedKVPool needs positive sizes; got num_pages="
                f"{num_pages}, page_size={page_size}")
        self.num_pages = int(num_pages)
        self.page_size = int(page_size)
        # pop() takes from the end: keep low page ids at the end so fresh
        # pools allocate 0, 1, 2, ... (deterministic tables for tests)
        self._free: List[int] = list(range(self.num_pages - 1, -1, -1))
        self._tables: Dict[int, List[int]] = {}
        self._tokens: Dict[int, int] = {}

    # -- capacity ----------------------------------------------------------

    @property
    def free_pages(self) -> int:
        return len(self._free)

    @property
    def live_requests(self) -> int:
        return len(self._tables)

    def pages_for(self, n_tokens: int) -> int:
        """Pages needed to hold ``n_tokens`` (at least one)."""
        return max(1, -(-int(n_tokens) // self.page_size))

    def can_alloc(self, n_tokens: int) -> bool:
        return self.pages_for(n_tokens) <= len(self._free)

    # -- lifecycle ---------------------------------------------------------

    def alloc(self, rid: int, n_tokens: int) -> List[int]:
        """Reserve pages covering ``n_tokens`` for request ``rid``."""
        if rid in self._tables:
            raise ValueError(f"request {rid} already holds pages")
        need = self.pages_for(n_tokens)
        if need > len(self._free):
            raise PoolExhausted(
                f"request {rid} needs {need} pages for {n_tokens} tokens "
                f"but only {len(self._free)}/{self.num_pages} are free")
        self._tables[rid] = [self._free.pop() for _ in range(need)]
        self._tokens[rid] = int(n_tokens)
        return list(self._tables[rid])

    def extend(self, rid: int, n_tokens: int) -> List[int]:
        """Grow ``rid``'s reservation to cover ``n_tokens`` total; returns
        the newly added pages (empty if the current table already covers)."""
        if rid not in self._tables:
            raise KeyError(f"request {rid} holds no pages")
        need = self.pages_for(n_tokens) - len(self._tables[rid])
        if need > len(self._free):
            raise PoolExhausted(
                f"request {rid} needs {need} more pages but only "
                f"{len(self._free)}/{self.num_pages} are free")
        new = [self._free.pop() for _ in range(max(need, 0))]
        self._tables[rid].extend(new)
        self._tokens[rid] = max(self._tokens[rid], int(n_tokens))
        return new

    def free(self, rid: int) -> int:
        """Return every page owned by ``rid``; returns the count freed."""
        pages = self._tables.pop(rid, None)
        self._tokens.pop(rid, None)
        if pages is None:
            return 0
        self._free.extend(reversed(pages))
        return len(pages)

    # -- views -------------------------------------------------------------

    def owns(self, rid: int) -> bool:
        return rid in self._tables

    def pages_of(self, rid: int) -> List[int]:
        return list(self._tables.get(rid, ()))

    def page_table(self, rid: int, max_pages: Optional[int] = None
                   ) -> np.ndarray:
        """``rid``'s page table as int32, ``FREE_PAGE``-padded to
        ``max_pages`` (default: just the owned pages) — the layout the
        paged-gather flash-decode kernel consumes."""
        pages = self._tables.get(rid, [])
        width = len(pages) if max_pages is None else int(max_pages)
        if len(pages) > width:
            raise ValueError(
                f"request {rid} owns {len(pages)} pages > max_pages={width}")
        table = np.full(width, FREE_PAGE, np.int32)
        table[:len(pages)] = pages
        return table

    def __repr__(self) -> str:
        return (f"PagedKVPool(num_pages={self.num_pages}, "
                f"page_size={self.page_size}, free={self.free_pages}, "
                f"live={self.live_requests})")
