"""Pallas TPU kernel: JugglePAC segmented streaming sum.

The circuit's streaming schedule, mapped to the TPU grid:

  * the serial 1-value/cycle input bus  ->  one (B, D) VMEM tile per grid step
    (TPU grid steps execute sequentially on a core, so the stream order is
    preserved — "cycles" become grid steps);
  * FSM state 1 (pair raw inputs)       ->  the intra-tile reduction, expressed
    as a one-hot matmul so it runs on the MXU: contrib = onehot(ids)^T @ vals;
  * the PIS register file               ->  the policy's carry tuple — (S, D)
    tiles resident in VMEM across grid steps (same output block revisited),
    addressed by segment label exactly like the PIS registers are addressed
    by set label;
  * in-order emission                   ->  row s of the output is segment s.

There is exactly ONE kernel body for the block schedule:
``_segsum_policy_kernel`` executes ``policy.update`` — the same pure jnp
ops the ref/blocked backends thread — against the carry refs, so the
cross-backend bitwise contract holds for every policy (fast / compensated
f32 carries, exact single-limb, exact2 two-limb, procrastinate bins) by
construction rather than by duplicated code.

VMEM budget per step: B*D (values) + B (ids) + carry_len*S*D floats —
the callers (ops.segment_sum, the reduce pallas backend) tile the label
space when the carry would exceed the budget, the software analogue of
"2–8 PIS registers, not a BRAM".
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _segsum_policy_kernel(ids_ref, vals_ref, *out_refs, num_segments: int,
                          seg_offset: int, policy):
    """The streaming schedule with the accuracy-policy carry baked in.

    ``policy.update`` is traced straight into the grid loop — the one
    canonical op sequence per policy; the cross-backend bitwise contract
    depends on this being the very function the blocked/ref backends
    call.  Policies executed here must zero-init their carry.
    """
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        for r in out_refs:
            r[...] = jnp.zeros_like(r)

    ids = ids_ref[...]                              # (B, 1) int32
    vals = vals_ref[...]                            # (B, D) domain dtype
    labels = jax.lax.broadcasted_iota(
        jnp.int32, (1, num_segments), 1) + seg_offset
    onehot = (ids == labels).astype(vals.dtype)     # (B, S)
    # state-1 pairing of the whole tile at once, on the MXU:
    contrib = jnp.dot(onehot.T, vals,
                      preferred_element_type=policy.acc_dtype)
    carry = policy.update(tuple(r[...] for r in out_refs), contrib)
    for r, c in zip(out_refs, carry):
        r[...] = c


def segsum_policy_pallas(values: jnp.ndarray, segment_ids: jnp.ndarray,
                         num_segments: int, *, policy,
                         block_rows: int = 512, seg_offset: int = 0,
                         interpret: bool = False):
    """values (N, D) already in ``policy``'s domain dtype (f32 or int32 —
    ``Policy.prepare`` already ran), ids (N,) int32 -> tuple of
    ``policy.carry_len`` (num_segments, D) carry arrays, not finalized.

    N must be a multiple of block_rows (the callers pad with
    ``OUT_OF_RANGE_LABEL``, which one-hots to a zero row).
    """
    n, d = values.shape
    if n % block_rows:
        raise ValueError(f"segsum_policy_pallas: N={n} must be a multiple "
                         f"of block_rows={block_rows}; pad in the caller")
    nb = n // block_rows
    ids2 = segment_ids.reshape(n, 1).astype(jnp.int32)
    kernel = functools.partial(_segsum_policy_kernel,
                               num_segments=num_segments,
                               seg_offset=seg_offset, policy=policy)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_rows, 1), lambda b: (b, 0)),
            pl.BlockSpec((block_rows, d), lambda b: (b, 0)),
        ],
        out_specs=[pl.BlockSpec((num_segments, d), lambda b: (0, 0))
                   for _ in range(policy.carry_len)],
        out_shape=[jax.ShapeDtypeStruct((num_segments, d), policy.acc_dtype)
                   for _ in range(policy.carry_len)],
        interpret=interpret,
    )(ids2, values)
    return tuple(out) if isinstance(out, (list, tuple)) else (out,)
