"""Pallas TPU kernel: JugglePAC segmented streaming sum.

The circuit's streaming schedule, mapped to the TPU grid:

  * the serial 1-value/cycle input bus  ->  one (K*B, D) VMEM supertile per
    grid step holding K consecutive schedule blocks (TPU grid steps execute
    sequentially on a core, so the stream order is preserved — "cycles"
    become grid steps);
  * the paper's back-to-back overlap   ->  double buffering at two levels:
    Pallas's automatic grid pipelining copies supertile i+1 HBM->VMEM while
    the kernel body runs supertile i, and *inside* the body the loop is
    software-pipelined — block j+1's (ids, vals) tiles are loaded before
    ``policy.update`` folds block j, so the gather stage of the next block
    overlaps the compute stage of the current one (the JugglePAC overlap,
    in-kernel);
  * FSM state 1 (pair raw inputs)       ->  the intra-tile reduction — the
    staged program's contrib stage: the one-hot MXU matmul, or the
    PhasedAccu lane-parallel scatter when the program plans it;
  * the PIS register file               ->  the policy's carry tuple — (S, D)
    tiles resident in VMEM across grid steps (same output block revisited),
    addressed by segment label exactly like the PIS registers are addressed
    by set label;
  * in-order emission                   ->  row s of the output is segment s.

There is exactly ONE kernel body for the block schedule:
``_segsum_policy_kernel`` executes the staged contrib
(``repro.reduce.program.block_contrib`` — the very helper ref/blocked
call) + ``policy.update`` — so the cross-backend bitwise contract holds
for every policy (fast / compensated f32 carries, exact single-limb,
exact2 limbs + residual-digit planes, procrastinate bins) by construction
rather than by duplicated code.  Multi-block supertiles change only *when*
tiles move, never the fold order: block j still folds before block j+1,
so results are bitwise identical at any ``blocks_per_step``.

VMEM budget per step: K*B*D (values) + K*B (ids) + carry_len*S*D floats —
the callers (ops.segment_sum, the reduce pallas backend) tile the label
space when the carry would exceed the budget, and ``blocks_per_step_for``
sizes K so the double-buffered input window stays modest (the software
analogue of "2–8 PIS registers, not a BRAM").

The reduction algebra (``repro.reduce.algebra``) needs no kernel of its
own: an op's ``pre`` widens the stream *before* dispatch (``moments``
folds ``[v | v*v]`` planes, components*D wide), so the width ``d`` this
file sees is already the op-widened domain — ``blocks_per_step_for``
shrinks the supertile depth to keep the same VMEM window, and the fold
order (hence every bitwise contract) is untouched.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.reduce.backends import OUT_OF_RANGE_LABEL
from repro.reduce.program import block_contrib

#: bytes of f32 input tiles one grid step may hold; with Pallas's grid
#: pipelining double-buffering the window, the live footprint is 2x this
_INPUT_WINDOW_BYTES = 1 << 19           # 512 KiB


def blocks_per_step_for(block_rows: int, width: int) -> int:
    """Schedule blocks per grid step (the supertile depth K).

    Sized so the (K*B, W) values + (K*B, 1) ids input window fits
    ``_INPUT_WINDOW_BYTES`` — deep enough that the per-grid-step copy
    amortizes over K contrib+update stages, shallow enough that double
    buffering the window stays far from the VMEM the carry needs.
    """
    per_block = block_rows * (width + 1) * 4
    return int(max(1, min(8, _INPUT_WINDOW_BYTES // max(per_block, 1))))


def _segsum_policy_kernel(ids_ref, vals_ref, *out_refs, num_segments: int,
                          seg_offset: int, policy, program,
                          block_rows: int, blocks_per_step: int):
    """The streaming schedule with the accuracy-policy carry baked in.

    The staged contrib (``block_contrib`` — dot or lane form per the
    planned program) and ``policy.update`` are traced straight into the
    grid loop — the one canonical op sequence per (policy, program); the
    cross-backend bitwise contract depends on these being the very
    functions the blocked/ref backends call.  Policies executed here must
    zero-init their carry.

    The body is software-pipelined over the supertile's blocks: tile j+1
    loads from the VMEM supertile before ``update`` folds tile j, telling
    the compiler the next gather never waits on the current fold.  The
    fold order is untouched — bitwise identical at any supertile depth.
    """
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        for r in out_refs:
            r[...] = jnp.zeros_like(r)

    def load(j):
        rows = pl.dslice(j * block_rows, block_rows)
        return ids_ref[rows, :], vals_ref[rows, :]

    carry = tuple(r[...] for r in out_refs)
    nxt = load(0)
    for j in range(blocks_per_step):
        ids, vals = nxt                             # (B, 1), (B, W)
        if j + 1 < blocks_per_step:
            nxt = load(j + 1)       # prefetch while this block folds
        contrib = block_contrib(vals, ids.reshape(block_rows),
                                num_segments, policy, program,
                                seg_offset=seg_offset)
        carry = policy.update(carry, contrib)
        # pin the fold boundary: with the supertile loop unrolled into one
        # computation, XLA may fuse consecutive float folds into a single
        # larger reduction (at S=1 the one-hot dot degenerates to a plain
        # reduce), silently changing the addition order the program fixes
        carry = jax.lax.optimization_barrier(carry)
    for r, c in zip(out_refs, carry):
        r[...] = c


def segsum_policy_pallas(values: jnp.ndarray, segment_ids: jnp.ndarray,
                         num_segments: int, *, policy,
                         block_rows: int = 512, seg_offset: int = 0,
                         interpret: bool = False, program=None,
                         blocks_per_step=None):
    """values (N, W) already in ``policy``'s domain (``Policy.prepare``
    already ran; W may exceed the raw feature width D — e.g. exact2's
    quantized|residual halves), ids (N,) int32 -> tuple of
    ``policy.carry_len`` carry arrays, not finalized.

    N must be a multiple of block_rows (the callers pad with
    ``OUT_OF_RANGE_LABEL``, which contributes a zero row); this wrapper
    additionally pads the *block count* up to a ``blocks_per_step``
    multiple with whole sentinel blocks — an identity for every policy
    whose ``update`` folds a zero contribution as a no-op (true of all
    registered tiers: f32 ``+0`` and ``two_sum(acc, 0)`` are exact,
    integer ``+0`` is trivial), so the supertile depth never changes the
    result bits.

    ``program`` is a planned ``BlockProgram`` (contrib mode);
    ``blocks_per_step=None`` sizes the supertile from the VMEM window
    (``blocks_per_step_for``).
    """
    n, d = values.shape
    if n % block_rows:
        raise ValueError(f"segsum_policy_pallas: N={n} must be a multiple "
                         f"of block_rows={block_rows}; pad in the caller")
    nb = n // block_rows
    if blocks_per_step is None:
        blocks_per_step = blocks_per_step_for(block_rows, d)
    bps = max(1, min(int(blocks_per_step), nb))
    extra = (-nb) % bps
    if extra:                       # whole sentinel blocks: fold identity
        values = jnp.pad(values, ((0, extra * block_rows), (0, 0)))
        segment_ids = jnp.pad(segment_ids, (0, extra * block_rows),
                              constant_values=OUT_OF_RANGE_LABEL)
        nb += extra
    ids2 = segment_ids.reshape(-1, 1).astype(jnp.int32)
    kernel = functools.partial(_segsum_policy_kernel,
                               num_segments=num_segments,
                               seg_offset=seg_offset, policy=policy,
                               program=program, block_rows=block_rows,
                               blocks_per_step=bps)
    # the policy's init is the one source of truth for per-component carry
    # shapes/dtypes (exact2 mixes int32 limbs with f32 residuals, and its
    # carries are half the domain width); the zeros are traced away
    carry0 = policy.init(num_segments, d)
    out = pl.pallas_call(
        kernel,
        grid=(nb // bps,),
        in_specs=[
            pl.BlockSpec((bps * block_rows, 1), lambda b: (b, 0)),
            pl.BlockSpec((bps * block_rows, d), lambda b: (b, 0)),
        ],
        out_specs=[pl.BlockSpec(c.shape, lambda b: (0, 0))
                   for c in carry0],
        out_shape=[jax.ShapeDtypeStruct(c.shape, c.dtype) for c in carry0],
        interpret=interpret,
    )(ids2, values)
    return tuple(out) if isinstance(out, (list, tuple)) else (out,)
