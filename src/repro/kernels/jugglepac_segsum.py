"""Pallas TPU kernel: JugglePAC segmented streaming sum.

The circuit's streaming schedule, mapped to the TPU grid:

  * the serial 1-value/cycle input bus  ->  one (B, D) VMEM tile per grid step
    (TPU grid steps execute sequentially on a core, so the stream order is
    preserved — "cycles" become grid steps);
  * FSM state 1 (pair raw inputs)       ->  the intra-tile reduction, expressed
    as a one-hot matmul so it runs on the MXU: contrib = onehot(ids)^T @ vals;
  * the PIS register file               ->  the (S, D) f32 accumulator tile that
    stays resident in VMEM across grid steps (same output block revisited),
    addressed by segment label exactly like the PIS registers are addressed
    by set label;
  * in-order emission                   ->  row s of the output is segment s.

VMEM budget per step: B*D (values) + B (ids) + S*D (accumulator) floats —
the wrapper (ops.segment_sum) tiles the label space when S*D exceeds the
budget, the software analogue of "2–8 PIS registers, not a BRAM".
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _segsum_kernel(ids_ref, vals_ref, out_ref, *, num_segments: int,
                   seg_offset: int):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    ids = ids_ref[...]                      # (B, 1) int32
    vals = vals_ref[...].astype(jnp.float32)  # (B, D)
    labels = jax.lax.broadcasted_iota(
        jnp.int32, (1, num_segments), 1) + seg_offset
    onehot = (ids == labels).astype(jnp.float32)        # (B, S)
    # state-1 pairing of the whole tile at once, on the MXU:
    out_ref[...] += jnp.dot(onehot.T, vals,
                            preferred_element_type=jnp.float32)


def segsum_pallas(values: jnp.ndarray, segment_ids: jnp.ndarray,
                  num_segments: int, *, block_rows: int = 512,
                  seg_offset: int = 0, interpret: bool = False) -> jnp.ndarray:
    """values (N, D), segment_ids (N,) int32 -> (num_segments, D) f32.

    N must be a multiple of block_rows (wrapper pads with an out-of-range
    label, which one-hots to a zero row).
    """
    n, d = values.shape
    if n % block_rows:
        raise ValueError(f"segsum_pallas: N={n} must be a multiple of "
                         f"block_rows={block_rows}; pad in the wrapper")
    nb = n // block_rows
    ids2 = segment_ids.reshape(n, 1).astype(jnp.int32)
    kernel = functools.partial(_segsum_kernel, num_segments=num_segments,
                               seg_offset=seg_offset)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_rows, 1), lambda b: (b, 0)),
            pl.BlockSpec((block_rows, d), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((num_segments, d), lambda b: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((num_segments, d), jnp.float32),
        interpret=interpret,
    )(ids2, values)


# ---------------------------------------------------------------------------
# Policy-aware variant for repro.reduce
# ---------------------------------------------------------------------------


def _segsum_policy_kernel(ids_ref, vals_ref, *out_refs, num_segments: int,
                          seg_offset: int, policy: str, acc_dtype):
    """The same streaming schedule with the accuracy-policy carry baked in.

    ``fast``        out = (acc f32,)         acc += contrib
    ``compensated`` out = (acc, comp f32)    Knuth two-sum across blocks
    ``exact``       out = (acc int32,)       integer add (values arrive
                                             pre-quantized; associative, so
                                             bitwise-equal for any schedule)
    """
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        for r in out_refs:
            r[...] = jnp.zeros_like(r)

    ids = ids_ref[...]                              # (B, 1) int32
    vals = vals_ref[...]                            # (B, D) domain dtype
    labels = jax.lax.broadcasted_iota(
        jnp.int32, (1, num_segments), 1) + seg_offset
    onehot = (ids == labels).astype(vals.dtype)     # (B, S)
    contrib = jnp.dot(onehot.T, vals, preferred_element_type=acc_dtype)

    if policy == "compensated":
        # the one canonical two_sum: the cross-backend bitwise contract
        # depends on this op sequence matching the blocked/ref backends
        from repro.reduce.policy import two_sum
        s, e = two_sum(out_refs[0][...], contrib)
        out_refs[0][...] = s
        out_refs[1][...] += e
    else:                                           # fast / exact
        out_refs[0][...] += contrib


def segsum_policy_pallas(values: jnp.ndarray, segment_ids: jnp.ndarray,
                         num_segments: int, *, policy: str = "fast",
                         carry_len: int = 1, block_rows: int = 512,
                         seg_offset: int = 0, interpret: bool = False):
    """values (N, D) already in the policy's domain dtype (f32 or int32),
    ids (N,) int32 -> tuple of ``carry_len`` (num_segments, D) carry arrays.

    N must be a multiple of block_rows (the backend pads with
    ``OUT_OF_RANGE_LABEL``, which one-hots to a zero row).
    """
    n, d = values.shape
    if n % block_rows:
        raise ValueError(f"segsum_policy_pallas: N={n} must be a multiple "
                         f"of block_rows={block_rows}; pad in the backend")
    nb = n // block_rows
    acc_dtype = values.dtype
    ids2 = segment_ids.reshape(n, 1).astype(jnp.int32)
    kernel = functools.partial(_segsum_policy_kernel,
                               num_segments=num_segments,
                               seg_offset=seg_offset, policy=policy,
                               acc_dtype=acc_dtype)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_rows, 1), lambda b: (b, 0)),
            pl.BlockSpec((block_rows, d), lambda b: (b, 0)),
        ],
        out_specs=[pl.BlockSpec((num_segments, d), lambda b: (0, 0))
                   for _ in range(carry_len)],
        out_shape=[jax.ShapeDtypeStruct((num_segments, d), acc_dtype)
                   for _ in range(carry_len)],
        interpret=interpret,
    )(ids2, values)
    return tuple(out) if isinstance(out, (list, tuple)) else (out,)
