"""Pallas TPU kernel: JugglePAC segmented streaming sum.

The circuit's streaming schedule, mapped to the TPU grid:

  * the serial 1-value/cycle input bus  ->  one (B, D) VMEM tile per grid step
    (TPU grid steps execute sequentially on a core, so the stream order is
    preserved — "cycles" become grid steps);
  * FSM state 1 (pair raw inputs)       ->  the intra-tile reduction, expressed
    as a one-hot matmul so it runs on the MXU: contrib = onehot(ids)^T @ vals;
  * the PIS register file               ->  the policy's carry tuple — (S, D)
    tiles resident in VMEM across grid steps (same output block revisited),
    addressed by segment label exactly like the PIS registers are addressed
    by set label;
  * in-order emission                   ->  row s of the output is segment s.

There is exactly ONE kernel body for the block schedule:
``_segsum_policy_kernel`` executes ``policy.contrib`` + ``policy.update``
— the same pure jnp ops the ref/blocked backends thread — against the
carry refs, so the cross-backend bitwise contract holds for every policy
(fast / compensated f32 carries, exact single-limb, exact2 limbs +
residual-digit planes, procrastinate bins) by construction rather
than by duplicated code.

VMEM budget per step: B*D (values) + B (ids) + carry_len*S*D floats —
the callers (ops.segment_sum, the reduce pallas backend) tile the label
space when the carry would exceed the budget, the software analogue of
"2–8 PIS registers, not a BRAM".
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _segsum_policy_kernel(ids_ref, vals_ref, *out_refs, num_segments: int,
                          seg_offset: int, policy):
    """The streaming schedule with the accuracy-policy carry baked in.

    ``policy.contrib`` and ``policy.update`` are traced straight into the
    grid loop — the one canonical op sequence per policy; the
    cross-backend bitwise contract depends on these being the very
    functions the blocked/ref backends call.  Policies executed here must
    zero-init their carry.
    """
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        for r in out_refs:
            r[...] = jnp.zeros_like(r)

    ids = ids_ref[...]                              # (B, 1) int32
    vals = vals_ref[...]                            # (B, W) domain dtype
    labels = jax.lax.broadcasted_iota(
        jnp.int32, (1, num_segments), 1) + seg_offset
    onehot = ids == labels                          # (B, S) bool
    # state-1 pairing of the whole tile at once, on the MXU (the policy
    # owns the dot(s): exact2 runs one int32 dot per block over its
    # quantized + residual-digit planes):
    contrib = policy.contrib(onehot, vals)
    carry = policy.update(tuple(r[...] for r in out_refs), contrib)
    for r, c in zip(out_refs, carry):
        r[...] = c


def segsum_policy_pallas(values: jnp.ndarray, segment_ids: jnp.ndarray,
                         num_segments: int, *, policy,
                         block_rows: int = 512, seg_offset: int = 0,
                         interpret: bool = False):
    """values (N, W) already in ``policy``'s domain (``Policy.prepare``
    already ran; W may exceed the raw feature width D — e.g. exact2's
    quantized|residual halves), ids (N,) int32 -> tuple of
    ``policy.carry_len`` carry arrays, not finalized.

    N must be a multiple of block_rows (the callers pad with
    ``OUT_OF_RANGE_LABEL``, which one-hots to a zero row).
    """
    n, d = values.shape
    if n % block_rows:
        raise ValueError(f"segsum_policy_pallas: N={n} must be a multiple "
                         f"of block_rows={block_rows}; pad in the caller")
    nb = n // block_rows
    ids2 = segment_ids.reshape(n, 1).astype(jnp.int32)
    kernel = functools.partial(_segsum_policy_kernel,
                               num_segments=num_segments,
                               seg_offset=seg_offset, policy=policy)
    # the policy's init is the one source of truth for per-component carry
    # shapes/dtypes (exact2 mixes int32 limbs with f32 residuals, and its
    # carries are half the domain width); the zeros are traced away
    carry0 = policy.init(num_segments, d)
    out = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((block_rows, 1), lambda b: (b, 0)),
            pl.BlockSpec((block_rows, d), lambda b: (b, 0)),
        ],
        out_specs=[pl.BlockSpec(c.shape, lambda b: (0, 0))
                   for c in carry0],
        out_shape=[jax.ShapeDtypeStruct(c.shape, c.dtype) for c in carry0],
        interpret=interpret,
    )(ids2, values)
    return tuple(out) if isinstance(out, (list, tuple)) else (out,)
