"""jit'd public wrappers around the Pallas kernels.

On CPU (this container) the kernels run with ``interpret=True`` — the kernel
body executes in Python, block by block, which validates the exact TPU
schedule.  On a real TPU backend the same code lowers to Mosaic.

The wrappers own the padding/tiling contracts so kernel bodies stay minimal:
  * segment_sum   pads N to the row-block, tiles the label space when the
                  (S, D) accumulator would not fit the VMEM budget;
  * intac_accum   pads N, enforces the int32 overflow bound;
  * flash_decode  pads S to the KV block with -inf bias, vmaps over
                  (batch, kv_head), broadcasts GQA groups.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.reduce.backends import OUT_OF_RANGE_LABEL
from repro.reduce.policy import get_policy

from . import flash_decode as _fd
from . import intac_accum as _ia
from . import jugglepac_segsum as _ss


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


# VMEM budget the segsum accumulator tile may claim (floats).
_SEGSUM_ACC_BUDGET = 2 * 1024 * 1024  # 8 MiB of f32 out of ~16 MiB VMEM


def seg_tile_for(num_segments: int, d: int, carries: int = 1) -> int:
    """Label-space tile size so all ``carries`` (S, D) carry tiles together
    fit the VMEM budget — the "few PIS registers, not a BRAM" rule.  The
    one source of truth for both this wrapper and the repro.reduce pallas
    backend (which passes ``policy.carry_len``)."""
    return max(1, min(num_segments,
                      _SEGSUM_ACC_BUDGET // (max(d, 1) * max(carries, 1))))


@functools.partial(jax.jit, static_argnames=("num_segments", "block_rows",
                                             "blocks_per_step", "interpret"))
def segment_sum(values: jnp.ndarray, segment_ids: jnp.ndarray,
                num_segments: int, *, block_rows: int = 512,
                blocks_per_step: Optional[int] = None,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """JugglePAC segmented sum. values (N, D) or (N,), ids (N,) int32.

    A thin wrapper over the one kernel body with the ``fast`` policy
    (f32 carry, identity finalize) — ``repro.reduce`` drives the same
    kernel for every other policy.  ``blocks_per_step`` sets the
    double-buffered supertile depth (None = sized from the VMEM window);
    it never changes the result bits, only how tiles stream.
    """
    interpret = _interpret_default() if interpret is None else interpret
    policy = get_policy("fast")
    squeeze = values.ndim == 1
    if squeeze:
        values = values[:, None]
    values = values.astype(jnp.float32)        # the fast policy's domain
    n, d = values.shape
    pad = (-n) % block_rows
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
        segment_ids = jnp.pad(segment_ids, (0, pad),
                              constant_values=OUT_OF_RANGE_LABEL)

    # Tile the label space so the accumulator fits the VMEM budget.
    seg_tile = seg_tile_for(num_segments, d)
    outs = []
    for off in range(0, num_segments, seg_tile):
        s = min(seg_tile, num_segments - off)
        outs.append(_ss.segsum_policy_pallas(
            values, segment_ids, s, policy=policy, block_rows=block_rows,
            seg_offset=off, blocks_per_step=blocks_per_step,
            interpret=interpret)[0])
    out = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)
    return out[:, 0] if squeeze else out


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def intac_accum(values: jnp.ndarray, scale: jnp.ndarray, *,
                block_rows: int = 256,
                interpret: Optional[bool] = None) -> jnp.ndarray:
    """Exact fixed-point accumulation -> int32 limbs (2, D)."""
    interpret = _interpret_default() if interpret is None else interpret
    n, d = values.shape
    if n > (1 << 15):
        raise ValueError("intac_accum: N > 2^15 would risk limb overflow; "
                         "split the stream and limb_merge the results")
    pad = (-n) % block_rows
    if pad:
        values = jnp.pad(values, ((0, pad), (0, 0)))
    return _ia.intac_accum_pallas(values, scale, block_rows=block_rows,
                                  interpret=interpret)


@functools.partial(jax.jit, static_argnames=("sm_scale", "block_kv",
                                             "interpret", "partial_chunks"))
def flash_decode(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                 kv_len: jnp.ndarray, *, sm_scale: float,
                 window: Optional[int] = None, block_kv: int = 512,
                 partial_chunks: Optional[int] = None,
                 interpret: Optional[bool] = None) -> jnp.ndarray:
    """Batched GQA decode attention for one new token.

    q (B, H, d); k, v (B, S, K, d) with H = K * G; kv_len (B,) valid lengths.
    ``window``: optional sliding-window size (mixtral-style SWA masking).
    ``partial_chunks``: split the KV stream into this many chunks, run each
    as an independent kernel emitting a raw (m, l, o) partial, and combine
    the partials with ``repro.reduce``'s ``FlashAccumulator`` in a fixed
    pairwise tree — the single-host rehearsal of the cross-device decode
    path (each KV shard = one partial).
    Returns (B, H, d) f32.
    """
    interpret = _interpret_default() if interpret is None else interpret
    b, h, d = q.shape
    s_len, kheads = k.shape[1], k.shape[2]
    assert h % kheads == 0
    g = h // kheads
    pad = (-s_len) % block_kv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    sp = s_len + pad

    pos = jnp.arange(sp)[None, :]                       # (1, S)
    valid = pos < kv_len[:, None]
    if window is not None:
        valid &= pos >= (kv_len[:, None] - window)
    bias = jnp.where(valid, 0.0, _fd._NEG_INF)[:, None, :]  # (B, 1, S)
    bias = jnp.broadcast_to(bias, (b, kheads, sp))

    qg = q.reshape(b, kheads, g, d)
    kk = jnp.moveaxis(k, 2, 1)                          # (B, K, S, d)
    vv = jnp.moveaxis(v, 2, 1)

    if partial_chunks is not None and partial_chunks > 1:
        from repro.reduce import FlashAccumulator, merge_tree
        nb = sp // block_kv
        per = -(-nb // partial_chunks)                  # blocks per chunk
        runp = functools.partial(_fd.flash_decode_partial_pallas,
                                 sm_scale=sm_scale, block_kv=block_kv,
                                 interpret=interpret)
        acc = FlashAccumulator()

        def one(qq, k1, v1, b1):
            states = []
            for c in range(0, nb, per):
                lo, hi = c * block_kv, min(c + per, nb) * block_kv
                states.append(runp(qq, k1[lo:hi], v1[lo:hi],
                                   b1[None, lo:hi]))
            return acc.finalize(merge_tree(acc, states))

        out = jax.vmap(jax.vmap(one))(qg, kk, vv, bias)
        return out.reshape(b, h, d)

    run = functools.partial(_fd.flash_decode_pallas, sm_scale=sm_scale,
                            block_kv=block_kv, interpret=interpret)
    out = jax.vmap(jax.vmap(lambda qq, k1, v1, b1: run(qq, k1, v1, b1[None])))(
        qg, kk, vv, bias)                               # (B, K, G, d)
    return out.reshape(b, h, d)


@functools.partial(jax.jit, static_argnames=("sm_scale", "interpret"))
def flash_decode_paged(q: jnp.ndarray, k_pages: jnp.ndarray,
                       v_pages: jnp.ndarray, page_tables: jnp.ndarray,
                       kv_len: jnp.ndarray, *, sm_scale: float,
                       interpret: Optional[bool] = None) -> jnp.ndarray:
    """Paged-gather GQA decode attention for one new token.

    The KV cache lives in a shared pool of fixed-size pages
    (``serve.PagedKVPool``); each request addresses its logical context
    through a page table instead of a contiguous slab.

    q (B, H, d); k_pages, v_pages (P, ps, K, d) — the *shared* physical
    pool (P pages of ps tokens, K kv-heads); page_tables (B, nb) int32,
    ``FREE_PAGE``-padded (padded entries are clamped to page 0 and masked
    via the length bias); kv_len (B,) valid lengths.  Returns (B, H, d)
    f32 — bitwise identical to ``flash_decode`` with ``block_kv=ps`` on
    the logically-assembled contiguous cache.
    """
    interpret = _interpret_default() if interpret is None else interpret
    if q.ndim != 3 or k_pages.ndim != 4:
        raise ValueError(
            "flash_decode_paged: expected q (B, H, d) and k_pages/v_pages "
            f"(P, ps, K, d); got q {q.shape}, k_pages {k_pages.shape}")
    if page_tables.ndim != 2 or page_tables.shape[0] != q.shape[0]:
        raise ValueError(
            "flash_decode_paged: page_tables must be (B, nb) matching "
            f"q's batch {q.shape[0]}; got {page_tables.shape}")
    b, h, d = q.shape
    ps, kheads = k_pages.shape[1], k_pages.shape[2]
    assert h % kheads == 0
    g = h // kheads
    nb = page_tables.shape[1]
    sp = nb * ps

    pos = jnp.arange(sp)[None, :]
    bias = jnp.where(pos < kv_len[:, None], 0.0, _fd._NEG_INF)  # (B, S)
    tables = jnp.maximum(page_tables.astype(jnp.int32), 0)      # clamp pads

    qg = q.reshape(b, kheads, g, d)
    kp = jnp.moveaxis(k_pages, 2, 0)                    # (K, P, ps, d)
    vp = jnp.moveaxis(v_pages, 2, 0)

    run = functools.partial(_fd.flash_decode_paged_pallas,
                            sm_scale=sm_scale, interpret=interpret)
    rows = []
    for bi in range(b):                 # page tables are per-request: loop,
        heads = [run(qg[bi, kh], kp[kh], vp[kh], bias[bi][None],
                     tables[bi])        # don't vmap over prefetch operands
                 for kh in range(kheads)]
        rows.append(jnp.stack(heads))                   # (K, G, d)
    return jnp.stack(rows).reshape(b, h, d)
