"""Pallas TPU kernels: streaming flash-decode attention (one new token).

This is the JugglePAC pattern applied to the online-softmax accumulator:
the KV cache is streamed block-by-block through VMEM (blocks = "cycles");
the running (m, l, acc) triple is the PIS register for the one in-flight
"set" (the query's attention row), carried in VMEM scratch across grid
steps; the division by l is the once-per-set finalization.

Three entry points share one online-softmax step:

  * ``flash_decode_pallas``          dense contiguous KV, finalized o;
  * ``flash_decode_partial_pallas``  dense KV, but emits the raw
    (m, l, o) partial triple instead of finalizing — chunks of the KV
    stream become independent partials that ``repro.reduce``'s
    ``FlashAccumulator`` merges in a fixed tree (the cross-chunk /
    cross-device "state 0" of the decode path);
  * ``flash_decode_paged_pallas``    paged KV: the cache lives in a
    shared pool of fixed-size pages and a per-request page table says
    which physical page backs each logical block.  The table rides in as
    a scalar-prefetch operand so the Pallas pipeline can schedule the
    gather DMA ahead of compute (``PrefetchScalarGridSpec``).

The cross-*device* half (each KV shard producing one (m, l, o) partial,
combined with a fixed pairwise tree) lives in
``core.segmented.combine_flash_partials_tree`` — kernels below handle the
within-shard stream.

Layout: one kernel instance handles one (batch, kv-head) pair:
  q    (G, d)    G = query heads sharing this KV head (GQA group)
  k, v (S, d)    the KV cache shard for this head (paged: (P, ps, d))
  bias (1, S)    additive mask (0 / -inf): padding, sliding-window, etc.
Grid: (S / Bs,) sequential; scratch m/l (G, 1), acc (G, d) f32.

Wrappers (ops.flash_decode / ops.flash_decode_paged) vmap or loop over
(batch, kv_heads).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _online_softmax_step(q, k, v, bias, m_ref, l_ref, acc_ref, *,
                         sm_scale: float):
    """One KV block through the running (m, l, acc) registers."""
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale + bias

    m_prev = m_ref[...]                           # (G, 1)
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)               # rescale old accumulator
    p = jnp.exp(s - m_new)                        # (G, Bs)
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new


def _init_registers(m_ref, l_ref, acc_ref):
    m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
    l_ref[...] = jnp.zeros_like(l_ref)
    acc_ref[...] = jnp.zeros_like(acc_ref)


def _flash_decode_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, sm_scale: float):
    step = pl.program_id(0)
    last = pl.num_programs(0) - 1

    @pl.when(step == 0)
    def _init():
        _init_registers(m_ref, l_ref, acc_ref)

    _online_softmax_step(q_ref[...].astype(jnp.float32),
                         k_ref[...].astype(jnp.float32),
                         v_ref[...].astype(jnp.float32),
                         bias_ref[...].astype(jnp.float32),
                         m_ref, l_ref, acc_ref, sm_scale=sm_scale)

    @pl.when(step == last)
    def _finalize():
        o_ref[...] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


def _flash_decode_partial_kernel(q_ref, k_ref, v_ref, bias_ref,
                                 m_out, l_out, o_out,
                                 m_ref, l_ref, acc_ref, *, sm_scale: float):
    step = pl.program_id(0)
    last = pl.num_programs(0) - 1

    @pl.when(step == 0)
    def _init():
        _init_registers(m_ref, l_ref, acc_ref)

    _online_softmax_step(q_ref[...].astype(jnp.float32),
                         k_ref[...].astype(jnp.float32),
                         v_ref[...].astype(jnp.float32),
                         bias_ref[...].astype(jnp.float32),
                         m_ref, l_ref, acc_ref, sm_scale=sm_scale)

    @pl.when(step == last)
    def _emit():
        # no finalize: the (m, l, o) triple leaves the kernel raw so the
        # FlashAccumulator can juggle partials from other chunks/shards
        m_out[...] = m_ref[...]
        l_out[...] = l_ref[...]
        o_out[...] = acc_ref[...]


def _flash_decode_paged_kernel(pt_ref, q_ref, k_ref, v_ref, bias_ref, o_ref,
                               m_ref, l_ref, acc_ref, *, sm_scale: float):
    del pt_ref  # consumed by the BlockSpec index maps (scalar prefetch)
    step = pl.program_id(0)
    last = pl.num_programs(0) - 1

    @pl.when(step == 0)
    def _init():
        _init_registers(m_ref, l_ref, acc_ref)

    _online_softmax_step(q_ref[...].astype(jnp.float32),
                         k_ref[0].astype(jnp.float32),   # (1, ps, d) block
                         v_ref[0].astype(jnp.float32),
                         bias_ref[...].astype(jnp.float32),
                         m_ref, l_ref, acc_ref, sm_scale=sm_scale)

    @pl.when(step == last)
    def _finalize():
        o_ref[...] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


def _check_dense_shapes(q, k, v, bias):
    if q.ndim != 2 or k.ndim != 2 or v.ndim != 2 or bias.ndim != 2:
        raise ValueError(
            "flash_decode_pallas: expected q (G, d), k/v (S, d), "
            f"bias (1, S); got q {q.shape}, k {k.shape}, v {v.shape}, "
            f"bias {bias.shape}")
    if k.shape != v.shape:
        raise ValueError(
            f"flash_decode_pallas: k {k.shape} and v {v.shape} must match")
    if q.shape[1] != k.shape[1]:
        raise ValueError(
            f"flash_decode_pallas: head dim mismatch: q has d={q.shape[1]} "
            f"but k has d={k.shape[1]}")
    if bias.shape != (1, k.shape[0]):
        raise ValueError(
            f"flash_decode_pallas: bias must be (1, S)=(1, {k.shape[0]}); "
            f"got {bias.shape}")


def _pad_kv_stream(k, v, bias, block_kv):
    """Pad S up to a block multiple; padded keys are masked with -inf bias
    so they cannot perturb the online softmax."""
    pad = (-k.shape[0]) % block_kv
    if pad:
        k = jnp.pad(k, ((0, pad), (0, 0)))
        v = jnp.pad(v, ((0, pad), (0, 0)))
        bias = jnp.pad(bias, ((0, 0), (0, pad)), constant_values=_NEG_INF)
    return k, v, bias


def flash_decode_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        bias: jnp.ndarray, *, sm_scale: float,
                        block_kv: int = 512,
                        interpret: bool = False) -> jnp.ndarray:
    """q (G, d), k/v (S, d), bias (1, S) -> (G, d) f32.

    Any S is accepted: a non-multiple of ``block_kv`` is padded here with
    ``-inf`` bias (padding is invisible to the softmax).
    """
    _check_dense_shapes(q, k, v, bias)
    g, d = q.shape
    k, v, bias = _pad_kv_stream(k, v, bias, block_kv)
    nb = k.shape[0] // block_kv
    kernel = functools.partial(_flash_decode_kernel, sm_scale=sm_scale)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((g, d), lambda b: (0, 0)),
            pl.BlockSpec((block_kv, d), lambda b: (b, 0)),
            pl.BlockSpec((block_kv, d), lambda b: (b, 0)),
            pl.BlockSpec((1, block_kv), lambda b: (0, b)),
        ],
        out_specs=pl.BlockSpec((g, d), lambda b: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, bias)


def flash_decode_partial_pallas(q: jnp.ndarray, k: jnp.ndarray,
                                v: jnp.ndarray, bias: jnp.ndarray, *,
                                sm_scale: float, block_kv: int = 512,
                                interpret: bool = False):
    """Like ``flash_decode_pallas`` but returns the raw partial triple
    (m (G,), l (G,), o (G, d)) — o *unnormalized* — ready for
    ``repro.reduce.FlashAccumulator`` / ``flash_partial_combine``."""
    _check_dense_shapes(q, k, v, bias)
    g, d = q.shape
    k, v, bias = _pad_kv_stream(k, v, bias, block_kv)
    nb = k.shape[0] // block_kv
    kernel = functools.partial(_flash_decode_partial_kernel,
                               sm_scale=sm_scale)
    m, l, o = pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((g, d), lambda b: (0, 0)),
            pl.BlockSpec((block_kv, d), lambda b: (b, 0)),
            pl.BlockSpec((block_kv, d), lambda b: (b, 0)),
            pl.BlockSpec((1, block_kv), lambda b: (0, b)),
        ],
        out_specs=[
            pl.BlockSpec((g, 1), lambda b: (0, 0)),
            pl.BlockSpec((g, 1), lambda b: (0, 0)),
            pl.BlockSpec((g, d), lambda b: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((g, 1), jnp.float32),
            jax.ShapeDtypeStruct((g, 1), jnp.float32),
            jax.ShapeDtypeStruct((g, d), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, bias)
    return m[:, 0], l[:, 0], o


def flash_decode_paged_pallas(q: jnp.ndarray, k_pages: jnp.ndarray,
                              v_pages: jnp.ndarray, bias: jnp.ndarray,
                              page_table: jnp.ndarray, *, sm_scale: float,
                              interpret: bool = False) -> jnp.ndarray:
    """Paged-gather flash decode for one (batch, kv-head) pair.

    q (G, d); k_pages/v_pages (P, ps, d) — the shared physical pool;
    page_table (nb,) int32 — physical page id backing each logical block
    (entries for logical pages past the request's footprint must point at
    a valid page, e.g. 0, and be masked via ``bias``); bias (1, nb * ps).

    The page table is a scalar-prefetch operand: the grid walks *logical*
    pages in order and each step's BlockSpec index map reads
    ``page_table[b]`` to aim the DMA at the right physical page, so the
    gather overlaps compute exactly like the dense stream.  With
    ``block_kv == ps`` and an identity table this is bitwise identical to
    ``flash_decode_pallas`` — same blocks, same combine order.
    """
    if q.ndim != 2 or k_pages.ndim != 3 or v_pages.ndim != 3:
        raise ValueError(
            "flash_decode_paged_pallas: expected q (G, d), k_pages/v_pages "
            f"(P, ps, d); got q {q.shape}, k_pages {k_pages.shape}, "
            f"v_pages {v_pages.shape}")
    if k_pages.shape != v_pages.shape:
        raise ValueError(
            f"flash_decode_paged_pallas: k_pages {k_pages.shape} and "
            f"v_pages {v_pages.shape} must match")
    if q.shape[1] != k_pages.shape[2]:
        raise ValueError(
            "flash_decode_paged_pallas: head dim mismatch: q has "
            f"d={q.shape[1]} but k_pages has d={k_pages.shape[2]}")
    if page_table.ndim != 1 or page_table.shape[0] == 0:
        raise ValueError(
            "flash_decode_paged_pallas: page_table must be a non-empty "
            f"(nb,) int vector; got shape {page_table.shape}")
    g, d = q.shape
    ps = k_pages.shape[1]
    nb = page_table.shape[0]
    if bias.shape != (1, nb * ps):
        raise ValueError(
            f"flash_decode_paged_pallas: bias must be (1, nb*ps)="
            f"(1, {nb * ps}); got {bias.shape}")
    kernel = functools.partial(_flash_decode_paged_kernel, sm_scale=sm_scale)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((g, d), lambda b, pt: (0, 0)),
            pl.BlockSpec((1, ps, d), lambda b, pt: (pt[b], 0, 0)),
            pl.BlockSpec((1, ps, d), lambda b, pt: (pt[b], 0, 0)),
            pl.BlockSpec((1, ps), lambda b, pt: (0, b)),
        ],
        out_specs=pl.BlockSpec((g, d), lambda b, pt: (0, 0)),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((g, d), jnp.float32),
        interpret=interpret,
    )(page_table.astype(jnp.int32), q, k_pages, v_pages, bias)
