"""Pallas TPU kernel: streaming flash-decode attention (one new token).

This is the JugglePAC pattern applied to the online-softmax accumulator:
the KV cache is streamed block-by-block through VMEM (blocks = "cycles");
the running (m, l, acc) triple is the PIS register for the one in-flight
"set" (the query's attention row), carried in VMEM scratch across grid
steps; the division by l is the once-per-set finalization.

The cross-*device* half of the decode path (each KV shard producing one
(m, l, o) partial, combined with a fixed pairwise tree) lives in
``core.segmented.combine_flash_partials_tree`` — kernel below handles the
within-shard stream.

Layout: one kernel instance handles one (batch, kv-head) pair:
  q    (G, d)    G = query heads sharing this KV head (GQA group)
  k, v (S, d)    the KV cache shard for this head
  bias (1, S)    additive mask (0 / -inf): padding, sliding-window, etc.
Grid: (S / Bs,) sequential; scratch m/l (G, 1), acc (G, d) f32.

Wrapper (ops.flash_decode) vmaps over (batch, kv_heads).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _flash_decode_kernel(q_ref, k_ref, v_ref, bias_ref, o_ref,
                         m_ref, l_ref, acc_ref, *, sm_scale: float):
    step = pl.program_id(0)
    last = pl.num_programs(0) - 1

    @pl.when(step == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[...].astype(jnp.float32)            # (G, d)
    k = k_ref[...].astype(jnp.float32)            # (Bs, d)
    v = v_ref[...].astype(jnp.float32)            # (Bs, d)
    bias = bias_ref[...].astype(jnp.float32)      # (1, Bs)

    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale + bias

    m_prev = m_ref[...]                           # (G, 1)
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    alpha = jnp.exp(m_prev - m_new)               # rescale old accumulator
    p = jnp.exp(s - m_new)                        # (G, Bs)
    l_ref[...] = l_prev * alpha + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(step == last)
    def _finalize():
        o_ref[...] = acc_ref[...] / jnp.maximum(l_ref[...], 1e-30)


def flash_decode_pallas(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                        bias: jnp.ndarray, *, sm_scale: float,
                        block_kv: int = 512,
                        interpret: bool = False) -> jnp.ndarray:
    """q (G, d), k/v (S, d), bias (1, S) -> (G, d) f32.  S % block_kv == 0."""
    g, d = q.shape
    s_len = k.shape[0]
    assert s_len % block_kv == 0, "pad in the wrapper"
    nb = s_len // block_kv
    kernel = functools.partial(_flash_decode_kernel, sm_scale=sm_scale)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((g, d), lambda b: (0, 0)),
            pl.BlockSpec((block_kv, d), lambda b: (b, 0)),
            pl.BlockSpec((block_kv, d), lambda b: (b, 0)),
            pl.BlockSpec((1, block_kv), lambda b: (0, b)),
        ],
        out_specs=pl.BlockSpec((g, d), lambda b: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((g, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, 1), jnp.float32),
            pltpu.VMEM((g, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v, bias)
