"""Pallas TPU kernels for the compute hot-spots, with jnp oracles.

  jugglepac_segsum  segmented streaming sum (the paper's accumulator)
  intac_accum       exact fixed-point accumulation (carry-save analogue)
  flash_decode      streaming online-softmax decode attention

Use via ``repro.kernels.ops`` — the wrappers own padding/tiling and select
interpret mode automatically off-TPU.
"""
from . import ops, ref  # noqa: F401
from .ops import flash_decode, intac_accum, intac_sum_exact, segment_sum  # noqa: F401
