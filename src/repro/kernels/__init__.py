"""Pallas TPU kernels for the compute hot-spots, with jnp oracles.

  jugglepac_segsum  segmented streaming sum (the paper's accumulator),
                    plus the policy-aware variant driven by repro.reduce
  intac_accum       exact fixed-point accumulation (carry-save analogue)
  flash_decode      streaming online-softmax decode attention

Reductions should enter through ``repro.reduce`` (the ``pallas`` backend
dispatches here); ``repro.kernels.ops`` remains the kernel-level wrapper
layer that owns padding/tiling and selects interpret mode off-TPU.
``ops.intac_sum_exact`` is a deprecation shim for
``repro.reduce(..., policy="exact")``.
"""
from . import ops, ref  # noqa: F401
from .ops import flash_decode, intac_accum, intac_sum_exact, segment_sum  # noqa: F401
