"""Pallas TPU kernels for the compute hot-spots, with jnp oracles.

  jugglepac_segsum  the one kernel body for the block schedule — every
                    accuracy policy of repro.reduce runs through it
  intac_accum       exact fixed-point accumulation (carry-save analogue)
  flash_decode      streaming online-softmax decode attention

Reductions should enter through ``repro.reduce`` (the ``pallas`` backend
dispatches here); ``repro.kernels.ops`` remains the kernel-level wrapper
layer that owns padding/tiling and selects interpret mode off-TPU.
"""
from . import ops, ref  # noqa: F401
from .ops import (flash_decode, flash_decode_paged,  # noqa: F401
                  intac_accum, segment_sum)
