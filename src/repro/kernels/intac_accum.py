"""Pallas TPU kernel: INTAC exact fixed-point accumulation.

The carry-save contract on the VPU: each grid step quantizes one (B, D) tile
to fixed point and adds it into a two-limb int32 accumulator that stays
resident in VMEM.  Integer adds are exact and associative (the 3:2
compressor analogue, with the "critical path" now a single VPU int add);
the limbs are only resolved to a float **after** the kernel — the
resource-shared final addition, paid once per call instead of per element.

Overflow discipline (documented, checked in the wrapper):
  |x| * scale < 2^(LIMB_SHIFT + 15)  and  N < 2^(31 - LIMB_SHIFT - 1)
so each limb accumulates N terms of < 2^15 magnitude -> fits int32.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.intac import LIMB_SHIFT


def _intac_kernel(scale_ref, vals_ref, out_ref):
    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    scale = scale_ref[0, 0]
    q = jnp.round(vals_ref[...].astype(jnp.float32) * scale)
    hi = jnp.floor(q * (1.0 / (1 << LIMB_SHIFT)))
    lo = q - hi * (1 << LIMB_SHIFT)                      # in [0, 2^15)
    hi_i = jnp.sum(hi.astype(jnp.int32), axis=0)         # exact int adds
    lo_i = jnp.sum(lo.astype(jnp.int32), axis=0)
    out_ref[...] += jnp.stack([hi_i, lo_i], axis=0)      # (2, D) int32


def intac_accum_pallas(values: jnp.ndarray, scale: jnp.ndarray, *,
                       block_rows: int = 256,
                       interpret: bool = False) -> jnp.ndarray:
    """values (N, D) f32, scale () f32 -> int32 limbs (2, D).

    Resolve with ``core.intac.limb_finalize``-style math:
    result = (limbs[0] * 2^LIMB_SHIFT + limbs[1]) / scale.
    """
    n, d = values.shape
    assert n % block_rows == 0, "pad in the wrapper"
    nb = n // block_rows
    scale2 = jnp.asarray(scale, jnp.float32).reshape(1, 1)
    return pl.pallas_call(
        _intac_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b: (0, 0)),
            pl.BlockSpec((block_rows, d), lambda b: (b, 0)),
        ],
        out_specs=pl.BlockSpec((2, d), lambda b: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((2, d), jnp.int32),
        interpret=interpret,
    )(scale2, values)
