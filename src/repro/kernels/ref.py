"""Pure-jnp oracles for every Pallas kernel in this package."""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.intac import LIMB_SHIFT


def segsum_ref(values: jnp.ndarray, segment_ids: jnp.ndarray,
               num_segments: int, seg_offset: int = 0) -> jnp.ndarray:
    """Oracle for jugglepac_segsum: scatter-add into [seg_offset, +S)."""
    ids = segment_ids.astype(jnp.int32) - seg_offset
    ok = (ids >= 0) & (ids < num_segments)
    ids = jnp.where(ok, ids, num_segments)      # park invalid rows
    vals = jnp.where(ok[:, None], values.astype(jnp.float32), 0.0)
    out = jnp.zeros((num_segments + 1,) + values.shape[1:], jnp.float32)
    return out.at[ids].add(vals, mode="drop")[:num_segments]


def intac_accum_ref(values: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Oracle for intac_accum: same quantization, exact int adds via f64-free
    int32 math (term magnitudes are bounded by the wrapper's contract)."""
    q = jnp.round(values.astype(jnp.float32) * scale)
    hi = jnp.floor(q * (1.0 / (1 << LIMB_SHIFT))).astype(jnp.int32)
    lo = (q - jnp.floor(q * (1.0 / (1 << LIMB_SHIFT)))
          * (1 << LIMB_SHIFT)).astype(jnp.int32)
    return jnp.stack([hi.sum(0), lo.sum(0)], axis=0)


def limbs_to_float(limbs: jnp.ndarray, scale) -> jnp.ndarray:
    return (limbs[0].astype(jnp.float32) * (1 << LIMB_SHIFT)
            + limbs[1].astype(jnp.float32)) / scale


def flash_decode_ref(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                     bias: jnp.ndarray, *, sm_scale: float) -> jnp.ndarray:
    """Oracle for flash_decode: materialized softmax attention row."""
    s = (q.astype(jnp.float32) @ k.astype(jnp.float32).T) * sm_scale
    s = s + bias.astype(jnp.float32)             # (G, S)
    p = jax.nn.softmax(s, axis=-1)
    return p @ v.astype(jnp.float32)
