"""Fault injectors for the robustness suite.

Every injector models one concrete failure from docs/robustness.md and is
paired (in ``tests/test_faults.py``) with an assertion that the stack
*detects, degrades, or recovers* — never silently corrupts:

  * ``inject_nonfinite``      — NaN/Inf payload bursts in a value stream
                                (a poisoned loss/gradient microbatch);
  * ``flip_bit`` /
    ``truncate_file`` /
    ``corrupt_checkpoint``    — storage faults in checkpoint artifacts,
                                caught by the CRC sidecars as a structured
                                ``CheckpointError``;
  * ``kill-mid-save`` (CLI)   — a host dying between the shard write and
                                the atomic rename, leaving a ``.tmp``
                                directory that must never be restored;
  * ``drop_shard_carry``      — a shard's policy carry lost before
                                ``merge_carry_across`` (device dropout);
                                carry merges are linear, so the correct
                                degraded outcome is *exactly* the
                                reduction over the surviving shards' rows.

The kill-mid-save fault needs a real process death, so it ships as a CLI:

    python -m repro.testing.faults kill-mid-save <ckpt_dir> <step>

which builds a small deterministic tree, patches ``os.replace`` to die
(exit code 9) the moment ``ckpt.save`` reaches the atomic-rename point,
and leaves the partially-written ``step_XXXXXXXX.tmp`` behind for the
test to probe.
"""

from __future__ import annotations

import os
import sys
from pathlib import Path

import numpy as np

KILL_EXIT_CODE = 9


# ---------------------------------------------------------------------------
# numerical faults
# ---------------------------------------------------------------------------


def inject_nonfinite(values, *, rows, kind: str = "nan"):
    """Return a copy of ``values`` (N,) or (N, D) with ``rows`` poisoned.

    ``kind``: "nan", "inf", or "both" (alternating NaN / -Inf).  ``rows``
    is a sequence of row indices — the burst.
    """
    out = np.array(values, dtype=np.float32, copy=True)
    for j, r in enumerate(rows):
        if kind == "nan" or (kind == "both" and j % 2 == 0):
            out[r] = np.nan
        elif kind == "inf" or kind == "both":
            out[r] = -np.inf
        else:
            raise ValueError(f"kind must be nan/inf/both, got {kind!r}")
    return out


# ---------------------------------------------------------------------------
# storage faults
# ---------------------------------------------------------------------------


def flip_bit(path, *, seed: int = 0) -> int:
    """Flip one pseudo-randomly chosen bit of ``path`` in place.

    Returns the byte offset flipped.  Deterministic per (file size, seed).
    """
    p = Path(path)
    blob = bytearray(p.read_bytes())
    if not blob:
        raise ValueError(f"flip_bit: {p} is empty")
    rng = np.random.RandomState(seed)
    off = int(rng.randint(0, len(blob)))
    blob[off] ^= 1 << int(rng.randint(0, 8))
    p.write_bytes(bytes(blob))
    return off


def truncate_file(path, *, frac: float = 0.5) -> int:
    """Truncate ``path`` to ``frac`` of its size (storage ran out / torn
    write).  Returns the new size."""
    p = Path(path)
    size = p.stat().st_size
    keep = int(size * frac)
    with open(p, "rb+") as f:
        f.truncate(keep)
    return keep


def corrupt_checkpoint(ckpt_dir, step: int, *, mode: str = "bitflip",
                       seed: int = 0) -> Path:
    """Apply a storage fault to a finished checkpoint's shard file.

    ``mode``: "bitflip" (one flipped bit in the msgpack blob) or
    "truncate" (half the file gone).  Returns the path touched.  The CRC
    sidecar is left intact — that is the point: restore must notice the
    mismatch.
    """
    d = Path(ckpt_dir) / f"step_{step:08d}"
    shards = sorted(d.glob("shard_*.msgpack"))
    if not shards:
        raise FileNotFoundError(f"no shard files under {d}")
    target = shards[0]
    if mode == "bitflip":
        flip_bit(target, seed=seed)
    elif mode == "truncate":
        truncate_file(target)
    else:
        raise ValueError(f"mode must be bitflip/truncate, got {mode!r}")
    return target


# ---------------------------------------------------------------------------
# collective faults
# ---------------------------------------------------------------------------


def drop_shard_carry(carry, axis_name: str, shard_index: int):
    """Zero one shard's policy carry before ``merge_carry_across`` — the
    collective face of device dropout (must run inside shard_map).

    Carry merges are linear (integer adds / psums), so zeroing a shard's
    carry is *exactly* equivalent to that shard's rows never existing:
    the merged result degrades to the valid reduction over the surviving
    shards — no garbage, and bitwise-reproducible for the integer tiers.
    ``tests/test_faults.py`` asserts precisely that equivalence.
    """
    import jax
    import jax.numpy as jnp

    keep = jax.lax.axis_index(axis_name) != shard_index
    return tuple(jnp.where(keep, c, jnp.zeros_like(c)) for c in carry)


# ---------------------------------------------------------------------------
# kill-mid-save CLI
# ---------------------------------------------------------------------------


def _demo_tree():
    rng = np.random.RandomState(1234)
    return {"w": rng.randn(8, 4).astype(np.float32),
            "b": rng.randn(4).astype(np.float32)}


def _kill_mid_save(ckpt_dir: str, step: int):
    """Run ``ckpt.save`` but die at the atomic-rename point, the way a
    host loss would: shard + manifest written into the ``.tmp`` dir, the
    rename never happens."""
    from repro.ckpt import checkpoint as ckpt

    real_replace = os.replace

    def dying_replace(src, dst):          # noqa: ARG001 — signature match
        sys.stderr.write(f"[faults] dying before rename of {src}\n")
        sys.stderr.flush()
        os._exit(KILL_EXIT_CODE)

    os.replace = dying_replace
    try:
        ckpt.save(ckpt_dir, step, _demo_tree(), extra={"next_step": step + 1})
    finally:                               # pragma: no cover — never reached
        os.replace = real_replace
    raise AssertionError("save returned: the injected crash did not fire")


def main(argv=None):
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) == 3 and argv[0] == "kill-mid-save":
        _kill_mid_save(argv[1], int(argv[2]))
        return 2                           # pragma: no cover
    sys.stderr.write(
        "usage: python -m repro.testing.faults kill-mid-save "
        "<ckpt_dir> <step>\n")
    return 2


if __name__ == "__main__":
    sys.exit(main())
