"""Test-support utilities shipped with the package (not test code).

``repro.testing.faults`` is the fault-injection harness behind
``tests/test_faults.py`` and the robustness story in
``docs/robustness.md``: NaN/Inf payload bursts, checkpoint bit flips and
truncation, kill-mid-save crashes, and shard dropout — each built so the
corresponding detection/degradation/recovery path can be asserted rather
than hoped for.
"""

from . import faults  # noqa: F401
