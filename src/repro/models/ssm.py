"""State-space / recurrent blocks: Mamba, mLSTM, sLSTM.

All three are *streaming accumulators* in the JugglePAC sense: a running
state is updated by a stream of inputs, and fp non-associativity means the
evaluation order must be fixed.  We use the chunkwise-parallel form
everywhere it exists (TPU-native: intra-chunk work is matmul-shaped for the
MXU, inter-chunk state is a short ``lax.scan``), which is exactly the
state-1 (intra-block pairing) / state-0 (carry combination) split:

  * Mamba   — selective SSM; intra-chunk via ``associative_scan`` (fixed
              combination tree!), inter-chunk carried state.
  * mLSTM   — matrix-memory LSTM (xLSTM); chunkwise stabilized parallel form
              with carried (C, n, m) state.
  * sLSTM   — scalar-memory LSTM with recurrent connections: inherently
              sequential (the xLSTM paper says so), so a per-timestep scan.

Each block provides init / train-apply / single-token decode step; decode
state is O(1) in sequence length — the long_500k path for xLSTM and Jamba.
"""

from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import MambaCfg, ModelConfig, XLSTMCfg
from .layers import dense, dense_init

CHUNK = 128


# ---------------------------------------------------------------------------
# Mamba (selective SSM)
# ---------------------------------------------------------------------------


class MambaState(NamedTuple):
    h: jnp.ndarray        # (B, di, n)
    conv: jnp.ndarray     # (B, d_conv-1, di)


def mamba_init(key, d_model: int, m: MambaCfg, dtype):
    di = m.expand * d_model
    dt_rank = max(1, math.ceil(d_model / 16))
    ks = jax.random.split(key, 7)
    a = jnp.tile(jnp.arange(1, m.d_state + 1, dtype=jnp.float32), (di, 1))  # detlint: ok[DET006] d_state well under 2^24
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (m.d_conv, di), jnp.float32)
                   * (1.0 / m.d_conv)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "x_proj": dense_init(ks[2], di, dt_rank + 2 * m.d_state, dtype),
        "dt_proj": dense_init(ks[3], dt_rank, di, dtype),
        "dt_bias": jnp.zeros((di,), jnp.float32),
        "a_log": jnp.log(a),                     # (di, n) f32
        "d_skip": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[4], di, d_model, dtype),
    }


def _mamba_gates(p, xc, m: MambaCfg):
    """xc (B, L, di) conv'd+silu'd stream -> (dt, bmat, cmat)."""
    dt_rank = p["dt_proj"].shape[0]
    proj = dense(p["x_proj"], xc).astype(jnp.float32)
    dt, b, c = jnp.split(proj, [dt_rank, dt_rank + m.d_state], axis=-1)
    dt = jax.nn.softplus(jnp.einsum("blr,rd->bld", dt, p["dt_proj"]
                                    .astype(jnp.float32)) + p["dt_bias"])
    return dt, b, c    # (B,L,di), (B,L,n), (B,L,n)


def _mamba_scan_chunk(h0, xin, dt, b, c, a):
    """One chunk: h0 (B,di,n); xin/dt (B,Q,di); b/c (B,Q,n); a (di,n)."""
    decay = jnp.exp(dt[..., None] * (-a))                    # (B,Q,di,n)
    drive = (dt * xin)[..., None] * b[:, :, None, :]         # (B,Q,di,n)

    def combine(l, r):
        return (r[0] * l[0], r[0] * l[1] + r[1])

    acum, bcum = jax.lax.associative_scan(combine, (decay, drive), axis=1)
    h = acum * h0[:, None] + bcum                            # (B,Q,di,n)
    y = jnp.einsum("bqdn,bqn->bqd", h, c)
    return y, h[:, -1]


def mamba_apply(p, x, m: MambaCfg, *, mode: str = "train",
                state: Optional[MambaState] = None,
                chunk: int = CHUNK,
                cfg=None) -> Tuple[jnp.ndarray, Optional[MambaState]]:
    """x (B, S, d) -> (y (B, S, d), state).

    ``cfg`` (optional ModelConfig) supplies mesh hints: di is TP-sharded on
    'model' and the chunk-scan inputs must keep (batch, channel) sharding
    through the reshape/transpose or GSPMD replicates them."""
    from .layers import shard_hint
    hint = ((lambda t, dims: shard_hint(t, cfg, dims)) if cfg is not None
            else (lambda t, dims: t))
    bsz, s, _ = x.shape
    di = p["conv_b"].shape[0]
    dconv = p["conv_w"].shape[0]
    xz = dense(p["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)                        # (B,S,di)

    if mode in ("train", "prefill"):
        xi = hint(xi, ("dp", None, "model"))
        pad = jnp.zeros((bsz, dconv - 1, di), xi.dtype)
        xpad = jnp.concatenate([pad, xi], axis=1)
        xc = jax.nn.silu(_depthwise_conv(xpad, p))
        dt, b, c = _mamba_gates(p, xc.astype(x.dtype), m)
        dt = hint(dt, ("dp", None, "model"))
        a = jnp.exp(p["a_log"])
        nchunks = -(-s // chunk)
        padlen = nchunks * chunk - s
        def padq(t):
            return jnp.pad(t, ((0, 0), (0, padlen)) + ((0, 0),) * (t.ndim - 2))
        xcp, dtp, bp, cp = map(padq, (xc, dt, b, c))
        h0 = hint(jnp.zeros((bsz, di, m.d_state), jnp.float32),
                  ("dp", "model", None))

        chunk_fn = jax.checkpoint(
            lambda h, xq, dq, bq, cq: _mamba_scan_chunk(h, xq, dq, bq, cq, a))

        def step(h, args):
            xq, dq, bq, cq = args
            y, hq = chunk_fn(h, hint(xq, ("dp", None, "model")),
                             hint(dq, ("dp", None, "model")), bq, cq)
            return hint(hq, ("dp", "model", None)), y

        resh = lambda t: t.reshape(bsz, nchunks, chunk, t.shape[-1]) \
                          .transpose(1, 0, 2, 3)
        hN, ys = jax.lax.scan(step, h0, tuple(map(resh, (xcp, dtp, bp, cp))))
        y = ys.transpose(1, 0, 2, 3).reshape(bsz, nchunks * chunk, di)[:, :s]
        y = hint(y, ("dp", None, "model"))
        y = y + xc * p["d_skip"]
        out = dense(p["out_proj"],
                    (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype))
        new_state = None
        if mode == "prefill":
            conv_tail = jnp.concatenate([pad, xi], axis=1)[:, -(dconv - 1):]
            new_state = MambaState(h=hN, conv=conv_tail)
        return out, new_state

    assert mode == "decode" and state is not None and s == 1
    window = jnp.concatenate([state.conv, xi], axis=1)       # (B,dconv,di)
    xc = (jnp.einsum("bwd,wd->bd", window.astype(jnp.float32),
                     p["conv_w"].astype(jnp.float32))
          + p["conv_b"].astype(jnp.float32))
    xc = jax.nn.silu(xc)[:, None, :]                         # (B,1,di)
    dt, b, c = _mamba_gates(p, xc.astype(x.dtype), m)
    a = jnp.exp(p["a_log"])
    decay = jnp.exp(dt[:, 0, :, None] * (-a))                # (B,di,n)
    drive = (dt[:, 0] * xc[:, 0])[..., None] * b[:, 0, None, :]
    h = decay * state.h + drive
    y = jnp.einsum("bdn,bn->bd", h, c[:, 0]) + xc[:, 0] * p["d_skip"]
    out = dense(p["out_proj"],
                (y[:, None] * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype))
    return out, MambaState(h=h, conv=window[:, 1:])


def _depthwise_conv(xpad, p):
    """Causal depthwise conv: xpad (B, S+K-1, di) -> (B, S, di)."""
    k = p["conv_w"].shape[0]
    s = xpad.shape[1] - (k - 1)
    acc = 0.0
    # detlint: ok[DET002] depthwise conv taps: K=4 fixed-order affine
    # chain, deliberately fusible — not under the reduce contract
    for i in range(k):                      # K is 4: unrolled, fusible
        acc = acc + xpad[:, i:i + s, :].astype(jnp.float32) \
            * p["conv_w"][i].astype(jnp.float32)
    return acc + p["conv_b"].astype(jnp.float32)


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix memory) — chunkwise stabilized parallel form
# ---------------------------------------------------------------------------


class MLSTMState(NamedTuple):
    c: jnp.ndarray      # (B, H, pv, pk)
    n: jnp.ndarray      # (B, H, pk)
    m: jnp.ndarray      # (B, H) log stabilizer
    conv: jnp.ndarray   # (B, kconv-1, di)


def mlstm_init(key, d_model: int, x: XLSTMCfg, dtype):
    di = int(x.proj_factor_m * d_model)
    ks = jax.random.split(key, 8)
    return {
        "in_proj": dense_init(ks[0], d_model, 2 * di, dtype),
        "conv_w": (jax.random.normal(ks[1], (x.conv_kernel, di), jnp.float32)
                   * (1.0 / x.conv_kernel)).astype(dtype),
        "conv_b": jnp.zeros((di,), dtype),
        "wq": dense_init(ks[2], di, di, dtype),
        "wk": dense_init(ks[3], di, di, dtype),
        "wv": dense_init(ks[4], di, di, dtype),
        "w_if": dense_init(ks[5], di, 2 * x.num_heads, jnp.float32),
        "b_i": jnp.zeros((x.num_heads,), jnp.float32),
        "b_f": jnp.full((x.num_heads,), 3.0, jnp.float32),  # open forget gates
        "out_norm": jnp.ones((di,), dtype),
        "out_proj": dense_init(ks[6], di, d_model, dtype),
    }


def _mlstm_chunk(c0, n0, m0, q, k, v, logi, logf):
    """One chunk, one head-batch.

    q,k,v (B,H,Q,p); logi/logf (B,H,Q); state c0 (B,H,p,p), n0 (B,H,p),
    m0 (B,H).  Derivation: with F_t = cumsum(logf), u_s = logi_s - F_s,
    w_t = max(m0, max_{s<=t} u_s), the stabilized intra weights are
    A_ts = exp(u_s - w_t) (F_t cancels!) and the carried-state coefficient
    is exp(m0 - w_t); the chunk-final stabilizer is m_Q = F_Q + w_Q.
    """
    p = q.shape[-1]
    q = q.astype(jnp.float32) * (p ** -0.5)   # 1/sqrt(p) lives on q
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    f_cum = jnp.cumsum(logf, axis=-1)  # detlint: ok[DET001] gate prefix scan: the chunked-attention recurrence, not a segment reduction
    u = logi - f_cum                                       # (B,H,Q)
    b_run = jax.lax.associative_scan(jnp.maximum, u, axis=-1)
    w = jnp.maximum(m0[..., None], b_run)                  # (B,H,Q)

    mask = jnp.tril(jnp.ones((q.shape[2], q.shape[2]), bool))
    aw = jnp.exp(u[..., None, :] - w[..., None])           # (B,H,Q_t,Q_s)
    aw = jnp.where(mask, aw, 0.0)
    qk = jnp.einsum("bhtp,bhsp->bhts", q, k)
    scores = qk * aw                                       # (B,H,t,s)

    inter_coef = jnp.exp(m0[..., None] - w)                # (B,H,Q)
    num = (jnp.einsum("bhts,bhsp->bhtp", scores, v)
           + inter_coef[..., None]
           * jnp.einsum("bhtp,bhvp->bhtv", q, c0))
    den_dot = scores.sum(-1) + inter_coef * jnp.einsum("bhtp,bhp->bht", q, n0)  # detlint: ok[DET001] softmax denominator; algebra routing is a ROADMAP item
    m_t = f_cum + w
    den = jnp.maximum(jnp.abs(den_dot), jnp.exp(-m_t))
    h = num / den[..., None]

    # end-of-chunk state
    f_q = f_cum[..., -1]                                   # (B,H)
    w_q = w[..., -1]
    m_new = f_q + w_q
    r = jnp.exp(u + f_q[..., None] - m_new[..., None])     # (B,H,Q)
    decay = jnp.exp(m0 + f_q - m_new)                      # (B,H)
    c_new = (decay[..., None, None] * c0
             + jnp.einsum("bhs,bhsv,bhsp->bhvp", r, v, k))
    n_new = decay[..., None] * n0 + jnp.einsum("bhs,bhsp->bhp", r, k)
    return h, c_new, n_new, m_new


def mlstm_core(q, k, v, logi, logf, state, chunk: int = CHUNK):
    """q,k,v (B,H,S,p). Chunk-scan the stabilized parallel form."""
    bsz, hh, s, p = q.shape
    nchunks = -(-s // chunk)
    pad = nchunks * chunk - s
    if pad:
        padq = lambda t: jnp.pad(t, ((0, 0), (0, 0), (0, pad))
                                 + ((0, 0),) * (t.ndim - 3))
        q, k, v = (jnp.pad(t, ((0, 0), (0, 0), (0, pad), (0, 0)))
                   for t in (q, k, v))
        logi = jnp.pad(logi, ((0, 0), (0, 0), (0, pad)),
                       constant_values=-1e30)   # zero input gate on padding
        logf = jnp.pad(logf, ((0, 0), (0, 0), (0, pad)))
    c0, n0, m0 = state

    chunk_fn = jax.checkpoint(_mlstm_chunk)

    def step(carry, args):
        c, n, m = carry
        qq, kk, vv, li, lf = args
        h, c, n, m = chunk_fn(c, n, m, qq, kk, vv, li, lf)
        return (c, n, m), h

    resh = lambda t: t.reshape(bsz, hh, nchunks, chunk, *t.shape[3:]) \
                      .transpose(2, 0, 1, 3, *range(4, t.ndim + 1))
    args = tuple(map(resh, (q, k, v, logi, logf)))
    (c, n, m), hs = jax.lax.scan(step, (c0, n0, m0), args)
    h = hs.transpose(1, 2, 0, 3, 4).reshape(bsz, hh, nchunks * chunk, p)
    return h[:, :, :s], (c, n, m)


def mlstm_step(q, k, v, logi, logf, state):
    """Single-token recurrence. q,k,v (B,H,p); logi/logf (B,H)."""
    c, n, m = state
    p = q.shape[-1]
    q = q.astype(jnp.float32) * (p ** -0.5)
    k = k.astype(jnp.float32)
    v = v.astype(jnp.float32)
    m_new = jnp.maximum(logf + m, logi)
    a = jnp.exp(logf + m - m_new)
    b = jnp.exp(logi - m_new)
    c_new = a[..., None, None] * c + b[..., None, None] \
        * jnp.einsum("bhv,bhp->bhvp", v, k)
    n_new = a[..., None] * n + b[..., None] * k
    num = jnp.einsum("bhvp,bhp->bhv", c_new, q)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhp,bhp->bh", n_new, q)),
                      jnp.exp(-m_new))
    return num / den[..., None], (c_new, n_new, m_new)


def mlstm_apply(p, x, xc_cfg: XLSTMCfg, *, mode: str = "train",
                state: Optional[MLSTMState] = None, chunk: int = CHUNK):
    bsz, s, _ = x.shape
    di = p["conv_b"].shape[0]
    nh = xc_cfg.num_heads
    hd = di // nh
    kconv = p["conv_w"].shape[0]

    xz = dense(p["in_proj"], x)
    xi, z = jnp.split(xz, 2, axis=-1)

    if mode in ("train", "prefill"):
        padc = jnp.zeros((bsz, kconv - 1, di), xi.dtype)
        xpad = jnp.concatenate([padc, xi], axis=1)
        xc = jax.nn.silu(_depthwise_conv(
            xpad, {"conv_w": p["conv_w"], "conv_b": p["conv_b"]}))
        xc = xc.astype(x.dtype)
    else:
        assert state is not None and s == 1
        window = jnp.concatenate([state.conv, xi], axis=1)
        xc = jax.nn.silu(
            jnp.einsum("bwd,wd->bd", window.astype(jnp.float32),
                       p["conv_w"].astype(jnp.float32))
            + p["conv_b"].astype(jnp.float32))[:, None].astype(x.dtype)

    tohead = lambda t: t.reshape(bsz, -1, nh, hd).transpose(0, 2, 1, 3)
    q = tohead(dense(p["wq"], xc))     # model dtype; cast f32 inside chunks
    k = tohead(dense(p["wk"], xc))
    v = tohead(dense(p["wv"], xi))

    gates = dense(p["w_if"], xc).astype(jnp.float32)         # (B,S,2H)
    logi = gates[..., :nh].transpose(0, 2, 1) + p["b_i"][None, :, None]
    logf = jax.nn.log_sigmoid(
        gates[..., nh:].transpose(0, 2, 1) + p["b_f"][None, :, None])

    if mode in ("train", "prefill"):
        c0 = jnp.zeros((bsz, nh, hd, hd), jnp.float32)
        n0 = jnp.zeros((bsz, nh, hd), jnp.float32)
        m0 = jnp.zeros((bsz, nh), jnp.float32)
        h, (c, n, m) = mlstm_core(q, k, v, logi, logf, (c0, n0, m0), chunk)
        h = h.transpose(0, 2, 1, 3).reshape(bsz, s, di)
        new_state = None
        if mode == "prefill":
            conv_tail = jnp.concatenate([padc, xi], axis=1)[:, -(kconv - 1):]
            new_state = MLSTMState(c, n, m, conv_tail)
    else:
        h1, (c, n, m) = mlstm_step(q[:, :, 0], k[:, :, 0], v[:, :, 0],
                                   logi[:, :, 0], logf[:, :, 0],
                                   (state.c, state.n, state.m))
        h = h1.reshape(bsz, 1, di)
        new_state = MLSTMState(c, n, m, window[:, 1:])

    from .layers import rmsnorm
    h = rmsnorm(p["out_norm"], h.astype(x.dtype))
    out = dense(p["out_proj"],
                (h.astype(jnp.float32)
                 * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype))
    return out, new_state


# ---------------------------------------------------------------------------
# sLSTM (scalar memory, exponential gating, recurrent connections)
# ---------------------------------------------------------------------------


class SLSTMState(NamedTuple):
    c: jnp.ndarray   # (B, d)
    n: jnp.ndarray   # (B, d)
    h: jnp.ndarray   # (B, d)
    m: jnp.ndarray   # (B, d)


def slstm_init(key, d_model: int, x: XLSTMCfg, dtype):
    ks = jax.random.split(key, 4)
    dff = int(x.proj_factor_s * d_model)
    return {
        "w_x": dense_init(ks[0], d_model, 4 * d_model, dtype),
        "w_h": dense_init(ks[1], d_model, 4 * d_model, dtype),
        "bias": jnp.zeros((4 * d_model,), jnp.float32),
        "ff_wi": dense_init(ks[2], d_model, dff, dtype),
        "ff_wo": dense_init(ks[3], dff, d_model, dtype),
    }


def slstm_cell(p, xt, st: SLSTMState) -> Tuple[jnp.ndarray, SLSTMState]:
    """xt (B, 4d) pre-projected input contribution."""
    d = st.c.shape[-1]
    g = xt + dense(p["w_h"], st.h).astype(jnp.float32) + p["bias"]
    zi, ii, ff, oo = jnp.split(g.astype(jnp.float32), 4, axis=-1)
    zt = jnp.tanh(zi)
    logi = ii
    logf = jax.nn.log_sigmoid(ff)
    m_new = jnp.maximum(logf + st.m, logi)
    a = jnp.exp(logf + st.m - m_new)
    b = jnp.exp(logi - m_new)
    c_new = a * st.c + b * zt
    n_new = jnp.maximum(a * st.n + b, jnp.exp(-m_new))
    h_new = jax.nn.sigmoid(oo) * (c_new / n_new)
    return h_new, SLSTMState(c_new, n_new, h_new.astype(st.h.dtype), m_new)


def slstm_apply(p, x, xc_cfg: XLSTMCfg, *, mode: str = "train",
                state: Optional[SLSTMState] = None):
    bsz, s, d = x.shape
    xg = dense(p["w_x"], x).astype(jnp.float32)              # (B,S,4d)
    if state is None:
        z = jnp.zeros((bsz, d), jnp.float32)
        state = SLSTMState(z, jnp.ones_like(z), z.astype(x.dtype), z)

    if mode in ("train", "prefill"):
        cell = jax.checkpoint(lambda st, xt: slstm_cell(p, xt, st))

        def step(st, xt):
            h, st2 = cell(st, xt)
            return st2, h
        stN, hs = jax.lax.scan(step, state, xg.transpose(1, 0, 2))
        h = hs.transpose(1, 0, 2).astype(x.dtype)
        new_state = stN if mode == "prefill" else None
    else:
        assert s == 1
        h1, new_state = slstm_cell(p, xg[:, 0], state)
        h = h1[:, None].astype(x.dtype)

    ff = dense(p["ff_wo"], jax.nn.gelu(
        dense(p["ff_wi"], h).astype(jnp.float32)).astype(x.dtype))
    return h + ff, new_state
