"""Model configuration system.

One ``ModelConfig`` describes any of the assigned architectures: dense,
MoE, SSM (xLSTM), hybrid (Jamba), VLM-backbone, audio enc-dec.  The layer
stack is a repeated ``period`` of block specs (scan-over-periods keeps the
HLO size independent of depth); heterogeneous stacks (Jamba's 1:7
attention:mamba interleave, xLSTM's mLSTM/sLSTM mix, MoE-every-k) are all
expressed through the period pattern.
"""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple


def round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclass(frozen=True)
class MoECfg:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router_norm_topk: bool = False   # deepseek: normalize over chosen top-k
    # accuracy-tier name to route the top-k combine-weight normalization
    # denominator through repro.reduce (None = plain XLA sum, bitwise
    # identical to the pre-algebra path)
    router_norm_policy: Optional[str] = None


@dataclass(frozen=True)
class MambaCfg:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2


@dataclass(frozen=True)
class XLSTMCfg:
    num_heads: int = 4
    proj_factor_m: float = 2.0      # mLSTM up-projection
    proj_factor_s: float = 1.3      # sLSTM FFN factor
    conv_kernel: int = 4


@dataclass(frozen=True)
class BlockSpec:
    """One block in the period pattern."""
    kind: str              # 'attn' | 'mamba' | 'mlstm' | 'slstm'
    mlp: str = "swiglu"    # 'swiglu' | 'gelu' | 'moe' | 'none'


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|ssm|hybrid|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    period: Tuple[BlockSpec, ...] = (BlockSpec("attn", "swiglu"),)
    head_dim: Optional[int] = None
    moe: Optional[MoECfg] = None
    mamba: Optional[MambaCfg] = None
    xlstm: Optional[XLSTMCfg] = None
    # attention flavor
    attn_type: str = "gqa"            # 'gqa' | 'mla'
    window: Optional[int] = None      # sliding-window size (SWA)
    rope_theta: float = 1e4
    mrope: bool = False               # qwen2-vl multimodal rope (3 sections)
    # MLA (deepseek-v2) dims
    kv_lora_rank: int = 0
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128
    # enc-dec (seamless): encoder depth; decoder uses n_layers
    encoder_layers: int = 0
    # modality frontend stub: inputs arrive as precomputed embeddings
    embed_inputs: bool = False        # True => input_specs gives (B,S,D) f32
    norm_eps: float = 1e-5
    # accuracy-tier name to route every rmsnorm's mean-square through the
    # repro.reduce front door (None = plain XLA mean, bitwise identical
    # to the pre-algebra path); with an integer tier the norm denominator
    # — like the clip norm via adamw's norm_policy and the MoE combine
    # weights via MoECfg.router_norm_policy — stops depending on XLA's
    # internal reduction tiling
    norm_reduce_policy: Optional[str] = None
    tie_embeddings: bool = False
    dtype: str = "bfloat16"
    # chunk length for the SSM inner scans (mamba/mLSTM chunkwise forms);
    # dry-run cost-variants set it to seq_len so cost_analysis sees the
    # whole sequence (while bodies are counted once by XLA).
    scan_chunk: int = 512
    # query-block size for chunked (memory-bounded) training attention;
    # blocks of q attend to the full K/V without materializing (S, S).
    attn_qchunk: int = 1024
    # data-parallel mesh axes to pin activations to (None = unconstrained,
    # for single-device smoke runs).  Without this GSPMD may all-gather the
    # batch to exploit FSDP-sharded contracting dims (16x activation blowup).
    act_dp_axes: Optional[Tuple[str, ...]] = None
    # sequence-chunked fused head+xent: the (B, chunk, V) logits block is
    # the only vocab-sized tensor ever materialized (256k-vocab archs would
    # otherwise spend >10 GB/device on loss intermediates).
    loss_chunk: int = 1024
    # sequence parallelism: shard the residual stream's sequence axis over
    # this mesh axis between blocks (Megatron-SP).  The remat-saved per-layer
    # carries shrink by the axis size; blocks re-gather as needed.
    act_sp_axis: Optional[str] = None
    # MoE activation sharding: expert axis (EP) or expert-FF axis (expert-TP
    # when E doesn't divide the model axis) — set by the mesh plan.
    moe_expert_axis: Optional[str] = None
    moe_ff_axis: Optional[str] = None
    # expert-TP: reduce the wo partial sums cross-shard in bf16 instead of
    # f32 (halves the dominant all-reduce; per-shard accumulation stays f32)
    moe_bf16_combine: bool = False
    # virtual experts: split each expert's FFN into v column shards, giving
    # E*v schedulable experts — exact EP when E*v divides the model axis
    # (mixtral: 8*2=16).  The cross-shard f32 partial-sum all-reduce of
    # expert-TP becomes part of the (bf16) combine gather: each virtual
    # expert's partial output is one more row in the token's top-(k*v)
    # segmented sum — the JugglePAC variable-length-set combine, literally.
    moe_virtual_split: int = 1
    # long-context capability marker (for long_500k applicability)
    subquadratic: bool = False

    def __post_init__(self):
        assert self.n_layers % len(self.period) == 0, (
            f"{self.name}: n_layers {self.n_layers} not divisible by period "
            f"{len(self.period)}")

    @property
    def hdim(self) -> int:
        return self.head_dim or (self.d_model // self.n_heads)

    @property
    def padded_vocab(self) -> int:
        return round_up(self.vocab, 256)

    @property
    def n_periods(self) -> int:
        return self.n_layers // len(self.period)

    @property
    def is_encdec(self) -> bool:
        return self.encoder_layers > 0

    def scaled(self, **overrides) -> "ModelConfig":
        """A reduced copy for smoke tests."""
        return dataclasses.replace(self, **overrides)

    # ---- parameter counting (for roofline MODEL_FLOPS = 6*N*D) ----------

    def param_counts(self) -> dict:
        """Returns dict(total=..., active=...) parameter counts (no embed
        double count; embeddings included)."""
        d, h, kv, hd = self.d_model, self.n_heads, self.n_kv_heads, self.hdim
        per_kind_total = {}
        per_kind_active = {}

        def attn_params():
            if self.attn_type == "mla":
                r, nd, rd, vd = (self.kv_lora_rank, self.qk_nope_dim,
                                 self.qk_rope_dim, self.v_head_dim)
                q = d * h * (nd + rd)
                kv_a = d * (r + rd)
                kv_b = r * h * (nd + vd)
                o = h * vd * d
                return q + kv_a + kv_b + o
            return d * h * hd + 2 * d * kv * hd + h * hd * d

        def mlp_params(spec: BlockSpec):
            if spec.mlp == "moe":
                m = self.moe
                routed = m.num_experts * 3 * d * m.d_ff_expert
                shared = m.num_shared * 3 * d * (m.d_ff_shared or m.d_ff_expert)
                router = d * m.num_experts
                active = (m.top_k * 3 * d * m.d_ff_expert + shared + router)
                return routed + shared + router, active
            if spec.mlp == "none":
                return 0, 0
            ff = 3 * d * self.d_ff if spec.mlp == "swiglu" else 2 * d * self.d_ff
            return ff, ff

        def block_params(spec: BlockSpec):
            if spec.kind == "attn":
                core = attn_params()
            elif spec.kind == "mamba":
                m = self.mamba or MambaCfg()
                di = m.expand * d
                core = (d * 2 * di + di * m.d_conv + di * (2 * m.d_state + 1)
                        + di + di * d)
            elif spec.kind == "mlstm":
                x = self.xlstm or XLSTMCfg()
                di = int(x.proj_factor_m * d)
                core = d * 2 * di + 3 * di * di // x.num_heads + di * d + 3 * di
            elif spec.kind == "slstm":
                x = self.xlstm or XLSTMCfg()
                core = 4 * d * d + 4 * d * d + int(x.proj_factor_s * d) * d * 2
            else:
                raise ValueError(spec.kind)
            mlp_t, mlp_a = mlp_params(spec)
            return core + mlp_t, core + mlp_a

        total = active = 0
        for spec in self.period:
            t, a = block_params(spec)
            total += t * self.n_periods
            active += a * self.n_periods
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        total += emb
        active += emb
        if self.is_encdec:
            enc_block = attn_params() + 3 * d * self.d_ff
            total += self.encoder_layers * enc_block
            active += self.encoder_layers * enc_block
            # decoder cross-attention
            total += self.n_layers * attn_params()
            active += self.n_layers * attn_params()
        return dict(total=total, active=active)


# Shape set assigned to the LM family (applies to all 10 archs).
@dataclass(frozen=True)
class ShapeCfg:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # 'train' | 'prefill' | 'decode'


SHAPES = (
    ShapeCfg("train_4k", 4096, 256, "train"),
    ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    ShapeCfg("decode_32k", 32768, 128, "decode"),
    ShapeCfg("long_500k", 524288, 1, "decode"),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}
