"""The unified LM: init / train forward / prefill / decode for every
assigned architecture.

Layer stacking is scan-over-periods: parameters for each position in the
period pattern are stacked with a leading ``n_periods`` axis and consumed by
``lax.scan``, so HLO size is O(period), not O(depth) — essential for the
512-device dry-run compiles.  Heterogeneous stacks (Jamba 1:7, xLSTM m/s
mix, MoE-every-k) fall out of the period pattern.  Decode carries the
per-layer caches through the same scan.
"""

from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from . import attention as attn
from . import moe as moe_mod
from . import ssm
from .config import BlockSpec, MambaCfg, ModelConfig, XLSTMCfg
from .layers import (dense, dense_init, embed_init, embed_lookup, gelu_mlp,
                     gelu_mlp_init, rmsnorm, rmsnorm_init, swiglu, swiglu_init)


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def _block_init(key, spec: BlockSpec, cfg: ModelConfig, dtype, *,
                cross: bool = False):
    ks = jax.random.split(key, 6)
    p: Dict[str, Any] = {"norm1": rmsnorm_init(cfg.d_model, dtype)}
    if spec.kind == "attn":
        if cfg.attn_type == "mla":
            p["core"] = attn.mla_init(ks[0], cfg, dtype)
        else:
            p["core"] = attn.gqa_init(ks[0], cfg, dtype)
    elif spec.kind == "mamba":
        p["core"] = ssm.mamba_init(ks[0], cfg.d_model,
                                   cfg.mamba or MambaCfg(), dtype)
    elif spec.kind == "mlstm":
        p["core"] = ssm.mlstm_init(ks[0], cfg.d_model,
                                   cfg.xlstm or XLSTMCfg(), dtype)
    elif spec.kind == "slstm":
        p["core"] = ssm.slstm_init(ks[0], cfg.d_model,
                                   cfg.xlstm or XLSTMCfg(), dtype)
    else:
        raise ValueError(spec.kind)
    if cross:
        p["norm_x"] = rmsnorm_init(cfg.d_model, dtype)
        p["cross"] = attn.gqa_init(ks[1], cfg, dtype)
    if spec.mlp != "none":
        p["norm2"] = rmsnorm_init(cfg.d_model, dtype)
        if spec.mlp == "moe":
            p["mlp"] = moe_mod.moe_init(ks[2], cfg, dtype)
        elif spec.mlp == "swiglu":
            p["mlp"] = swiglu_init(ks[2], cfg.d_model, cfg.d_ff, dtype)
        else:
            p["mlp"] = gelu_mlp_init(ks[2], cfg.d_model, cfg.d_ff, dtype)
    return p


def _stacked_block_init(key, spec, cfg, dtype, n, **kw):
    keys = jax.random.split(key, n)
    return jax.vmap(lambda k: _block_init(k, spec, cfg, dtype, **kw))(keys)


def init_params(key, cfg: ModelConfig) -> Dict[str, Any]:
    dtype = jnp.dtype(cfg.dtype)
    keys = jax.random.split(key, 8 + len(cfg.period))
    params: Dict[str, Any] = {}
    # the embed table always exists: embed_inputs archs (vlm/audio) consume
    # precomputed embeddings at prefill but decode with text tokens
    params["embed"] = embed_init(keys[0], cfg.padded_vocab,
                                 cfg.d_model, dtype)
    params["blocks"] = [
        _stacked_block_init(keys[1 + j], spec, cfg, dtype, cfg.n_periods,
                            cross=cfg.is_encdec)
        for j, spec in enumerate(cfg.period)]
    params["final_norm"] = rmsnorm_init(cfg.d_model, dtype)
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            keys[5], cfg.d_model, cfg.padded_vocab, dtype)
    if cfg.is_encdec:
        enc_spec = BlockSpec("attn", "gelu")
        params["encoder"] = {
            "blocks": _stacked_block_init(keys[6], enc_spec, cfg, dtype,
                                          cfg.encoder_layers),
            "final_norm": rmsnorm_init(cfg.d_model, dtype),
        }
    return params


# ---------------------------------------------------------------------------
# blocks
# ---------------------------------------------------------------------------


def _constrain_act(x, cfg: ModelConfig):
    """Pin the batch axis of an activation to the dp mesh axes.  Without
    this GSPMD may all-gather the batch to exploit the FSDP (data)-sharded
    contracting dim of a weight — a 16x activation-memory blowup."""
    if cfg.act_dp_axes:
        dp = cfg.act_dp_axes if len(cfg.act_dp_axes) > 1 \
            else cfg.act_dp_axes[0]
        sp = cfg.act_sp_axis
        if sp is not None and x.ndim >= 3 and x.shape[1] > 1:
            return jax.lax.with_sharding_constraint(
                x, P(*((dp, sp) + (None,) * (x.ndim - 2))))
        return jax.lax.with_sharding_constraint(
            x, P(*((dp,) + (None,) * (x.ndim - 1))))
    return x


def _apply_block(bp, spec: BlockSpec, x, cfg: ModelConfig, *, positions,
                 mode, cache, enc_out, moe_impl, is_causal=True):
    aux = jnp.float32(0.0)
    h = rmsnorm(bp["norm1"], x, cfg.norm_eps,
                policy=cfg.norm_reduce_policy)
    new_cache = {}
    core_cache = None if cache is None else cache.get("core")

    if spec.kind == "attn":
        if cfg.attn_type == "mla":
            out, c2 = attn.mla_apply(bp["core"], h, cfg, positions=positions,
                                     mode=mode, cache=core_cache)
        else:
            out, c2 = attn.gqa_apply(bp["core"], h, cfg, positions=positions,
                                     mode=mode, cache=core_cache,
                                     causal=is_causal)
        new_cache["core"] = c2
    elif spec.kind == "mamba":
        out, c2 = ssm.mamba_apply(bp["core"], h, cfg.mamba or MambaCfg(),
                                  mode=mode, state=core_cache,
                                  chunk=cfg.scan_chunk, cfg=cfg)
        new_cache["core"] = c2
    elif spec.kind == "mlstm":
        out, c2 = ssm.mlstm_apply(bp["core"], h, cfg.xlstm or XLSTMCfg(),
                                  mode=mode, state=core_cache,
                                  chunk=cfg.scan_chunk)
        new_cache["core"] = c2
    elif spec.kind == "slstm":
        out, c2 = ssm.slstm_apply(bp["core"], h, cfg.xlstm or XLSTMCfg(),
                                  mode=mode, state=core_cache)
        new_cache["core"] = c2
    else:
        raise ValueError(spec.kind)
    x = x + out

    if "cross" in bp and enc_out is not None:
        # Cross-attention KV is recomputed from the encoder memory each call
        # (cheap relative to self-attention; avoids cache-structure drift
        # between prefill and decode).
        hx = rmsnorm(bp["norm_x"], x, cfg.norm_eps,
                     policy=cfg.norm_reduce_policy)
        k = dense(bp["cross"]["wk"], enc_out)
        v = dense(bp["cross"]["wv"], enc_out)
        hd = cfg.hdim
        k = k.reshape(k.shape[:-1] + (cfg.n_kv_heads, hd))
        v = v.reshape(v.shape[:-1] + (cfg.n_kv_heads, hd))
        out, _ = attn.gqa_apply(bp["cross"], hx, cfg, positions=positions,
                                mode="train", kv_override=(k, v), cross=True)
        x = x + out

    if spec.mlp != "none":
        h2 = rmsnorm(bp["norm2"], x, cfg.norm_eps,
                     policy=cfg.norm_reduce_policy)
        if spec.mlp == "moe":
            out, a = moe_mod.moe_apply(bp["mlp"], h2, cfg, impl=moe_impl)
            aux = aux + a
        elif spec.mlp == "swiglu":
            out = swiglu(bp["mlp"], h2)
        else:
            out = gelu_mlp(bp["mlp"], h2)
        x = x + out
    return x, new_cache, aux


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------


def _run_stack(params_blocks, cfg: ModelConfig, x, *, positions, mode,
               caches, enc_out, moe_impl, remat: bool = False,
               is_causal=True, pattern=None):
    """Scan over periods. ``caches``: list per pattern position of stacked
    cache pytrees (leading axis n_periods) or None."""
    pattern = pattern or cfg.period

    def period_body(xc, scanned):
        bps, cs = scanned
        aux = jnp.float32(0.0)
        new_cs = []
        xc = _constrain_act(xc, cfg)
        # detlint: ok[DET002] aux-loss scalar chain across unrolled
        # blocks: legacy bits pinned by tests; front-door routing is the
        # knob-gated follow-up (docs/algebra.md)
        for j, spec in enumerate(pattern):
            c_j = None if cs is None else cs[j]
            xc, nc, a = _apply_block(bps[j], spec, xc, cfg,
                                     positions=positions, mode=mode,
                                     cache=c_j, enc_out=enc_out,
                                     moe_impl=moe_impl, is_causal=is_causal)
            xc = _constrain_act(xc, cfg)
            new_cs.append(nc)
            aux = aux + a
        return xc, (tuple(new_cs), aux)

    body = period_body
    if remat:
        body = jax.checkpoint(period_body, prevent_cse=False)

    def scan_fn(xc, scanned):
        return body(xc, scanned)

    cs_stacked = None if caches is None else tuple(caches)
    nper = jax.tree.leaves(params_blocks[0])[0].shape[0]
    if nper <= 2:
        # Unrolled: dry-run depth-1/2 cost variants need the period body in
        # the top-level HLO (XLA cost_analysis counts while bodies ONCE,
        # independent of trip count, so scanned variants measure nothing).
        ys = []
        for i in range(nper):
            sl = jax.tree.map(lambda t: t[i],
                              (tuple(params_blocks), cs_stacked))
            x, y = scan_fn(x, sl)
            ys.append(y)
        new_caches, auxs = jax.tree.map(lambda *t: jnp.stack(t), *ys) \
            if ys else ((), jnp.zeros((0,)))
        return x, list(new_caches), jnp.sum(auxs)  # detlint: ok[DET001] L aux scalars
    x, (new_caches, auxs) = jax.lax.scan(
        scan_fn, x, (tuple(params_blocks), cs_stacked))
    return x, list(new_caches), jnp.sum(auxs)  # detlint: ok[DET001] L aux scalars


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def _default_positions(cfg: ModelConfig, bsz, s, offset=0):
    """``offset`` is a scalar (shared position) or a (B,) array — serving
    slots in a continuous batch sit at per-request positions."""
    off = jnp.asarray(offset, jnp.int32)
    pos = jnp.arange(s, dtype=jnp.int32)[None, :]
    pos = pos + (off[:, None] if off.ndim == 1 else off)
    pos = jnp.broadcast_to(pos, (bsz, s))
    if cfg.mrope:
        pos = jnp.broadcast_to(pos[..., None], (bsz, s, 3))
    return pos


def encode(params, cfg: ModelConfig, enc_embeds, *, remat=False):
    """Encoder stack (enc-dec only); enc_embeds (B, S, D) from the stub
    modality frontend."""
    bsz, s, _ = enc_embeds.shape
    positions = _default_positions(cfg, bsz, s)
    enc_cfg_pattern = (BlockSpec("attn", "gelu"),)
    x, _, _ = _run_stack([params["encoder"]["blocks"]], cfg, enc_embeds,
                         positions=positions, mode="train", caches=None,
                         enc_out=None, moe_impl="capacity", remat=remat,
                         is_causal=False, pattern=enc_cfg_pattern)
    return rmsnorm(params["encoder"]["final_norm"], x, cfg.norm_eps,
                   policy=cfg.norm_reduce_policy)


def forward_hidden(params, cfg: ModelConfig, *, tokens=None, embeds=None,
                   positions=None, mode: str = "train", caches=None,
                   enc_out=None, moe_impl: str = "capacity",
                   remat: bool = False, position_offset=0):
    """Backbone only: returns (final-norm hidden states, caches, aux)."""
    if embeds is not None:
        x = embeds
    else:
        x = embed_lookup(params["embed"], tokens)
    bsz, s = x.shape[0], x.shape[1]
    if positions is None:
        positions = _default_positions(cfg, bsz, s, position_offset)

    x, new_caches, aux = _run_stack(
        params["blocks"], cfg, x, positions=positions, mode=mode,
        caches=caches, enc_out=enc_out, moe_impl=moe_impl, remat=remat)

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps,
                policy=cfg.norm_reduce_policy)
    return x, new_caches, aux


def _lm_head(params, cfg: ModelConfig):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def forward(params, cfg: ModelConfig, *, tokens=None, embeds=None,
            positions=None, mode: str = "train", caches=None,
            enc_out=None, moe_impl: str = "capacity", remat: bool = False,
            position_offset=0, logits_pspec=None):
    """Returns (logits, new_caches, aux_loss)."""
    x, new_caches, aux = forward_hidden(
        params, cfg, tokens=tokens, embeds=embeds, positions=positions,
        mode=mode, caches=caches, enc_out=enc_out, moe_impl=moe_impl,
        remat=remat, position_offset=position_offset)
    logits = jnp.einsum("bsd,dv->bsv", x, _lm_head(params, cfg),
                        preferred_element_type=jnp.float32)
    if logits_pspec is not None:
        # keep the vocab axis sharded through the loss (26 GB/device if not)
        logits = jax.lax.with_sharding_constraint(logits, logits_pspec)
    return logits, new_caches, aux


def loss_fn(params, cfg: ModelConfig, batch, *, moe_impl="capacity",
            remat=False, aux_weight: float = 0.01, logits_pspec=None):
    """batch: tokens (B,S) [+ optional embeds/enc_embeds/positions];
    next-token xent in f32 with an MoE load-balance aux term."""
    tokens = batch.get("tokens")
    embeds = batch.get("embeds")
    enc_out = None
    if cfg.is_encdec:
        enc_out = encode(params, cfg, batch["enc_embeds"], remat=remat)
    hidden, _, aux = forward_hidden(
        params, cfg, tokens=tokens, embeds=embeds,
        positions=batch.get("positions"), mode="train",
        enc_out=enc_out, moe_impl=moe_impl, remat=remat)
    labels = batch.get("labels")
    if labels is None:
        labels = tokens[:, 1:]
        hidden = hidden[:, :-1]
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones(labels.shape, jnp.float32)
    else:
        mask = mask.astype(jnp.float32)[:, :labels.shape[1]]

    # Sequence-chunked fused head + xent.  Two disciplines at work:
    #  * gather-free: take_along_axis over the model-sharded vocab axis
    #    would make GSPMD all-gather the logits; instead lse reduces over
    #    the sharded axis (an all-reduce of (B, chunk)) and the label logit
    #    is a masked reduction;
    #  * chunked: only one (B, chunk, V) logits block is live at a time —
    #    256k-vocab archs would otherwise spend >10 GB/device here.  The
    #    chunk loop is a JugglePAC stream: per-chunk partial (nll, count)
    #    accumulate in the carry; the normalization happens once at the end.
    head = _lm_head(params, cfg)
    s = labels.shape[1]
    chunk = cfg.loss_chunk if (s % cfg.loss_chunk == 0) else s

    @jax.checkpoint
    def chunk_nll(h_c, lab_c, m_c):
        lg = jnp.einsum("bsd,dv->bsv", h_c, head,
                        preferred_element_type=jnp.float32)
        if logits_pspec is not None:
            lg = jax.lax.with_sharding_constraint(lg, logits_pspec)
        lse = jax.nn.logsumexp(lg, axis=-1)
        iota = jnp.arange(lg.shape[-1], dtype=jnp.int32)
        # detlint: ok[DET001] per-chunk xent math (label gather + masked
        # loss): legacy bits pinned by tests
        lab_logit = jnp.sum(
            jnp.where(iota[None, None, :] == lab_c[..., None], lg, 0.0),
            axis=-1)
        # detlint: ok[DET001] same xent chunk reduction as above
        return jnp.sum((lse - lab_logit) * m_c)

    if chunk == s:
        nll = chunk_nll(hidden, labels, mask)
    else:
        nb = s // chunk
        resh = lambda t: t.reshape(t.shape[0], nb, chunk, *t.shape[2:]) \
                          .swapaxes(0, 1)

        def body(acc, args):
            h_c, lab_c, m_c = args
            return acc + chunk_nll(h_c, lab_c, m_c), None

        nll, _ = jax.lax.scan(
            body, jnp.float32(0.0),
            (resh(hidden), resh(labels), resh(mask)))
    xent = nll / jnp.maximum(mask.sum(), 1.0)  # detlint: ok[DET001] token count, B*S well under 2^24
    loss = xent + aux_weight * aux
    return loss, {"xent": xent, "aux": aux,
                  "tokens": mask.sum()}  # detlint: ok[DET001] logging metric


# ---------------------------------------------------------------------------
# decode caches
# ---------------------------------------------------------------------------


def init_caches(cfg: ModelConfig, bsz: int, max_len: int,
                dtype=None) -> list:
    """Stacked (n_periods-leading) cache pytrees per pattern position."""
    dtype = dtype or jnp.dtype(cfg.dtype)
    n = cfg.n_periods
    caches = []
    for spec in cfg.period:
        if spec.kind == "attn":
            if cfg.attn_type == "mla":
                c = attn.MLACache(
                    c_kv=jnp.zeros((n, bsz, max_len, cfg.kv_lora_rank), dtype),
                    k_rope=jnp.zeros((n, bsz, max_len, cfg.qk_rope_dim), dtype),
                    length=jnp.zeros((n, bsz), jnp.int32))
            else:
                s_alloc = (cfg.window if cfg.window is not None else max_len)
                c = attn.KVCache(
                    k=jnp.zeros((n, bsz, s_alloc, cfg.n_kv_heads, cfg.hdim),
                                dtype),
                    v=jnp.zeros((n, bsz, s_alloc, cfg.n_kv_heads, cfg.hdim),
                                dtype),
                    length=jnp.zeros((n, bsz), jnp.int32))
            caches.append({"core": c})
        elif spec.kind == "mamba":
            m = cfg.mamba or MambaCfg()
            di = m.expand * cfg.d_model
            caches.append({"core": ssm.MambaState(
                h=jnp.zeros((n, bsz, di, m.d_state), jnp.float32),
                conv=jnp.zeros((n, bsz, m.d_conv - 1, di), dtype))})
        elif spec.kind == "mlstm":
            xc = cfg.xlstm or XLSTMCfg()
            di = int(xc.proj_factor_m * cfg.d_model)
            hd = di // xc.num_heads
            caches.append({"core": ssm.MLSTMState(
                c=jnp.zeros((n, bsz, xc.num_heads, hd, hd), jnp.float32),
                n=jnp.zeros((n, bsz, xc.num_heads, hd), jnp.float32),
                m=jnp.zeros((n, bsz, xc.num_heads), jnp.float32),
                conv=jnp.zeros((n, bsz, xc.conv_kernel - 1, di), dtype))})
        elif spec.kind == "slstm":
            d = cfg.d_model
            caches.append({"core": ssm.SLSTMState(
                c=jnp.zeros((n, bsz, d), jnp.float32),
                n=jnp.ones((n, bsz, d), jnp.float32),
                h=jnp.zeros((n, bsz, d), dtype),
                m=jnp.zeros((n, bsz, d), jnp.float32))})
        else:
            raise ValueError(spec.kind)
    return caches


def pad_caches_to(cfg: ModelConfig, caches, max_len: int):
    """Grow prefill-shaped KV caches (seq axis == prefill length) to
    ``max_len`` so decode can append.  Ring / SSM caches are O(1) already."""
    def pad_block(c, spec: BlockSpec):
        core = c.get("core")
        if core is None:
            return c
        if isinstance(core, attn.KVCache) and cfg.window is None:
            s_now = core.k.shape[2]       # (n, B, S, K, hd)
            padn = max_len - s_now
            if padn > 0:
                padk = jnp.pad(core.k, ((0, 0), (0, 0), (0, padn),
                                        (0, 0), (0, 0)))
                padv = jnp.pad(core.v, ((0, 0), (0, 0), (0, padn),
                                        (0, 0), (0, 0)))
                return {**c, "core": attn.KVCache(padk, padv, core.length)}
        if isinstance(core, attn.MLACache):
            s_now = core.c_kv.shape[2]
            padn = max_len - s_now
            if padn > 0:
                pc = jnp.pad(core.c_kv, ((0, 0), (0, 0), (0, padn), (0, 0)))
                pr = jnp.pad(core.k_rope, ((0, 0), (0, 0), (0, padn), (0, 0)))
                return {**c, "core": attn.MLACache(pc, pr, core.length)}
        return c

    return [pad_block(c, spec) for c, spec in zip(caches, cfg.period)]


def decode_step(params, cfg: ModelConfig, token, caches, position, *,
                enc_out=None, moe_impl: str = "capacity"):
    """One serving step: token (B, 1) -> (logits (B,1,V), new caches).

    ``position`` may be a scalar (lock-step batch) or a (B,) array of
    per-request positions (continuous batching: each slot appends at its
    own cache length).  ``token`` with s > 1 columns is a chunked-prefill
    extend for attention caches (SSM states remain one-token-at-a-time).
    """
    bsz, s = token.shape[0], token.shape[1]
    positions = _default_positions(cfg, bsz, s, position)
    logits, new_caches, _ = forward(params, cfg, tokens=token,
                                    positions=positions, mode="decode",
                                    caches=caches, enc_out=enc_out,
                                    moe_impl=moe_impl)
    return logits, new_caches
