"""Attention blocks: GQA (+ sliding window), MLA (DeepSeek-V2), cross-attn.

Three execution modes share one set of weights:
  * mode="train"/"prefill": full-sequence causal attention (optionally
    windowed).  Prefill additionally returns the KV cache.
  * mode="decode": one new token against a cache.  GQA decode can run via
    the Pallas flash_decode kernel (use_pallas=True) or the jnp reference —
    identical math; the jnp path is what the multi-pod dry-run lowers (the
    HLO roofline terms are equivalent).

Caches:
  * full cache   k,v (B, S, K, hd) + length (B,)
  * ring cache   k,v (B, W, K, hd) + absolute position — sliding-window
    (mixtral) long-context decode in O(W) memory: the sub-quadratic path.
  * MLA latent   c_kv (B, S, r) + k_rope (B, S, rd): decode works entirely
    in the r-dim latent space (absorbed projections), the paper-exact trick
    from DeepSeek-V2 — per-token cache is r+rd instead of 2*K*hd.
"""

from __future__ import annotations

from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import apply_mrope, apply_rope, causal_mask, dense, dense_init


class KVCache(NamedTuple):
    k: jnp.ndarray          # (B, S, K, hd) — or (B, W, K, hd) ring buffer
    v: jnp.ndarray
    length: jnp.ndarray     # (B,) int32 — tokens currently valid
    # NB: ring (sliding-window) addressing is a *static* property derived
    # from cfg.window, never stored here — it must not be traced.


class MLACache(NamedTuple):
    c_kv: jnp.ndarray       # (B, S, r)
    k_rope: jnp.ndarray     # (B, S, rd)
    length: jnp.ndarray


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------


def gqa_init(key, cfg: ModelConfig, dtype):
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hdim
    ks = jax.random.split(key, 4)
    return {"wq": dense_init(ks[0], d, h * hd, dtype),
            "wk": dense_init(ks[1], d, kv * hd, dtype),
            "wv": dense_init(ks[2], d, kv * hd, dtype),
            "wo": dense_init(ks[3], h * hd, d, dtype)}


def _split_heads(x, n, hd):
    return x.reshape(x.shape[:-1] + (n, hd))


def _rope_or_mrope(x, positions, cfg: ModelConfig):
    if cfg.mrope:
        # positions (B, S, 3); hd/2 partitioned per qwen2-vl
        # ([16,24,24] at hd=128, scaled proportionally otherwise).
        half = x.shape[-1] // 2
        s0 = max(1, round(half * 16 / 64))
        s1 = (half - s0) // 2
        s2 = half - s0 - s1
        return apply_mrope(x, positions, cfg.rope_theta, (s0, s1, s2))
    return apply_rope(x, positions, cfg.rope_theta)


def _sdpa_chunked(q, k, v, cfg: ModelConfig, sm_scale, *, causal: bool,
                  qchunk: int):
    """Memory-bounded causal attention: scan over query blocks, each block
    attending to full K/V — scores are (B, H, qc, S), never (S, S).

    This is the streaming-accumulation discipline again: the query stream is
    processed block-by-block against a resident K/V, exactly how the Pallas
    flash kernel tiles, expressed at the jnp level so it shards under pjit.
    """
    b, s, h, hd = q.shape
    nblk = s // qchunk
    qb = q.reshape(b, nblk, qchunk, h, hd).transpose(1, 0, 2, 3, 4)

    @jax.checkpoint
    def blk_body(i, qblk):
        # checkpointed so the scan VJP saves only (i, qblk), never the
        # (B, H, qc, S) score blocks — flash-attention memory discipline
        if causal:
            mask = causal_mask(qchunk, s, offset=i * qchunk,
                               window=cfg.window)
        else:
            mask = jnp.zeros((qchunk, s), jnp.float32)
        return _sdpa(qblk, k, v, mask, sm_scale)

    def blk(carry, args):
        i, qblk = args
        return carry, blk_body(i, qblk)

    _, outs = jax.lax.scan(blk, (), (jnp.arange(nblk), qb))
    return outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, hd)


def _sdpa(q, k, v, mask, sm_scale):
    """q (B,S,H,hd), k/v (B,T,K,hd) grouped; mask (B,1,S,T) or (S,T)."""
    b, s, h, hd = q.shape
    kheads = k.shape[2]
    g = h // kheads
    qg = q.reshape(b, s, kheads, g, hd)
    scores = jnp.einsum("bskgd,btkd->bkgst", qg.astype(jnp.float32),
                        k.astype(jnp.float32),
                        preferred_element_type=jnp.float32) * sm_scale
    if mask.ndim == 2:
        mask = mask[None, None, None]
    else:
        mask = mask[:, None, :, :][:, :, None]   # (B,1,1,S,T)
    scores = scores + mask
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bkgst,btkd->bskgd", p, v.astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    return out.reshape(b, s, h, hd)


def gqa_apply(params, x, cfg: ModelConfig, *, positions, mode: str = "train",
              cache: Optional[KVCache] = None,
              kv_override: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
              cross: bool = False, causal: bool = True):
    """Returns (out, new_cache)."""
    b, s, d = x.shape
    h, kvh, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hdim
    sm_scale = hd ** -0.5
    is_ring = cfg.window is not None          # static

    q = _split_heads(dense(params["wq"], x), h, hd)
    if kv_override is not None:                  # cross-attention memory
        k, v = kv_override
    else:
        k = _split_heads(dense(params["wk"], x), kvh, hd)
        v = _split_heads(dense(params["wv"], x), kvh, hd)

    if not cross:
        q = _rope_or_mrope(q, positions, cfg)
        if kv_override is None:
            k = _rope_or_mrope(k, positions, cfg)

    new_cache = cache
    if mode in ("train", "prefill"):
        qchunk = cfg.attn_qchunk
        if s > qchunk and s % qchunk == 0:
            out = _sdpa_chunked(q, k, v, cfg, sm_scale,
                                causal=(causal and not cross), qchunk=qchunk)
        else:
            if cross or not causal:
                t = k.shape[1]
                mask = jnp.zeros((s, t), jnp.float32)
            else:
                mask = causal_mask(s, s, window=cfg.window)
            out = _sdpa(q, k, v, mask, sm_scale)
        if mode == "prefill" and not cross:
            from .layers import shard_hint
            # cache layout: head_dim on 'model' — matches the natural
            # projection sharding, so no cross-layout reshard of the cache
            # (GSPMD's replicate-fallback costs ~17 GB/layer otherwise)
            k = shard_hint(k, cfg, ("dp", None, None, "model"))
            v = shard_hint(v, cfg, ("dp", None, None, "model"))
            if is_ring:
                # Pack the last W positions into ring order: slot j holds
                # the latest p <= s-1 with p % W == j.  Slots with p < 0
                # (when s < W) hold garbage but are masked at decode.
                w = cfg.window
                j = jnp.arange(w)
                p = (s - 1) - ((s - 1 - j) % w)
                p_safe = jnp.clip(p, 0, s - 1)
                new_cache = KVCache(k=k[:, p_safe], v=v[:, p_safe],
                                    length=jnp.full((b,), s, jnp.int32))
            else:
                new_cache = KVCache(k=k, v=v,
                                    length=jnp.full((b,), s, jnp.int32))
    elif mode == "decode":
        # Decode/extend against a cache.  Each batch row appends its ``s``
        # new tokens at its OWN ``length[row]`` (continuous-batching slots
        # hold requests at heterogeneous positions), so writes are per-row
        # scatters, not one shared dynamic_update_slice.  s == 1 is the
        # classic decode step; s > 1 is a chunked-prefill extend: the chunk
        # attends causally to [0, length + qi] per chunk-local query qi.
        # Out-of-bounds positions (an idle serving slot past max_len) are
        # dropped rather than clamped.
        if cache is None:
            raise ValueError("gqa_apply: mode='decode' needs a cache")
        length = cache.length                    # (B,) tokens already cached
        rows = jnp.arange(b)[:, None]            # (B, 1)
        qi = jnp.arange(s, dtype=length.dtype)   # chunk-local query offsets
        newpos = length[:, None] + qi[None, :]   # (B, s) absolute positions
        if is_ring:
            # Ring (sliding-window) cache: slot j holds the latest absolute
            # position p <= L with p % W == j  =>  p = L - ((L - j) % W).
            w = cache.k.shape[1]
            ck = cache.k.at[rows, newpos % w].set(k, mode="drop")
            cv = cache.v.at[rows, newpos % w].set(v, mode="drop")
            j = jnp.arange(w)[None, :]
            last = length[:, None] + (s - 1)
            pos_k = last - ((last - j) % w)                  # (B, W)
            # query qi sees ring positions in (newpos - w, newpos]
            valid = (pos_k[:, None, :] <= newpos[..., None]) \
                & (pos_k[:, None, :] > newpos[..., None] - w) \
                & (pos_k[:, None, :] >= 0)
        else:
            ck = cache.k.at[rows, newpos].set(k, mode="drop")
            cv = cache.v.at[rows, newpos].set(v, mode="drop")
            t = ck.shape[1]
            j = jnp.arange(t)[None, None, :]
            valid = j <= newpos[..., None]                   # (B, s, T)
            if cfg.window is not None:
                valid &= j > (newpos[..., None] - cfg.window)
        mask = jnp.where(valid, 0.0, -1e30)               # (B, s, T)
        out = _sdpa(q, ck, cv, mask, sm_scale)
        new_cache = KVCache(ck, cv, length + s)
    else:
        raise ValueError(mode)

    out = out.astype(x.dtype).reshape(b, s, h * hd)
    return dense(params["wo"], out), new_cache


# ---------------------------------------------------------------------------
# MLA — Multi-head Latent Attention (DeepSeek-V2)
# ---------------------------------------------------------------------------


def mla_init(key, cfg: ModelConfig, dtype):
    d, h = cfg.d_model, cfg.n_heads
    r, nd, rd, vd = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    ks = jax.random.split(key, 6)
    return {"wq": dense_init(ks[0], d, h * (nd + rd), dtype),
            "wdkv": dense_init(ks[1], d, r, dtype),
            "wkr": dense_init(ks[2], d, rd, dtype),
            "wuk": dense_init(ks[3], r, h * nd, dtype),
            "wuv": dense_init(ks[4], r, h * vd, dtype),
            "wo": dense_init(ks[5], h * vd, d, dtype),
            "c_norm": jnp.ones((r,), dtype)}


def mla_apply(params, x, cfg: ModelConfig, *, positions, mode: str = "train",
              cache: Optional[MLACache] = None):
    """Returns (out, new_cache). Decode runs fully absorbed in the latent
    space — the cache stores only (c_kv, k_rope): r+rd floats per token."""
    from .layers import rmsnorm  # local import to avoid cycle at module load

    b, s, d = x.shape
    h = cfg.n_heads
    r, nd, rd, vd = (cfg.kv_lora_rank, cfg.qk_nope_dim, cfg.qk_rope_dim,
                     cfg.v_head_dim)
    sm_scale = (nd + rd) ** -0.5

    q = _split_heads(dense(params["wq"], x), h, nd + rd)   # (B,S,H,nd+rd)
    qn, qr = q[..., :nd], q[..., nd:]
    qr = apply_rope(qr, positions, cfg.rope_theta)

    c = rmsnorm(params["c_norm"], dense(params["wdkv"], x), cfg.norm_eps,
                policy=cfg.norm_reduce_policy)
    kr = dense(params["wkr"], x)[:, :, None, :]             # (B,S,1,rd)
    kr = apply_rope(kr, positions, cfg.rope_theta)[:, :, 0]  # (B,S,rd)

    if mode in ("train", "prefill"):
        kn = _split_heads(dense(params["wuk"], c), h, nd)   # (B,S,H,nd)
        v = _split_heads(dense(params["wuv"], c), h, vd)    # (B,S,H,vd)
        knf = kn.astype(jnp.float32)
        krf = kr.astype(jnp.float32)
        vf = v.astype(jnp.float32)

        def block(qn_blk, qr_blk, offset):
            sc = (jnp.einsum("bshd,bthd->bhst", qn_blk, knf)
                  + jnp.einsum("bshd,btd->bhst", qr_blk, krf)) * sm_scale
            sc = sc + causal_mask(qn_blk.shape[1], s, offset=offset)[None, None]
            p = jax.nn.softmax(sc, axis=-1)
            return jnp.einsum("bhst,bthd->bshd", p, vf)

        qchunk = cfg.attn_qchunk
        if s > qchunk and s % qchunk == 0:
            nblk = s // qchunk
            qnb = qn.astype(jnp.float32).reshape(
                b, nblk, qchunk, h, nd).transpose(1, 0, 2, 3, 4)
            qrb = qr.astype(jnp.float32).reshape(
                b, nblk, qchunk, h, rd).transpose(1, 0, 2, 3, 4)

            block_ckpt = jax.checkpoint(block)

            def scan_blk(carry, args):
                i, qnq, qrq = args
                return carry, block_ckpt(qnq, qrq, i * qchunk)

            _, outs = jax.lax.scan(scan_blk, (),
                                   (jnp.arange(nblk), qnb, qrb))
            out = outs.transpose(1, 0, 2, 3, 4).reshape(b, s, h, vd)
        else:
            out = block(qn.astype(jnp.float32), qr.astype(jnp.float32), 0)
        new_cache = cache
        if mode == "prefill":
            from .layers import shard_hint
            c_sh = shard_hint(c, cfg, ("dp", None, "model"))
            kr_sh = shard_hint(kr, cfg, ("dp", None, None))
            new_cache = MLACache(c_kv=c_sh, k_rope=kr_sh,
                                 length=jnp.full((b,), s, jnp.int32))
    elif mode == "decode":
        # Per-row append (continuous-batching slots sit at heterogeneous
        # lengths); s > 1 is a chunked-prefill extend with chunk-causal
        # masking, mirroring the GQA decode/extend branch.
        if cache is None:
            raise ValueError("mla_apply: mode='decode' needs a cache")
        length = cache.length
        rows = jnp.arange(b)[:, None]
        newpos = length[:, None] + jnp.arange(s, dtype=length.dtype)[None, :]
        cc = cache.c_kv.at[rows, newpos].set(c, mode="drop")
        ckr = cache.k_rope.at[rows, newpos].set(kr, mode="drop")
        t = cc.shape[1]
        # absorb W_uk into the query: q_eff (B,s,H,r)
        wuk = params["wuk"].reshape(r, h, nd)
        q_eff = jnp.einsum("bshd,rhd->bshr", qn.astype(jnp.float32),
                           wuk.astype(jnp.float32))
        scores = (jnp.einsum("bshr,btr->bhst", q_eff,
                             cc.astype(jnp.float32))
                  + jnp.einsum("bshd,btd->bhst", qr.astype(jnp.float32),
                               ckr.astype(jnp.float32))) * sm_scale
        valid = jnp.arange(t)[None, None, :] <= newpos[..., None]  # (B,s,t)
        scores = scores + jnp.where(valid, 0.0, -1e30)[:, None, :, :]
        p = jax.nn.softmax(scores, axis=-1)
        o_lat = jnp.einsum("bhst,btr->bshr", p, cc.astype(jnp.float32))
        wuv = params["wuv"].reshape(r, h, vd)
        out = jnp.einsum("bshr,rhd->bshd", o_lat, wuv.astype(jnp.float32))
        new_cache = MLACache(cc, ckr, length + s)
    else:
        raise ValueError(mode)

    out = out.astype(x.dtype).reshape(b, s, h * vd)
    return dense(params["wo"], out), new_cache
