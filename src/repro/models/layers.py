"""Basic layers: inits, norms, MLPs, rotary embeddings.

Parameters are plain pytrees (nested dicts of jnp arrays); every layer is a
pair of functions (init, apply).  Compute-critical matmuls take
``preferred_element_type=float32`` so bf16 params accumulate in f32 (MXU
native behavior).
"""

from __future__ import annotations

from typing import Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .config import ModelConfig


def shard_hint(x, cfg: ModelConfig, dims: Sequence):
    """with_sharding_constraint helper: ``dims`` entries are 'dp' (the
    configured data-parallel axes), 'sp' (the sequence-parallel axis), a
    mesh-axis name, or None.  No-op when cfg.act_dp_axes is unset (smoke
    runs without a mesh)."""
    if not cfg.act_dp_axes:
        return x
    spec = []
    for d in dims:
        if d == "dp":
            dp = cfg.act_dp_axes
            spec.append(dp if len(dp) > 1 else dp[0])
        elif d == "sp":
            if cfg.act_sp_axis is None:
                spec.append(None)
            else:
                spec.append(cfg.act_sp_axis)
        else:
            spec.append(d)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def _dtype(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


def dense_init(key, d_in: int, d_out: int, dtype, scale: Optional[float] = None):
    scale = scale if scale is not None else d_in ** -0.5
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def dense(w, x):
    return jnp.einsum("...d,df->...f", x, w,
                      preferred_element_type=jnp.float32).astype(x.dtype)


def rmsnorm_init(d: int, dtype):
    return jnp.ones((d,), dtype)


def rmsnorm(g, x, eps: float = 1e-5, *, policy: Optional[str] = None):
    """``policy=None`` (default) is the legacy XLA mean — bit for bit.
    A policy name routes the per-token mean square through the
    ``repro.reduce`` front door instead: the feature axis becomes the
    stream (one (D, T) ``op="sumsq"`` pass, tokens as the element
    width), so under an integer tier the norm denominator is bitwise
    independent of how XLA tiles the reduction."""
    xf = x.astype(jnp.float32)
    if policy is None:
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)  # detlint: ok[DET001] policy=None legacy path, bits pinned; sumsq front door is the knob
    else:
        from repro import reduce as _reduce
        d = xf.shape[-1]
        cols = xf.reshape(-1, d).T                       # (D, T)
        ssq = _reduce.reduce(cols, op="sumsq", policy=policy)
        var = (ssq / d).reshape(xf.shape[:-1] + (1,))
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * g


def swiglu_init(key, d: int, d_ff: int, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"wi": dense_init(k1, d, d_ff, dtype),
            "wg": dense_init(k2, d, d_ff, dtype),
            "wo": dense_init(k3, d_ff, d, dtype)}


def swiglu(p, x):
    h = jax.nn.silu(dense(p["wg"], x).astype(jnp.float32)).astype(x.dtype)
    return dense(p["wo"], h * dense(p["wi"], x))


def gelu_mlp_init(key, d: int, d_ff: int, dtype):
    k1, k2 = jax.random.split(key)
    return {"wi": dense_init(k1, d, d_ff, dtype),
            "wo": dense_init(k2, d_ff, d, dtype)}


def gelu_mlp(p, x):
    return dense(p["wo"], jax.nn.gelu(dense(p["wi"], x).astype(jnp.float32))
                 .astype(x.dtype))


def embed_init(key, vocab: int, d: int, dtype):
    return (jax.random.normal(key, (vocab, d), jnp.float32) * 0.02).astype(dtype)


def embed_lookup(table, tokens):
    return jnp.take(table, tokens, axis=0)


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(hdim: int, theta: float) -> jnp.ndarray:
    # detlint: ok[DET006] RoPE frequency grid: hdim/2 well under 2^24
    return 1.0 / (theta ** (jnp.arange(0, hdim, 2, dtype=jnp.float32) / hdim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float = 1e4) -> jnp.ndarray:
    """x (..., S, H, hd); positions (..., S) int32."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)                         # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    ang = ang[..., None, :]                               # (..., S, 1, hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions3: jnp.ndarray, theta: float,
                sections: Sequence[int] = (16, 24, 24)) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE.

    x (B, S, H, hd); positions3 (B, S, 3) = (temporal, height, width) ids.
    The hd/2 frequency slots are partitioned into 3 sections, each rotated by
    its own position stream.  For pure text all three streams are equal and
    M-RoPE == RoPE.
    """
    hd = x.shape[-1]
    half = hd // 2
    sections = list(sections)
    assert sum(sections) == half, (sections, half)
    freqs = rope_freqs(hd, theta)                         # (half,)
    sec_idx = jnp.repeat(jnp.arange(3), jnp.asarray(sections),
                         total_repeat_length=half)        # (half,)
    # gather each slot's position stream: pos_per_slot (B, S, half)
    pos_per_slot = positions3.astype(jnp.float32)[..., sec_idx]
    ang = pos_per_slot * freqs                            # (B, S, half)
    ang = ang[..., None, :]                               # (B, S, 1, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def causal_mask(s_q: int, s_k: int, *, offset: int = 0,
                window: Optional[int] = None) -> jnp.ndarray:
    """(s_q, s_k) additive mask. offset = first query position."""
    qi = jnp.arange(s_q)[:, None] + offset
    kj = jnp.arange(s_k)[None, :]
    ok = kj <= qi
    if window is not None:
        ok &= kj > (qi - window)
    return jnp.where(ok, 0.0, -1e30).astype(jnp.float32)
