"""Model zoo: layers, attention, MoE, SSM blocks, and the unified LM."""
from . import attention, config, layers, model, moe, ssm  # noqa: F401
from .config import ModelConfig, SHAPES, SHAPES_BY_NAME  # noqa: F401
from .model import (decode_step, encode, forward, init_caches, init_params,  # noqa: F401
                    loss_fn, pad_caches_to)
