"""Mixture-of-Experts: top-k router + capacity-based dispatch (EP-shardable).

Two dispatch strategies, one contract:

  * ``capacity``  — production/dry-run path: tokens are packed into a fixed
    (E, C) buffer with one-hot dispatch/combine einsums (MaxText-style).
    Under pjit with the expert axis sharded on 'model', XLA turns the
    dispatch/combine einsums into all-to-alls — expert parallelism.
  * ``dense``     — small-scale/oracle path: every expert runs on every token,
    gated combine.  O(E) compute, exact (no capacity drops); used by smoke
    tests as the reference for the capacity path.

The **combine** step is a segmented accumulation (each token sums its top-k
expert contributions — variable "set" sizes once capacity drops happen);
``combine_segsum`` routes it through the JugglePAC segmented-reduction
kernel, which is the paper's technique doing real work in the MoE layer.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from .config import ModelConfig, MoECfg
from .layers import dense_init


def moe_init(key, cfg: ModelConfig, dtype):
    m = cfg.moe
    d = cfg.d_model
    v = cfg.moe_virtual_split
    e, f = m.num_experts * v, m.d_ff_expert // v
    assert m.d_ff_expert % v == 0
    ks = jax.random.split(key, 5)
    p = {"router": dense_init(ks[0], d, m.num_experts, jnp.float32),
         "wi": (jax.random.normal(ks[1], (e, d, f), jnp.float32)
                * d ** -0.5).astype(dtype),
         "wg": (jax.random.normal(ks[2], (e, d, f), jnp.float32)
                * d ** -0.5).astype(dtype),
         "wo": (jax.random.normal(ks[3], (e, f, d), jnp.float32)
                * (f * v) ** -0.5).astype(dtype)}
    if m.num_shared:
        fs = m.d_ff_shared or m.d_ff_expert
        from .layers import swiglu_init
        p["shared"] = swiglu_init(ks[4], d, m.num_shared * fs, dtype)
    return p


def router_topk(router_w, x, m: MoECfg):
    """Returns (weights (T,k) f32, idx (T,k) i32, aux_loss scalar)."""
    logits = jnp.einsum("td,de->te", x.astype(jnp.float32), router_w)
    probs = jax.nn.softmax(logits, axis=-1)
    w, idx = jax.lax.top_k(probs, m.top_k)
    if m.router_norm_topk:
        if m.router_norm_policy is not None:
            # combine-weight normalization through the front door: the
            # top-k axis is the stream (k rows, tokens as the width), so
            # the denominator every combine weight divides by reduces
            # under the configured accuracy tier
            from repro import reduce as _reduce
            den = _reduce.reduce(w.T, policy=m.router_norm_policy)  # (T,)
            w = w / jnp.maximum(den[:, None], 1e-9)
        else:
            w = w / jnp.maximum(w.sum(-1, keepdims=True), 1e-9)  # detlint: ok[DET001] legacy branch, bits pinned; router_norm_policy is the front door
    # load-balancing auxiliary loss (Switch-style)
    e = m.num_experts
    # detlint: ok[DET001] Switch aux-loss stats over E experts: legacy
    # bits pinned by tests (next pragma covers all three reductions)
    me = jnp.mean(probs, axis=0)                            # mean router prob
    ce = jnp.mean(jax.nn.one_hot(idx[:, 0], e), axis=0)  # detlint: ok[DET001] top-1 load, E experts
    aux = e * jnp.sum(me * ce)  # detlint: ok[DET001] aux-loss scalar, E experts
    return w, idx, aux


def _expert_ffn(p, xe):
    """xe (E, C, D) -> (E, C, D); batched swiglu over the expert axis."""
    hi = jnp.einsum("ecd,edf->ecf", xe, p["wi"],
                    preferred_element_type=jnp.float32)
    hg = jnp.einsum("ecd,edf->ecf", xe, p["wg"],
                    preferred_element_type=jnp.float32)
    h = (jax.nn.silu(hg) * hi).astype(xe.dtype)
    return jnp.einsum("ecf,efd->ecd", h, p["wo"],
                      preferred_element_type=jnp.float32).astype(xe.dtype)


MOE_GROUP = 4096   # tokens per capacity group (aligns with dp shards)


def moe_apply_capacity(params, x, cfg: ModelConfig, *,
                       capacity: Optional[int] = None,
                       group_size: int = MOE_GROUP):
    """x (B, S, D) -> (B, S, D).  Grouped gather/scatter dispatch.

    Tokens are processed in groups of ``group_size`` with a fixed per-group
    expert capacity Cg = ceil(G*k*cf/E).  Dispatch and combine are pure
    gathers (batched over the group axis, so the dp sharding of tokens never
    moves), and the expert FFN is an einsum with the expert axis sharded on
    'model' — EP without any fake one-hot matmul FLOPs.  The group axis is
    the JugglePAC "block stream": each group is a block, expert buffers are
    the label-addressed registers, and capacity drops are the bounded-storage
    rule made explicit.
    """
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    v = cfg.moe_virtual_split
    e, k = m.num_experts * v, m.top_k * v
    xt = x.reshape(t, d)
    w, idx, aux = router_topk(params["router"], xt, m)      # (T,k) f32/i32
    if v > 1:
        # each chosen expert expands to its v virtual column shards; the
        # shards' partial outputs sum in the combine (weights unchanged:
        # y = sum_v (x @ wi_v) @ wo_v)
        idx = (idx[:, :, None] * v
               + jnp.arange(v)[None, None, :]).reshape(t, k)
        w = jnp.repeat(w, v, axis=1)

    g = min(group_size, t)
    ng = -(-t // g)
    padt = ng * g - t
    if padt:
        xt = jnp.pad(xt, ((0, padt), (0, 0)))
        idx = jnp.pad(idx, ((0, padt), (0, 0)), constant_values=0)
        w = jnp.pad(w, ((0, padt), (0, 0)))                 # zero weight
    cg = capacity or max(1, int(m.capacity_factor * g * k / e))

    idx_g = idx.reshape(ng, g * k)                          # token-major
    w_g = w.reshape(ng, g, k)

    # position of each (token, choice) in its expert's per-group buffer
    onehot = jax.nn.one_hot(idx_g, e, dtype=jnp.int32)      # (nG, G*k, E)
    pos = jnp.cumsum(onehot, axis=1) - 1  # detlint: ok[DET001] int32 slot-position prefix count: exact, part of the dispatch algorithm
    pos = jnp.take_along_axis(pos, idx_g[..., None], axis=-1)[..., 0]
    keep = pos < cg                                         # (nG, G*k)

    # scatter token ids into expert slots: slots (nG, E*Cg [+1 overflow])
    slot = jnp.where(keep, idx_g * cg + pos, e * cg)
    tok_in_g = jnp.broadcast_to(
        (jnp.arange(g)[:, None]).reshape(1, g, 1), (ng, g, k)).reshape(ng, g * k)
    slots = jnp.full((ng, e * cg + 1), g, jnp.int32)
    slots = slots.at[jnp.arange(ng)[:, None], slot].set(tok_in_g, mode="drop")
    slots = slots[:, :e * cg]                               # drop overflow

    # dispatch gather: (nG, G+1, D) -> (nG, E*Cg, D)
    from .layers import shard_hint
    xg = shard_hint(xt.reshape(ng, g, d), cfg, ("dp", None, None))
    xg_pad = jnp.pad(xg, ((0, 0), (0, 1), (0, 0)))          # zero row @ G
    xe = jnp.take_along_axis(xg_pad, slots[..., None], axis=1)
    ea, fa = cfg.moe_expert_axis, cfg.moe_ff_axis
    xe = shard_hint(xe.reshape(ng, e, cg, d), cfg, ("dp", ea, None, None))

    # expert FFN (E sharded on 'model' => expert parallelism)
    hi = jnp.einsum("gecd,edf->gecf", xe, params["wi"],
                    preferred_element_type=jnp.float32)
    hg = jnp.einsum("gecd,edf->gecf", xe, params["wg"],
                    preferred_element_type=jnp.float32)
    h = (jax.nn.silu(hg) * hi).astype(xe.dtype)
    h = shard_hint(h, cfg, ("dp", ea, None, fa))
    # under expert-TP the contraction over the F-sharded axis emits a
    # cross-shard all-reduce of the partials; bf16 halves that traffic
    # (per-shard MXU accumulation remains f32 either way)
    combine_dtype = (jnp.bfloat16 if (cfg.moe_bf16_combine and fa)
                     else jnp.float32)
    ye = jnp.einsum("gecf,efd->gecd", h, params["wo"],
                    preferred_element_type=combine_dtype).astype(xe.dtype)
    ye = shard_hint(ye, cfg, ("dp", ea, None, None))

    # combine gather: each (token, choice) reads its slot back
    ye_flat = ye.reshape(ng, e * cg, d)
    ye_pad = jnp.pad(ye_flat, ((0, 0), (0, 1), (0, 0)))     # zero row
    src = jnp.where(keep, idx_g * cg + pos, e * cg)         # (nG, G*k)
    y_tk = jnp.take_along_axis(ye_pad, src[..., None], axis=1)
    y_tk = y_tk.reshape(ng, g, k, d)
    yt = jnp.einsum("ngkd,ngk->ngd", y_tk.astype(jnp.float32),
                    w_g.astype(jnp.float32)).reshape(ng * g, d)
    yt = yt[:t].astype(x.dtype)

    if m.num_shared:
        from .layers import swiglu
        yt = yt + swiglu(params["shared"], x.reshape(t, d))
    return yt.reshape(b, s, d), aux


def moe_apply_dense(params, x, cfg: ModelConfig):
    """Exact O(E)-compute reference: every expert sees every token."""
    m = cfg.moe
    v = cfg.moe_virtual_split
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    w, idx, aux = router_topk(params["router"], xt, m)
    e_eff = m.num_experts * v
    ye = _expert_ffn(params, jnp.broadcast_to(xt, (e_eff,) + xt.shape))
    if v > 1:   # sum virtual shards back into parent experts
        ye = ye.reshape(m.num_experts, v, *ye.shape[1:]).sum(1)  # detlint: ok[DET001] v virtual shards, fixed axis order; pinned by moe tests
    gates = jnp.zeros((b * s, m.num_experts), jnp.float32).at[
        jnp.arange(b * s)[:, None], idx].add(w, mode="drop")
    yt = jnp.einsum("etd,te->td", ye.astype(jnp.float32), gates)
    if m.num_shared:
        from .layers import swiglu
        yt = yt + swiglu(params["shared"], xt).astype(jnp.float32)
    return yt.astype(x.dtype).reshape(b, s, d), aux


def combine_segsum(expert_rows, row_token_ids, num_tokens, *, interpret=None):
    """Top-k combine as a JugglePAC segmented sum.

    expert_rows (R, D): already gate-weighted expert outputs, one row per
    (token, choice) pair that survived capacity; row_token_ids (R,): which
    token each row belongs to.  Variable rows-per-token == the paper's
    variable-length sets.  Returns (num_tokens, D).

    Goes through the ``repro.reduce`` front door: backend auto-selection
    picks the pallas kernel on TPU and the scanned blocks elsewhere —
    both produce bitwise-identical results.
    """
    from repro import reduce as _reduce
    backend = "pallas" if interpret is not None else None
    return _reduce.reduce(expert_rows, segment_ids=row_token_ids,
                          num_segments=num_tokens, backend=backend,
                          interpret=interpret)


def moe_apply(params, x, cfg: ModelConfig, *, impl: str = "capacity",
              capacity: Optional[int] = None):
    if cfg.moe is None:
        raise ValueError("moe_apply on a non-MoE config")
    if impl == "capacity":
        return moe_apply_capacity(params, x, cfg, capacity=capacity)
    if impl == "dense":
        return moe_apply_dense(params, x, cfg)
    raise ValueError(impl)
