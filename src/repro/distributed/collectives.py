"""Explicit-collective training step (shard_map) — the paper's technique on
the distributed-optimization path.

``make_shardmap_train_step`` builds a data-parallel training step where the
gradient reduction is *explicit* rather than XLA-inserted, enabling the two
JugglePAC/INTAC distributed tricks:

  1. **INTAC compressed all-reduce** — gradients are quantized to ``bits``-bit
     fixed point with a shared power-of-two scale, summed in the exact
     integer domain (associative => bitwise identical for any reduction
     topology / pod layout), dequantized once, with error-feedback residuals
     carried between steps.  Payload: bits/32 of fp32 (int8 => 4x).

  2. **Gradient juggler microbatching** — within a step, microbatch
     gradients accumulate through the binary-counter pairing tree
     (core.juggler): O(log m) live gradient copies, O(log m) rounding-error
     growth, schedule independent of microbatch grouping.

  3. **Hierarchical reduction** — 'data' (in-pod ICI) first, then 'pod'
     (cross-pod DCI), matching the physical topology.

The pjit path (train/steps.py) remains the default for the dry-run; this
step is benchmarked against it in benchmarks/ and exercised by tests.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core import intac, juggler
from repro.models import loss_fn
from repro.models.config import ModelConfig
from repro.optim import adamw


def make_shardmap_train_step(cfg: ModelConfig, mesh, *, lr_fn: Callable,
                             num_microbatches: int = 1,
                             compress_bits: Optional[int] = 8,
                             moe_impl: str = "dense",
                             remat: bool = False,
                             clip_norm: float = 1.0):
    """Data-parallel over every mesh axis; params replicated per shard.

    state = (params, opt_state, ef_residuals); batch leading dim must be
    divisible by (dp_size * num_microbatches).
    """
    axes = tuple(mesh.axis_names)

    def step(params, opt_state, residuals, batch):
        # ---- per-shard microbatch gradients through the pairing tree ----
        def grad_fn(p, mb):
            (loss, metrics), g = jax.value_and_grad(
                lambda pp: loss_fn(pp, cfg, mb, moe_impl=moe_impl,
                                   remat=remat), has_aux=True)(p)
            return g, (loss, metrics["xent"])

        if num_microbatches > 1:
            mbs = jax.tree.map(
                lambda x: x.reshape((num_microbatches,
                                     x.shape[0] // num_microbatches)
                                    + x.shape[1:]), batch)
            grads, (losses, _) = juggler.accumulate_microbatch_grads(
                grad_fn, params, mbs, num_microbatches=num_microbatches,
                mean=True)
            loss = jnp.mean(losses)
        else:
            grads, (loss, _) = grad_fn(params, batch)

        # ---- gradient reduction across the fleet ----
        if compress_bits is not None:
            new_res = []
            flat_g, tdef = jax.tree.flatten(grads)
            flat_r = tdef.flatten_up_to(residuals)
            red = []
            for g, r in zip(flat_g, flat_r):
                m, nr = _hierarchical_compressed_mean(
                    g, r, axes, bits=compress_bits)
                red.append(m)
                new_res.append(nr)
            grads = tdef.unflatten(red)
            residuals = tdef.unflatten(new_res)
        else:
            grads = jax.tree.map(
                lambda g: _hierarchical_mean(g, axes), grads)

        lr = lr_fn(opt_state.count + 1)   # count is 0-based
        params, opt_state, gnorm = adamw.update(
            grads, opt_state, params, lr=lr, clip_norm=clip_norm)
        loss = jax.lax.pmean(loss, axes)
        return params, opt_state, residuals, {"loss": loss,
                                              "grad_norm": gnorm, "lr": lr}

    pspec = P()           # params replicated (pure DP; FSDP stays on pjit)
    bspec = P(axes if len(axes) > 1 else axes[0])
    return shard_map(step, mesh=mesh,
                     in_specs=(pspec, pspec, pspec, bspec),
                     out_specs=(pspec, pspec, pspec, pspec),
                     check_rep=False)


def _hierarchical_mean(g, axes):
    """data-axis psum (in-pod ICI) first, then pod axis (DCI)."""
    for a in reversed(axes):            # innermost (fastest) axis first
        g = jax.lax.psum(g, a)
    n = 1.0
    return g / jax.lax.psum(jnp.float32(1.0), axes)


def _hierarchical_compressed_mean(g, residual, axes, *, bits: int):
    """INTAC compressed mean: exact integer sum per axis, one dequantize.

    The in-pod reduction runs at higher precision (bits) than needed and
    the cross-pod hop reuses the same integer payload — the quantization
    error is charged once and error-fed-back.
    """
    xr = g + residual
    gmax = jnp.max(jnp.abs(xr))
    for a in axes:
        gmax = jax.lax.pmax(gmax, a)
    scale = intac.choose_scale(gmax, 1, qbits=bits - 1)
    q = intac.quantize(xr, scale)
    new_residual = xr - intac.dequantize(q, scale)
    for a in reversed(axes):
        q = jax.lax.psum(q, a)          # exact, associative — any topology
    n = jax.lax.psum(jnp.float32(1.0), axes)
    return intac.dequantize(q, scale) / n, new_residual


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
