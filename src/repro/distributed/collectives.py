"""Explicit-collective training step (shard_map) — the paper's technique on
the distributed-optimization path.

``make_shardmap_train_step`` builds a data-parallel training step where the
gradient reduction is *explicit* rather than XLA-inserted.  The reduction
itself goes through the ``repro.reduce`` front door: microbatch gradients
stream through the Accumulator protocol (or, with ``microbatch_reduce``,
through a ``repro.reduce`` segment reduction under any accuracy policy),
and the cross-device mean is a ``repro.reduce.collective_mean`` policy —
``fast`` (plain hierarchical), ``compensated`` (INTAC compressed + error
feedback), ``exact`` (full-width integer psum), ``exact2`` (three-limb
psum: integer limbs + the exactly-captured quantization residual), or
``procrastinate`` (per-bin psum).  The JugglePAC/INTAC distributed
tricks:

  1. **INTAC compressed all-reduce** — gradients are quantized to ``bits``-bit
     fixed point with a shared power-of-two scale, summed in the exact
     integer domain (associative => bitwise identical for any reduction
     topology / pod layout), dequantized once, with error-feedback residuals
     carried between steps.  Payload: bits/32 of fp32 (int8 => 4x).

  2. **Gradient juggler microbatching** — within a step, microbatch
     gradients accumulate through the binary-counter pairing tree
     (repro.reduce.TreeAccumulator): O(log m) live gradient copies, O(log m) rounding-error
     growth, schedule independent of microbatch grouping.

  3. **Hierarchical reduction** — 'data' (in-pod ICI) first, then 'pod'
     (cross-pod DCI), matching the physical topology.

  4. **Fused merge collectives** — every cross-device merge on this path
     is batched per dtype rather than issued per component: the fast-tier
     gradient tree fuses all leaves into one psum per mesh axis
     (``collective_mean_tree``), the exact2 three-limb merge ships
     [hi | lo | residual-digits] as a single int32 psum
     (``core.intac.limb3_merge_across``), and policy-carry merges go
     through ``reduce.policy.fused_psum``.  psum is elementwise, so the
     fusion is bitwise invisible — it only removes per-collective latency
     floors, which dominate once the per-shard kernel tail shrinks.

``make_elastic_train_step`` is the topology-elastic variant: gradients
and loss cross the device boundary only through
``repro.reduce.elastic_reduce_mean`` under a bitwise policy, and the
microbatch grid is pinned to the global stream — so the same global
batch produces bit-identical params on any mesh shape or device count
(the resume-anywhere half of docs/robustness.md).

The pjit path (train/steps.py) remains the default for the dry-run; this
step is benchmarked against it in benchmarks/ and exercised by tests.
"""

from __future__ import annotations

from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro import reduce as _reduce
from repro.models import loss_fn
from repro.models.config import ModelConfig
from repro.optim import adamw


def make_shardmap_train_step(cfg: ModelConfig, mesh, *, lr_fn: Callable,
                             num_microbatches: int = 1,
                             compress_bits: Optional[int] = 8,
                             reduce_policy: Optional[str] = None,
                             microbatch_reduce: Optional[str] = None,
                             moe_impl: str = "dense",
                             remat: bool = False,
                             clip_norm: float = 1.0):
    """Data-parallel over every mesh axis; params replicated per shard.

    state = (params, opt_state, ef_residuals); batch leading dim must be
    divisible by (dp_size * num_microbatches).

    ``reduce_policy`` picks the collective accuracy tier explicitly
    ("fast" | "compensated" | "exact" | "exact2" | "procrastinate"); when
    None it is derived from ``compress_bits`` (bits set => "compensated",
    else "fast") for backward compatibility.

    ``microbatch_reduce`` (a policy name) routes the per-shard microbatch
    gradient mean through the ``repro.reduce`` segment-reduction front
    door instead of the pairing tree: per-microbatch gradients stack into
    an (m, |leaf|) stream per leaf and reduce under the chosen accuracy
    policy, so e.g. ``microbatch_reduce="exact2",
    reduce_policy="exact2"`` makes the *whole* gradient path — in-shard
    accumulation and cross-device mean — integer-exact and bitwise
    independent of microbatch count and device layout.  (The backend is
    pinned to a local executor: this already runs inside shard_map.)
    """
    axes = tuple(mesh.axis_names)
    policy = reduce_policy or ("compensated" if compress_bits is not None
                               else "fast")
    bits = compress_bits if compress_bits is not None else 8

    def step(params, opt_state, residuals, batch):
        # ---- per-shard microbatch gradients through the pairing tree ----
        def grad_fn(p, mb):
            (loss, metrics), g = jax.value_and_grad(
                lambda pp: loss_fn(pp, cfg, mb, moe_impl=moe_impl,
                                   remat=remat), has_aux=True)(p)
            return g, (loss, metrics["xent"])

        if num_microbatches > 1:
            mbs = jax.tree.map(
                lambda x: x.reshape((num_microbatches,
                                     x.shape[0] // num_microbatches)
                                    + x.shape[1:]), batch)
            if microbatch_reduce is not None:
                # backend pinned local: this already runs inside shard_map
                grads, (losses, _) = _reduce.reduce_microbatch_grads(
                    grad_fn, params, mbs,
                    num_microbatches=num_microbatches,
                    policy=microbatch_reduce, backend="blocked")
            else:
                grads, (losses, _) = _reduce.accumulate_microbatch_grads(
                    grad_fn, params, mbs, num_microbatches=num_microbatches,
                    mean=True)
            loss = jnp.mean(losses)  # detlint: ok[DET001] m microbatch scalars; grads take the front door below
        else:
            grads, (loss, _) = grad_fn(params, batch)

        # ---- gradient reduction across the fleet: one policy knob ----
        grads, residuals = _reduce.collective_mean_tree(
            grads, residuals, axes, policy=policy, bits=bits)

        lr = lr_fn(opt_state.count + 1)   # count is 0-based
        params, opt_state, gnorm = adamw.update(
            grads, opt_state, params, lr=lr, clip_norm=clip_norm)
        loss = jax.lax.pmean(loss, axes)  # detlint: ok[DET001] logging metric only; grads go through collective_mean_tree
        return params, opt_state, residuals, {"loss": loss,
                                              "grad_norm": gnorm, "lr": lr}

    pspec = P()           # params replicated (pure DP; FSDP stays on pjit)
    bspec = P(axes if len(axes) > 1 else axes[0])
    return shard_map(step, mesh=mesh,
                     in_specs=(pspec, pspec, pspec, bspec),
                     out_specs=(pspec, pspec, pspec, pspec),
                     check_rep=False)


def make_elastic_train_step(cfg: ModelConfig, mesh, *, lr_fn: Callable,
                            microbatch_size: int = 1,
                            moe_impl: str = "dense",
                            remat: bool = False,
                            clip_norm: float = 1.0,
                            policy: str = "exact2",
                            block_size: int = 512):
    """The topology-elastic training step: same params + same global batch
    => bitwise-identical new params and loss on *any* mesh.

    The difference from ``make_shardmap_train_step`` is that every
    quantity crossing the device boundary goes through
    ``repro.reduce.elastic_reduce_mean`` under a bitwise policy (exact2
    by default — all-int32 carry, residual included), and the unit of
    work is pinned to the *global* stream, not the topology:

      * ``microbatch_size`` is a fixed global constant.  shard_map splits
        the batch contiguously, each shard scans its rows in
        ``microbatch_size`` slices, so the set of microbatch gradients
        {rows [k*mb, (k+1)*mb)} is identical however many shards exist —
        only their assignment to devices changes.
      * the gradient mean and the loss mean are elastic reductions over
        that global microbatch stack: quantization grid shared by pmax,
        partition-invariant integer carries, one associative psum per
        component.  Bin-packing the same items differently cannot change
        a single bit.

    Combined with checkpointing this is the elastic-resume guarantee
    (docs/robustness.md): train on 2 devices, checkpoint, resume on 8 —
    the loss curve continues bit-for-bit (proven in tests/test_faults.py).

    Requires the per-shard row count (batch / n_devices) to be a
    multiple of ``microbatch_size``.

    state = (params, opt_state); returns (params, opt_state, metrics).
    """
    axes = tuple(mesh.axis_names)

    def step(params, opt_state, batch):
        def grad_fn(p, mb):
            (loss, metrics), g = jax.value_and_grad(
                lambda pp: loss_fn(pp, cfg, mb, moe_impl=moe_impl,
                                   remat=remat), has_aux=True)(p)
            return g, loss

        rows = jax.tree.leaves(batch)[0].shape[0]       # per-shard, static
        if rows % microbatch_size:
            raise ValueError(
                f"elastic step: per-shard batch of {rows} rows is not a "
                f"multiple of microbatch_size={microbatch_size}; the "
                f"global microbatch grid must tile every shard")
        m_local = rows // microbatch_size
        mbs = jax.tree.map(
            lambda x: x.reshape((m_local, microbatch_size) + x.shape[1:]),
            batch)

        def scan_body(_, mb):
            g, loss = grad_fn(params, mb)
            return None, (g, loss)

        _, (gstack, losses) = jax.lax.scan(scan_body, None, mbs)
        # one elastic mean per leaf over the global microbatch stack;
        # the loss is the same reduction (NOT a pmean — its combine
        # order would follow the topology)
        grads = jax.tree.map(
            lambda gs: _reduce.elastic_reduce_mean(
                gs, axes, policy=policy, block_size=block_size), gstack)
        loss = _reduce.elastic_reduce_mean(losses, axes, policy=policy,
                                           block_size=block_size)

        lr = lr_fn(opt_state.count + 1)   # count is 0-based
        params, opt_state, gnorm = adamw.update(
            grads, opt_state, params, lr=lr, clip_norm=clip_norm)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm,
                                   "lr": lr}

    pspec = P()           # params replicated (pure DP)
    bspec = P(axes if len(axes) > 1 else axes[0])
    return shard_map(step, mesh=mesh,
                     in_specs=(pspec, pspec, bspec),
                     out_specs=(pspec, pspec, pspec),
                     check_rep=False)


def init_residuals(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
