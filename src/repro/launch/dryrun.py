import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512")
# The two lines above MUST run before any other import pulls in jax: jax
# locks the device count at first backend initialization.

"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell this produces, without allocating any model memory:
  * proof the sharding config is coherent (compile succeeds on the
    production meshes: 16x16 single pod, 2x16x16 multi-pod);
  * compiled.memory_analysis()  — per-device bytes (fits-in-HBM evidence);
  * compiled.cost_analysis()    — HLO FLOPs / bytes for the roofline;
  * collective bytes parsed from the post-SPMD HLO text.

XLA counts while-loop (lax.scan) bodies ONCE in cost_analysis, so raw
numbers undercount scan-over-layers models.  We therefore also lower
depth-1 and depth-2 variants of each config (same width, 1 and 2 periods)
and extrapolate linearly: cost(N) = c1 + (N-1) * (c2 - c1).  SSM inner
scans are removed in the cost variants by setting scan_chunk = seq_len;
the sLSTM per-timestep scan is corrected analytically (see roofline.py).

Usage:
  python -m repro.launch.dryrun --arch mixtral-8x22b --shape train_4k \
      --mesh single --out experiments/dryrun
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""

import argparse
import dataclasses
import json
import re
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, get_config, shape_applicable
from repro.distributed import sharding as shd
from repro.launch import specs as sp
from repro.launch.mesh import dp_axes, make_production_mesh
from repro.models import decode_step, encode, forward
from repro.models.config import SHAPES_BY_NAME, ModelConfig, ShapeCfg
from repro.optim import adamw
from repro.train.steps import make_prefill_step, make_train_step

# perf-experiment knob (benchmarks/perf_experiments.py variants)
TRAIN_MICROBATCHES = 1

_COLLECTIVE_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*(\w+\[[^\]]*\](?:,\s*\w+\[[^\]]*\])*)?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")

_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|f8\w*|s64|s32|s16|s8|u64|u32|u16|u8|pred)\[([\d,]*)\]")

_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "s32": 4,
          "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2, "u8": 1,
          "pred": 1}


def collective_bytes_from_hlo(hlo: str) -> dict:
    """Sum result bytes of collective ops in a post-SPMD HLO module.

    Per-device (the SPMD module is the per-device program).  While bodies
    appear once — callers correct via depth extrapolation.
    """
    out = {k: 0 for k in ("all-reduce", "all-gather", "reduce-scatter",
                          "all-to-all", "collective-permute")}
    counts = {k: 0 for k in out}
    for line in hlo.splitlines():
        m = re.search(r"(all-reduce|all-gather|reduce-scatter|all-to-all|"
                      r"collective-permute)(-start)?\(", line)
        if not m or "= " not in line:
            continue
        kind = m.group(1)
        lhs = line.split("= ", 1)[0]
        rhs_type = line.split("= ", 1)[1]
        shapes = _SHAPE_RE.findall(rhs_type.split("(")[0])
        if not shapes:
            shapes = _SHAPE_RE.findall(lhs)
        nbytes = 0
        for dt, dims in shapes:
            n = 1
            for d in dims.split(","):
                if d.strip():
                    n *= int(d)
            nbytes += n * _BYTES.get(dt.split("{")[0], 4)
        out[kind] += nbytes
        counts[kind] += 1
    out["total"] = sum(out.values())
    out["counts"] = counts
    return out


def _depth_variant(cfg: ModelConfig, n_periods: int,
                   seq_len: int) -> ModelConfig:
    changes = dict(n_layers=n_periods * len(cfg.period),
                   scan_chunk=max(seq_len, 1),
                   loss_chunk=max(seq_len, 1),
                   attn_qchunk=max(seq_len, 1))
    if cfg.is_encdec:
        changes["encoder_layers"] = n_periods
    return cfg.scaled(**changes)


def build_step(cfg: ModelConfig, shape: ShapeCfg, mesh):
    """Returns (jitted_fn, abstract_args) for this cell."""
    plan = shd.mesh_plan(cfg, shape, mesh)
    dp_t = plan["batch_dp"]
    cfg = cfg.scaled(act_dp_axes=dp_t or None,
                     act_sp_axis=plan["act_sp_axis"],
                     moe_expert_axis=plan["moe_expert_axis"],
                     moe_ff_axis=plan["moe_ff_axis"])
    dp = (dp_t if len(dp_t) > 1 else (dp_t[0] if dp_t else None))
    params_abs = sp.abstract_params(cfg)
    pspec = shd.param_specs(cfg, params_abs,
                            replicate_all=plan["replicate_params"])
    pshard = shd.to_shardings(mesh, pspec)
    rep = NamedSharding(mesh, P())

    if shape.kind == "train":
        batch_abs = sp.train_input_specs(cfg, shape)
        bspec = shd.batch_specs(cfg, batch_abs, dp)
        bshard = shd.to_shardings(mesh, bspec)
        opt_abs = jax.eval_shape(adamw.init, params_abs)
        ospec = adamw.AdamWState(mu=pspec, nu=pspec, count=P())
        oshard = shd.to_shardings(mesh, ospec)
        lr_fn = adamw.cosine_schedule(3e-4, 100, 10000)
        mb = (TRAIN_MICROBATCHES if TRAIN_MICROBATCHES > 1
              else plan.get("microbatches", 1))
        step = make_train_step(cfg, lr_fn=lr_fn, remat=True,
                               logits_pspec=plan["logits_pspec"],
                               num_microbatches=mb)
        fn = jax.jit(step,
                     in_shardings=(pshard, oshard, bshard),
                     out_shardings=(pshard, oshard, rep),
                     donate_argnums=(0, 1))
        return fn, (params_abs, opt_abs, batch_abs)

    if shape.kind == "prefill":
        batch_abs = sp.prefill_input_specs(cfg, shape)
        bspec = shd.batch_specs(cfg, batch_abs, dp)
        bshard = shd.to_shardings(mesh, bspec)
        step = make_prefill_step(cfg)
        # prefill outputs: (last logits, caches)
        caches_abs = jax.eval_shape(step, params_abs, batch_abs)[1]
        seq_axes = ("model",)
        cspec = shd.cache_specs(cfg, _concretize_cache_tree(caches_abs, cfg),
                                dp, seq_axes=seq_axes)
        cshard = shd.to_shardings(mesh, cspec)
        fn = jax.jit(step, in_shardings=(pshard, bshard),
                     out_shardings=(rep, cshard))
        return fn, (params_abs, batch_abs)

    # decode
    ins = sp.decode_input_specs(cfg, shape)
    seq_axes = ("model",) if shape.global_batch > 1 else ("data", "model")
    cspec = shd.cache_specs(cfg, _concretize_cache_tree(ins["caches"], cfg),
                            dp, seq_axes=seq_axes)
    cshard = shd.to_shardings(mesh, cspec)
    tok_shard = NamedSharding(mesh, P(dp, None))
    pos_shard = rep
    enc_abs = ins.get("enc_out")

    def dstep(params, token, caches, position, enc_out=None):
        return decode_step(params, cfg, token, caches, position,
                           enc_out=enc_out)

    if enc_abs is not None:
        enc_shard = NamedSharding(mesh, P(dp, None, None))
        fn = jax.jit(dstep, in_shardings=(pshard, tok_shard, cshard,
                                          pos_shard, enc_shard),
                     out_shardings=(rep, cshard), donate_argnums=(2,))
        return fn, (params_abs, ins["token"], ins["caches"],
                    ins["position"], enc_abs)
    fn = jax.jit(dstep, in_shardings=(pshard, tok_shard, cshard, pos_shard),
                 out_shardings=(rep, cshard), donate_argnums=(2,))
    return fn, (params_abs, ins["token"], ins["caches"], ins["position"])


def _concretize_cache_tree(caches_abs, cfg):
    """cache_specs dispatches on NamedTuple types, which eval_shape
    preserves — pass through."""
    return caches_abs


def run_cell(arch: str, shape_name: str, mesh, mesh_name: str,
             *, with_cost_variants: bool = True) -> dict:
    cfg = get_config(arch)
    shape = SHAPES_BY_NAME[shape_name]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "devices": int(mesh.size), "kind": shape.kind}
    if not shape_applicable(cfg, shape):
        rec["status"] = "skipped"
        rec["reason"] = "long_500k requires sub-quadratic attention"
        return rec

    t0 = time.time()
    plan_mb = shd.mesh_plan(cfg, shape, mesh).get("microbatches", 1)
    rec["microbatches"] = (TRAIN_MICROBATCHES if TRAIN_MICROBATCHES > 1
                           else plan_mb)
    with jax.set_mesh(mesh):
        fn, args = build_step(cfg, shape, mesh)
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    rec["compile_s"] = round(time.time() - t0, 1)

    ca = compiled.cost_analysis() or {}
    rec["cost_raw"] = {k: float(v) for k, v in ca.items()
                       if isinstance(v, (int, float)) and
                       k in ("flops", "bytes accessed")}
    try:
        ma = compiled.memory_analysis()
        rec["memory"] = {
            k: int(getattr(ma, k)) for k in
            ("argument_size_in_bytes", "output_size_in_bytes",
             "temp_size_in_bytes", "generated_code_size_in_bytes")
            if hasattr(ma, k)}
    except Exception as e:                                    # CPU backend
        rec["memory"] = {"error": str(e)[:200]}
    hlo = compiled.as_text()
    rec["collectives_raw"] = collective_bytes_from_hlo(hlo)
    rec["hlo_bytes"] = len(hlo)

    if with_cost_variants:
        var = {}
        for nper in (1, 2):
            vcfg = _depth_variant(cfg, nper, shape.seq_len)
            with jax.set_mesh(mesh):
                vfn, vargs = build_step(vcfg, shape, mesh)
                vcomp = vfn.lower(*vargs).compile()
            vca = vcomp.cost_analysis() or {}
            var[nper] = {
                "flops": float(vca.get("flops", 0.0)),
                "bytes": float(vca.get("bytes accessed", 0.0)),
                "collectives": collective_bytes_from_hlo(vcomp.as_text()),
            }
        n = cfg.n_periods
        extr = {}
        for key in ("flops", "bytes"):
            c1, c2 = var[1][key], var[2][key]
            extr[key] = c1 + (n - 1) * (c2 - c1)
        coll = {}
        for k in ("all-reduce", "all-gather", "reduce-scatter",
                  "all-to-all", "collective-permute", "total"):
            c1 = var[1]["collectives"][k]
            c2 = var[2]["collectives"][k]
            coll[k] = c1 + (n - 1) * (c2 - c1)
        extr["collective_bytes"] = coll
        rec["cost_extrapolated"] = extr
        rec["cost_variants"] = var
    rec["status"] = "ok"
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="single")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--no-variants", action="store_true",
                    help="skip the depth-1/2 cost-extrapolation compiles")
    args = ap.parse_args()

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    archs = ARCH_IDS if (args.all or not args.arch) else (args.arch,)
    shapes = (tuple(SHAPES_BY_NAME) if (args.all or not args.shape)
              else (args.shape,))
    meshes = []
    if args.mesh in ("single", "both"):
        meshes.append(("single_pod_16x16", make_production_mesh()))
    if args.mesh in ("multi", "both"):
        meshes.append(("multi_pod_2x16x16",
                       make_production_mesh(multi_pod=True)))

    n_ok = n_skip = n_fail = 0
    for mesh_name, mesh in meshes:
        for arch in archs:
            for shape_name in shapes:
                tag = f"{arch}__{shape_name}__{mesh_name}"
                path = out / f"{tag}.json"
                if path.exists():
                    print(f"[cached ] {tag}")
                    continue
                try:
                    rec = run_cell(arch, shape_name, mesh, mesh_name,
                                   with_cost_variants=(
                                       not args.no_variants
                                       and mesh_name.startswith("single")))
                    status = rec["status"]
                    if status == "ok":
                        n_ok += 1
                        print(f"[ok {rec['compile_s']:6.1f}s] {tag} "
                              f"flops={rec['cost_raw'].get('flops', 0):.3g}")
                    else:
                        n_skip += 1
                        print(f"[skip   ] {tag}: {rec.get('reason')}")
                except Exception as e:
                    n_fail += 1
                    rec = {"arch": arch, "shape": shape_name,
                           "mesh": mesh_name, "status": "fail",
                           "error": f"{type(e).__name__}: {e}",
                           "traceback": traceback.format_exc()[-2000:]}
                    print(f"[FAIL   ] {tag}: {type(e).__name__}: "
                          f"{str(e)[:200]}")
                path.write_text(json.dumps(rec, indent=1))
    print(f"done: ok={n_ok} skip={n_skip} fail={n_fail}")


if __name__ == "__main__":
    main()
