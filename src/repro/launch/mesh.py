"""Production mesh construction.

Axes:
  pod    — cross-pod data parallelism (gradient reduction over DCI/ICI);
  data   — in-pod data parallelism + FSDP weight sharding;
  model  — tensor / expert / sequence(-cache) parallelism.

Importing this module never touches jax device state; call the function.
"""

from __future__ import annotations

import jax

try:                                  # jax >= 0.4.38
    from jax.sharding import AxisType
    _AXIS_KW = lambda n: {"axis_types": (AxisType.Auto,) * n}  # noqa: E731
except ImportError:                   # older jax: Auto is the only mode
    _AXIS_KW = lambda n: {}                                    # noqa: E731


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_AXIS_KW(len(axes)))


def make_mesh(shape, axes):
    """Arbitrary mesh for tests/smoke (e.g. (1, 1) on one CPU device)."""
    return jax.make_mesh(tuple(shape), tuple(axes),
                         **_AXIS_KW(len(shape)))


def dp_axes(mesh) -> tuple:
    """The data-parallel axes of a mesh (pod composes with data)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def axis_size(mesh, name: str) -> int:
    return mesh.shape[name] if name in mesh.axis_names else 1
