"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm-1.6b \
        --smoke --steps 50 --batch 8 --seq 256 --ckpt-dir /tmp/ckpt

Fault-tolerance behavior (the restart drill in tests/test_failover.py):
  * checkpoints every ``--ckpt-every`` steps (params, optimizer, data
    cursor) via repro.ckpt — atomic renames, latest-k retention;
  * on start, resumes from the newest checkpoint automatically; the data
    pipeline's batch(step) is pure, so the token stream replays exactly;
  * ``--simulate-failure-at K`` kills the process at step K (exercised by
    the failover test to prove restart equivalence).

Scale-out notes (how this maps to thousands of nodes):
  * this launcher is per-host; under multi-host JAX the same code runs on
    every host with jax.distributed.initialize() and the mesh from
    launch/mesh.py (the multi-pod dry-run proves those shardings compile);
  * stragglers: training is synchronous SPMD; mitigation is (a) the
    checkpoint/restart path above for fail-stop nodes, and (b) elastic
    restart — restore() re-shards onto whatever mesh is alive (see
    --elastic-remesh smoke flag which restores onto a different mesh
    shape to prove the path);
  * gradient compression: --compress-bits N switches to the shard_map
    step with the INTAC integer all-reduce + error feedback.
"""

from __future__ import annotations

import argparse
import os
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.ckpt import checkpoint as ckpt
from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import DataCfg, make_source
from repro.distributed.collectives import (init_residuals,
                                           make_shardmap_train_step)
from repro.launch.mesh import make_mesh
from repro.models import init_params
from repro.optim import adamw
from repro.train.steps import make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--data", default="", help="packed token file (optional)")
    ap.add_argument("--moe-impl", default="dense",
                    choices=("dense", "capacity"))
    ap.add_argument("--compress-bits", type=int, default=0,
                    help=">0: shard_map step with INTAC compressed "
                         "all-reduce at this bit width")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--simulate-failure-at", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    opt_state = adamw.init(params)
    lr_fn = adamw.cosine_schedule(args.lr, args.warmup, args.steps)

    dcfg = DataCfg(vocab=cfg.vocab, seq_len=args.seq,
                   global_batch=args.batch, seed=args.seed)
    source = make_source(dcfg, args.data or None)

    use_shardmap = args.compress_bits > 0 or args.microbatches > 1
    residuals = init_residuals(params) if use_shardmap else None
    if use_shardmap:
        mesh = make_mesh((jax.device_count(),), ("data",))
        step_fn = make_shardmap_train_step(
            cfg, mesh, lr_fn=lr_fn,
            num_microbatches=args.microbatches,
            compress_bits=args.compress_bits or None,
            moe_impl=args.moe_impl)
        step_fn = jax.jit(step_fn)
    else:
        step_fn = jax.jit(make_train_step(cfg, lr_fn=lr_fn, remat=False,
                                          moe_impl=args.moe_impl))

    start = 0
    if args.ckpt_dir:
        state = {"params": params, "opt": opt_state}
        if residuals is not None:
            state["residuals"] = residuals
        # newest *valid* snapshot: a crash mid-save leaves a .tmp dir (no
        # manifest) and a flipped bit fails the CRC sidecar — both fall
        # back to the previous verified step instead of crashing or
        # silently resuming from garbage
        restored = ckpt.restore_latest_valid(args.ckpt_dir, state)
        if restored is not None:
            state, manifest, latest = restored
            params, opt_state = state["params"], state["opt"]
            residuals = state.get("residuals", residuals)
            start = manifest["extra"]["next_step"]
            print(f"[restore] resumed from step {latest} -> next {start}",
                  flush=True)

    t0 = time.time()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in source.batch(step).items()}
        if use_shardmap:
            params, opt_state, residuals, metrics = step_fn(
                params, opt_state, residuals, batch)
        else:
            params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % args.log_every == 0 or step == args.steps - 1:
            loss = float(metrics["loss"])
            dt = time.time() - t0
            print(f"step {step:5d} loss {loss:.4f} "
                  f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)", flush=True)
        if args.ckpt_dir and ckpt.save_every(step, args.ckpt_every):
            state = {"params": params, "opt": opt_state}
            if residuals is not None:
                state["residuals"] = residuals
            ckpt.save(args.ckpt_dir, step, state,
                      extra={"next_step": step + 1, "arch": args.arch})
            print(f"[ckpt] saved step {step}", flush=True)
        if args.simulate_failure_at and step == args.simulate_failure_at:
            print(f"[failure] simulated crash at step {step}", flush=True)
            os._exit(17)

    print(f"done: {args.steps - start} steps, "
          f"final loss {float(metrics['loss']):.4f}")
    return float(metrics["loss"])


if __name__ == "__main__":
    main()
