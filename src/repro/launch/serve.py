"""Serving launcher: batched generation demo with the Engine.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm-1.6b \
        --smoke --requests 4 --new-tokens 16

Pass ``--arrival-gap G`` to drive the continuous-batching path instead
of the all-at-once wrapper: requests arrive with mean-G-step Poisson
gaps, admit mid-stream into freed decode slots, and results report
per-request latency (submission to retirement, queue wait included).
"""

from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models import init_params
from repro.serve.engine import Engine, Request


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm-1.6b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--new-tokens", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--temperature", type=float, default=0.8)
    ap.add_argument("--arrival-gap", type=float, default=0.0,
                    help="mean Poisson inter-arrival gap in engine steps; "
                         "0 = submit everything at time zero")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (get_smoke_config if args.smoke else get_config)(args.arch)
    if cfg.embed_inputs or cfg.is_encdec:
        raise SystemExit(f"{args.arch}: serve demo targets token-LM archs")
    params = init_params(jax.random.PRNGKey(args.seed), cfg)
    engine = Engine(cfg, params, max_len=args.max_len, seed=args.seed)

    rng = np.random.default_rng(args.seed)
    reqs = [Request(prompt=list(rng.integers(1, cfg.vocab,
                                             size=rng.integers(4, 24))),
                    max_new_tokens=args.new_tokens,
                    temperature=args.temperature)
            for _ in range(args.requests)]
    t0 = time.time()
    if args.arrival_gap > 0:
        t = 0.0
        for r in reqs:
            t += float(rng.exponential(args.arrival_gap))
            engine.submit(r, arrival=t)
        results = engine.run()
    else:
        results = engine.generate(reqs)
    dt = time.time() - t0
    total_new = sum(len(r.tokens) - r.prompt_len for r in results)
    for i, r in enumerate(results):
        lat = f" latency={r.latency_s * 1e3:.0f}ms" if args.arrival_gap \
            else ""
        print(f"req{i}: prompt[{r.prompt_len}] -> "
              f"+{len(r.tokens) - r.prompt_len} tokens: "
              f"{r.tokens[r.prompt_len:][:12]}{lat}")
    print(f"{total_new} tokens in {dt:.2f}s "
          f"({total_new / max(dt, 1e-9):.1f} tok/s batched)")


if __name__ == "__main__":
    main()
