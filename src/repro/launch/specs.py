"""Abstract input specs (ShapeDtypeStruct) for every (arch × shape) cell.

Nothing here allocates: the dry-run lowers against these stand-ins.
Modality frontends are stubs per the assignment — ``[vlm]`` gets precomputed
patch embeddings + M-RoPE position ids, ``[audio]`` gets precomputed frame
embeddings for the encoder.
"""

from __future__ import annotations

import functools
from typing import Any, Dict

import jax
import jax.numpy as jnp

from repro.models import init_caches, init_params
from repro.models.config import ModelConfig, ShapeCfg

# encoder memory length used for enc-dec *decode* shapes (the encoder ran at
# prefill time; its output length is bounded by the audio segment, not by
# the decoder's growing sequence).
ENC_LEN_DECODE = 4096


def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def abstract_params(cfg: ModelConfig):
    return jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))


def abstract_caches(cfg: ModelConfig, bsz: int, max_len: int):
    return jax.eval_shape(lambda: init_caches(cfg, bsz, max_len))


def train_input_specs(cfg: ModelConfig, shape: ShapeCfg) -> Dict[str, Any]:
    b, s, d = shape.global_batch, shape.seq_len, cfg.d_model
    batch: Dict[str, Any] = {}
    if cfg.embed_inputs and not cfg.is_encdec:
        batch["embeds"] = sds((b, s, d), cfg.dtype)
        batch["labels"] = sds((b, s), jnp.int32)
        if cfg.mrope:
            batch["positions"] = sds((b, s, 3), jnp.int32)
    else:
        batch["tokens"] = sds((b, s), jnp.int32)
    if cfg.is_encdec:
        batch["enc_embeds"] = sds((b, s, d), cfg.dtype)
    return batch


def prefill_input_specs(cfg: ModelConfig, shape: ShapeCfg) -> Dict[str, Any]:
    batch = train_input_specs(cfg, shape)
    batch.pop("labels", None)
    return batch


def decode_input_specs(cfg: ModelConfig, shape: ShapeCfg) -> Dict[str, Any]:
    b = shape.global_batch
    out: Dict[str, Any] = {
        "token": sds((b, 1), jnp.int32),
        "position": sds((), jnp.int32),
        "caches": abstract_caches(cfg, b, shape.seq_len),
    }
    if cfg.is_encdec:
        out["enc_out"] = sds((b, ENC_LEN_DECODE, cfg.d_model), cfg.dtype)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeCfg) -> Dict[str, Any]:
    if shape.kind == "train":
        return train_input_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    if shape.kind == "decode":
        return decode_input_specs(cfg, shape)
    raise ValueError(shape.kind)
