"""Train / prefill / decode step builders.

``make_train_step`` builds the canonical production step: forward + backward
(+ remat), gradient clip, AdamW.  Under pjit the data-parallel gradient
reduction is emitted by XLA from the shardings; ``make_shardmap_train_step``
(distributed/collectives.py) is the explicit-collective variant with the
INTAC compressed all-reduce and the gradient juggler — the paper's technique
on the distributed-optimization path.

``make_decode_step`` / ``make_prefill_step`` are the serving pair.
"""

from __future__ import annotations

import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp

from repro.models import decode_step as model_decode_step
from repro.models import encode, forward, loss_fn
from repro.models.config import ModelConfig
from repro.optim import adamw


def make_train_step(cfg: ModelConfig, *, lr_fn: Callable,
                    moe_impl: str = "capacity", remat: bool = True,
                    clip_norm: float = 1.0, weight_decay: float = 0.1,
                    logits_pspec=None, num_microbatches: int = 1,
                    grad_reduce: Optional[str] = None,
                    grad_reduce_mesh=None,
                    norm_policy: Optional[str] = None):
    """num_microbatches > 1: the batch splits along dim 0 and gradients
    accumulate through the JugglePAC binary-counter pairing tree
    (repro.reduce.TreeAccumulator) — activation memory scales down by the
    microbatch count while only O(log m) gradient copies stay live, and the
    fixed pairing schedule keeps the result independent of the grouping.

    ``grad_reduce`` (a policy name: "fast" ... "procrastinate") instead
    routes the microbatch-gradient mean through the ``repro.reduce`` front
    door (``reduce_microbatch_grads``): per-microbatch gradients stack
    into an (m, |leaf|) stream and reduce leaf-by-leaf under the chosen
    accuracy policy — for the integer tiers the mean is bitwise
    independent of microbatch count and executor.  Costs m live gradient
    copies instead of O(log m); pick it when accuracy/determinism of the
    gradient sum matters more than peak memory.  ``grad_reduce_mesh``
    additionally routes each leaf's reduction through the ``shard_map``
    backend on that mesh; leave it None (local executor) unless you
    specifically want the reduction itself distributed — the bits are
    identical either way for the integer tiers, and an m-row stream per
    leaf rarely merits per-leaf collectives.

    For data-parallel training whose step must be bitwise-reproducible
    across *device topologies* (checkpoint on 2 devices, resume on 8),
    use ``repro.distributed.collectives.make_elastic_train_step``
    instead — it pins the microbatch grid to the global stream and
    reduces through ``elastic_reduce_mean`` (docs/robustness.md).

    ``norm_policy`` routes the gradient-clipping global norm through the
    ``repro.reduce`` front door (``adamw.global_norm``); together with
    ``cfg.norm_reduce_policy`` (rmsnorm) and
    ``MoECfg.router_norm_policy`` (combine weights) it makes the
    in-model reductions policy-governed end to end (docs/algebra.md)."""
    from repro import reduce as _reduce

    def grad_fn(p, b):
        def loss_wrap(pp):
            return loss_fn(pp, cfg, b, moe_impl=moe_impl, remat=remat,
                           logits_pspec=logits_pspec)
        (loss, metrics), grads = jax.value_and_grad(
            loss_wrap, has_aux=True)(p)
        return grads, (loss, metrics)

    def train_step(params, opt_state, batch):
        if num_microbatches > 1:
            mbs = jax.tree.map(
                lambda x: x.reshape(
                    (num_microbatches, x.shape[0] // num_microbatches)
                    + x.shape[1:]), batch)
            if grad_reduce is not None:
                grads, (losses, metricses) = \
                    _reduce.reduce_microbatch_grads(
                        grad_fn, params, mbs,
                        num_microbatches=num_microbatches,
                        policy=grad_reduce, mesh=grad_reduce_mesh)
            else:
                grads, (losses, metricses) = \
                    _reduce.accumulate_microbatch_grads(
                        grad_fn, params, mbs,
                        num_microbatches=num_microbatches, mean=True)
            loss = jnp.mean(losses)  # detlint: ok[DET001] m microbatch scalars; grads take the front door above
            metrics = jax.tree.map(jnp.mean, metricses)
        else:
            grads, (loss, metrics) = grad_fn(params, batch)
        lr = lr_fn(opt_state.count + 1)   # count is 0-based
        params, opt_state, gnorm = adamw.update(
            grads, opt_state, params, lr=lr, clip_norm=clip_norm,
            weight_decay=weight_decay, norm_policy=norm_policy)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm, lr=lr)
        return params, opt_state, metrics
    return train_step


def make_eval_step(cfg: ModelConfig, *, moe_impl: str = "capacity"):
    def eval_step(params, batch):
        loss, metrics = loss_fn(params, cfg, batch, moe_impl=moe_impl,
                                remat=False)
        return dict(metrics, loss=loss)
    return eval_step


def make_prefill_step(cfg: ModelConfig, *, moe_impl: str = "capacity"):
    def prefill_step(params, batch):
        enc_out = None
        if cfg.is_encdec:
            enc_out = encode(params, cfg, batch["enc_embeds"])
        logits, caches, _ = forward(
            params, cfg, tokens=batch.get("tokens"),
            embeds=batch.get("embeds"), positions=batch.get("positions"),
            mode="prefill", enc_out=enc_out, moe_impl=moe_impl)
        # next-token distribution of the last position only
        return logits[:, -1:], caches
    return prefill_step


def make_decode_step(cfg: ModelConfig, *, moe_impl: str = "capacity"):
    def dstep(params, token, caches, position, enc_out=None):
        return model_decode_step(params, cfg, token, caches, position,
                                 enc_out=enc_out, moe_impl=moe_impl)
    return dstep
