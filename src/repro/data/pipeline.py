"""Tokenized data pipeline: deterministic, shardable, restartable.

Two sources behind one interface:
  * ``SyntheticLM``   — seeded synthetic token stream (zipfian unigram with
    a short markov flavor) for examples/benchmarks: infinite, reproducible.
  * ``PackedFile``    — memory-mapped flat token file (np.uint16/32) packed
    into fixed-length rows.

Determinism/fault-tolerance contract (what large-scale training needs):
  * batch(step, host) is a pure function — restart at step k replays the
    exact stream without reading the first k batches (skip-to-step);
  * host sharding by row index: host h of H reads rows r with r % H == h;
  * per-batch PRNG derived from (seed, step) only.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataCfg:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    num_hosts: int = 1
    host_id: int = 0

    @property
    def host_batch(self) -> int:
        assert self.global_batch % self.num_hosts == 0
        return self.global_batch // self.num_hosts


class SyntheticLM:
    """Seeded synthetic LM stream with non-trivial statistics.

    Tokens follow a zipfian unigram mixed with a position-local structure
    (repeated motifs) so that a model can actually reduce loss on it —
    useful for the train-for-a-few-hundred-steps example.
    """

    def __init__(self, cfg: DataCfg):
        self.cfg = cfg
        # fixed zipf table
        ranks = np.arange(1, cfg.vocab + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self._p = (p / p.sum()).astype(np.float64)  # detlint: ok[DET001] host-side numpy f64 dataset init, never traced

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(
            np.random.SeedSequence([cfg.seed, step, cfg.host_id]))
        b, s = cfg.host_batch, cfg.seq_len
        toks = rng.choice(cfg.vocab, size=(b, s), p=self._p)
        # motif structure: copy a shifted window with prob 1/4 per row
        copy_rows = rng.random(b) < 0.25
        if s >= 64:
            src = toks[:, : s // 2]
            toks[copy_rows, s // 2: s // 2 + src.shape[1]] = src[copy_rows]
        return {"tokens": toks.astype(np.int32)}

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1


class PackedFile:
    """Flat token file -> fixed-length rows, host-sharded, step-addressed."""

    def __init__(self, path: str, cfg: DataCfg, dtype=np.uint16):
        self.cfg = cfg
        self._data = np.memmap(path, dtype=dtype, mode="r")
        self.rows = len(self._data) // cfg.seq_len

    def batch(self, step: int) -> dict:
        cfg = self.cfg
        b, s = cfg.host_batch, cfg.seq_len
        # deterministic row addressing: global row ids for this (step, host)
        base = step * cfg.global_batch + cfg.host_id * b
        idx = (base + np.arange(b)) % self.rows
        rows = np.stack([self._data[i * s:(i + 1) * s] for i in idx])
        return {"tokens": rows.astype(np.int32)}


def make_source(cfg: DataCfg, path: Optional[str] = None):
    if path:
        return PackedFile(path, cfg)
    return SyntheticLM(cfg)
